"""Ch. 3 (Tables 3.3/3.4, Fig. 3.4): DLSB multiplier overheads + the
large-size-multiplication case study, on the paper's own unit-gate model,
plus wall-time of the bit-exact emulation."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area_model, encodings as enc


def rows():
    out = []
    t = area_model.dlsb_overhead_table()
    for n, (d1, d2) in t.items():
        out.append((f"dlsb.overhead_straightforward_n{n}_pct", 0.0, round(d1, 2)))
        out.append((f"dlsb.overhead_sophisticated_n{n}_pct", 0.0, round(d2, 2)))
    # Fig 3.4 case study: n-bit DLSB2 vs (n+1)-bit CMB as building block
    for n in (8, 16, 32):
        gain = 100 * (1 - area_model.area_dlsb2(n) / area_model.area_cmb(n + 2))
        out.append((f"dlsb.large_mult_area_gain_n{n}_pct", 0.0, round(gain, 1)))
    # emulation throughput (bit-exact DLSB product, vectorized)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 1 << 16), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 1 << 16), jnp.int32)
    ap = jnp.ones_like(a) % 2
    f = jax.jit(lambda a, ap, b, bp: enc.mult_dlsb_sophisticated(a, ap, b, bp, 16))
    f(a, ap, b, ap).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(a, ap, b, ap).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    out.append(("dlsb.emul_64k_products", round(us, 1), "bit-exact"))
    return out
