"""Roofline derivation (§Roofline deliverable): three terms per (arch x shape)
cell from the single-pod dry-run artifacts.

  compute_s    = dot_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
  memory_s     = hbm_bytes_per_device / HBM_bw            (819 GB/s)
  collective_s = collective_bytes_per_device / link_bw    (50 GB/s ICI)

FLOP and collective numerators come from the compiled per-device HLO via the
trip-count-aware walk in dist/hlo_analysis (XLA's cost_analysis counts while
bodies once — scanned layers would be ~L x undercounted).

Memory numerator: the raw HLO op-walk traffic proxy is kept as a diagnostic
but NOT used for the term — the CPU backend leaves element-wise chains
unfused (TPU XLA fuses them), so the op-walk overcounts HBM traffic by one to
two orders of magnitude.  Instead the term uses the compiled buffer
assignment (memory_analysis — backend-robust):

  hbm_bytes = argument_bytes            (params/opt-state/cache read once)
            + 2 * temp_bytes            (each live temp written + read)
            + (output_bytes - alias)    (non-donated outputs written)

Byte conventions: per-device; single-link ICI budget (conservative).

MODEL_FLOPS (useful work): train 6*N*D, MoE 6*N_active*D, prefill 2*N*D,
decode 2*N_active*B_newtokens; ratio MODEL/HLO flags remat & padding waste.
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun" / "pod16x16"

PEAK_FLOPS = 197e12     # bf16 / chip (v5e-class)
PEAK_FLOPS_INT8 = 394e12  # s8 MXU rate (2x bf16); s32 dots in the dry-run HLO
#                          are CPU-upcast int8 paths (no other s32 GEMMs exist)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link ICI


def model_flops(rec: dict) -> float:
    """Useful-work FLOPs per device (N recomputed from configs so model
    fixes don't require re-running the dry-run sweep)."""
    try:
        import sys

        sys.path.insert(0, str(ROOT / "src"))
        from repro.configs import get_config

        n_tot, n_act = get_config(rec["arch"]).param_count()
    except Exception:
        n_tot, n_act = rec["params_total"], rec["params_active"]
    chips = rec["chips"]
    if rec["kind"] == "train":
        d = rec["seq"] * rec["global_batch"]
        return 6.0 * n_act * d / chips
    if rec["kind"] == "prefill":
        d = rec["seq"] * rec["global_batch"]
        return 2.0 * n_act * d / chips
    # decode: one new token per sequence
    return 2.0 * n_act * rec["global_batch"] / chips


# ring-algorithm wire cost per byte of tensor: all-reduce moves ~2x (reduce-
# scatter phase + all-gather phase); RS / AG / A2A / permute move ~1x.
_WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def wire_bytes(by_kind: dict) -> float:
    return sum(_WIRE_WEIGHT.get(k, 1.0) * v for k, v in by_kind.items())


def analyze_cell(rec: dict) -> dict:
    h = rec["hlo_analysis"]
    m = rec["memory"]
    flops_dev = h["dot_flops"]
    bytes_dev = (m["argument_bytes"] + 2 * m["temp_bytes"]
                 + max(m["output_bytes"] - m["alias_bytes"], 0))
    coll_dev = wire_bytes(h["collectives"]["by_kind"])
    by_dtype = h.get("dot_flops_by_dtype") or {"f32": flops_dev}
    compute_s = sum(
        v / (PEAK_FLOPS_INT8 if dt in ("s8", "s32") else PEAK_FLOPS)
        for dt, v in by_dtype.items())
    if not by_dtype:
        compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    bound_s = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": flops_dev,
        "useful_ratio": mf / flops_dev if flops_dev else 0.0,
        # roofline fraction: useful-work time at peak vs bound time
        "roofline_frac": (mf / PEAK_FLOPS) / bound_s if bound_s else 0.0,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "arg_gib": rec["memory"]["argument_bytes"] / 2**30,
        "coll_detail": h["collectives"]["by_kind"],
    }


SUGGESTIONS = {
    "compute": "cut recompute (remat policy) / causal-skip attention blocks / "
               "int8 MXU path (axqmm) halves the compute term",
    "memory": "bf16 master-weight read path, fuse dequant, larger attention "
              "blocks to raise arithmetic intensity",
    "collective": "reduce-scatter+all-gather instead of all-reduce, overlap "
                  "via async collectives, int8-compressed gradient psum, "
                  "FSDP to trade param all-gathers for smaller grad reduces",
}


def load_cells(dirpath: Path = DRYRUN) -> list[dict]:
    out = []
    for f in sorted(dirpath.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            out.append(analyze_cell(rec))
        elif rec.get("status") == "skip":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "skip": rec["skip_reason"]})
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.2f}us"


def table(cells: list[dict]) -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful/HLO | roofline-frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skip" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | SKIP: "
                        f"{c['skip']} | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(c['compute_s'])} | "
            f"{fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} | "
            f"**{c['dominant']}** | {c['useful_ratio']:.2f} | "
            f"{c['roofline_frac']:.2%} |")
    return "\n".join(rows)


def main() -> None:
    cells = load_cells()
    ok = [c for c in cells if "skip" not in c]
    print(table(cells))
    print()
    for c in ok:
        print(f"{c['arch']} x {c['shape']}: dominant={c['dominant']} -> "
              f"{SUGGESTIONS[c['dominant']]}")
    out = ROOT / "experiments" / "roofline.json"
    out.write_text(json.dumps(cells, indent=2))
    (ROOT / "experiments" / "roofline.md").write_text(table(cells) + "\n")
    # pick the three hillclimb cells (§Perf): worst roofline fraction,
    # most collective-bound, most paper-representative (approx-GEMM heavy
    # train cell of the biggest dense model)
    graded = sorted(ok, key=lambda c: c["roofline_frac"])
    coll = sorted(ok, key=lambda c: -c["collective_s"])
    print("\nhillclimb candidates:")
    print("  worst roofline-frac:", graded[0]["arch"], graded[0]["shape"],
          f"{graded[0]['roofline_frac']:.2%}")
    print("  most collective-bound:", coll[0]["arch"], coll[0]["shape"],
          fmt_s(coll[0]["collective_s"]))


if __name__ == "__main__":
    main()
