"""Ch. 5 Table 5.5: runtime-configurable (DyFXU) vs design-time (AxFXU).
Hardware claim: ~3% area overhead, ~1.5x smaller gains, same error.  JAX
analogue measured here: traced-degree executable vs degree-constant-folded
executable — wall-time overhead of dynamism + identical bit-exact outputs,
plus degree switching without recompilation."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import axmult


def _time(f, *args, iters=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    out = []
    n = 16
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 1 << 18), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 1 << 18), jnp.int32)
    static = jax.jit(lambda a, b: axmult.mult_pr(a, b, n, 2, 4))
    dyn = jax.jit(lambda a, b, p, r: axmult.pr_multiply_dynamic(a, b, n, p, r))
    t_static = _time(static, a, b)
    p, r = jnp.int32(2), jnp.int32(4)
    t_dyn = _time(dyn, a, b, p, r)
    same = bool((static(a, b) == dyn(a, b, p, r)).all())
    out.append(("dyn.static_us", round(t_static, 1), "AxFXU p2r4"))
    out.append(("dyn.dynamic_us", round(t_dyn, 1), "DyFXU traced degree"))
    out.append(("dyn.overhead_pct", 0.0,
                round(100 * (t_dyn - t_static) / t_static, 1)))
    out.append(("dyn.bit_identical", 0.0, same))
    # switching degree: no recompile (same executable, new scalar)
    t0 = time.perf_counter()
    for pp, rr in [(0, 0), (1, 2), (3, 6), (4, 8)]:
        dyn(a, b, jnp.int32(pp), jnp.int32(rr)).block_until_ready()
    out.append(("dyn.switch_4_degrees_us", round((time.perf_counter() - t0) * 1e6, 1),
                "no recompilation"))
    return out
