"""Benchmark harness — one module per dissertation table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV."""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_cnn, bench_dlsb, bench_dsp, bench_dynamic,
                            bench_kernels, bench_pareto, bench_pr, bench_rad,
                            bench_serving)

    mods = [bench_dlsb, bench_rad, bench_pr, bench_dynamic, bench_pareto,
            bench_dsp, bench_cnn, bench_kernels, bench_serving]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        name = m.__name__.split(".")[-1]
        if only and only not in name:
            continue
        try:
            for row in m.rows():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
