"""Benchmark harness — one module per dissertation table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV.

For the perf-tracked modules (bench_kernels, bench_serving) the rows are also
written to ``benchmarks/BENCH_kernels.json`` / ``benchmarks/BENCH_serving.json``
— machine-readable perf records (skip-grid block-steps, decode µs/step,
tok/s) that future PRs regress against (``tools/check_bench.py`` /
``repro.obs.regress`` gate on their scale-invariant invariants).
"""
import json
import pathlib
import platform
import subprocess
import sys
import time
import traceback

_JSON_MODULES = {"bench_kernels": "BENCH_kernels.json",
                 "bench_serving": "BENCH_serving.json",
                 "bench_gemm": "BENCH_gemm.json",
                 "bench_tune": "BENCH_tune.json",
                 "bench_stream": "BENCH_stream.json",
                 "bench_chaos": "BENCH_chaos.json",
                 "bench_elastic": "BENCH_elastic.json",
                 "bench_admission": "BENCH_admission.json"}

# bump when the record layout changes; repro.obs.regress pins this
SCHEMA_VERSION = 2


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).parent, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def make_record(name: str, rows: list) -> dict:
    """Build a schema-v2 BENCH record: provenance stamps (git SHA, platform,
    JAX + kernel backends) make records comparable across machines — the
    regression gate refuses unstamped or cross-schema diffs."""
    import os

    import jax

    from repro.kernels import dispatch as kdispatch

    return {
        "bench": name,
        "schema_version": SCHEMA_VERSION,
        "unix_time": int(time.time()),
        "git_sha": _git_sha(),
        "platform": platform.platform(),
        "jax_backend": jax.default_backend(),
        "kernels_backend": kdispatch.resolved_backend(),
        # tiny CI-smoke runs use shrunk shapes: never compare their rows
        # against a full-shape baseline (row names overlap)
        "tiny_shapes": os.environ.get("REPRO_BENCH_TINY", "0") == "1",
        "columns": ["name", "us_per_call", "derived"],
        "rows": [[str(x) for x in r] for r in rows],
    }


def _write_record(name: str, rows: list) -> None:
    from repro.resil import retry

    path = pathlib.Path(__file__).parent / _JSON_MODULES[name]
    record = json.dumps(make_record(name, rows), indent=1) + "\n"
    # record writes ride the shared resilience retry helper: losing a
    # 10-minute bench run to one transient FS error is the silly outcome
    retry(lambda: path.write_text(record))


def main() -> None:
    from benchmarks import (bench_admission, bench_chaos, bench_cnn,
                            bench_dlsb, bench_dsp, bench_dynamic,
                            bench_elastic, bench_gemm, bench_kernels,
                            bench_pareto, bench_pr, bench_rad, bench_serving,
                            bench_stream, bench_tune)

    mods = [bench_dlsb, bench_rad, bench_pr, bench_dynamic, bench_pareto,
            bench_dsp, bench_cnn, bench_kernels, bench_gemm, bench_tune,
            bench_serving, bench_stream, bench_chaos, bench_elastic,
            bench_admission]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        name = m.__name__.split(".")[-1]
        if only and only not in name:
            continue
        try:
            rows = list(m.rows())
            for row in rows:
                print(",".join(str(x) for x in row), flush=True)
            if name in _JSON_MODULES:
                _write_record(name, rows)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
