"""Admission-pipeline benchmarks (ISSUE 10): bucketed AOT prefill, packed
prompts, chunked prefill.

Three scenarios on the dense smoke LM, each backing one acceptance claim:

* **zero recompiles** — an engine warmed at construction serves a bursty
  mix of 20 random-length prompts; the jit trace counters must not move
  (``post_warmup_traces=0``): the bucket ladder closed the executable set.
* **packed throughput** — the bursty short-prompt burst, admitted as
  pack=4 bucketed prefill calls vs one-row-at-a-time calls (same warmed
  executables).  The headline is admitted-requests/s; the gate pins
  packed >= 1.5x sequential (full-shape run).
* **chunked TTFT** — one 120-token prompt arrives with a stream of short
  requests behind it, under a :class:`~repro.resil.policy.VirtualClock`
  with a modeled per-admitted-token device cost (CPU emulation cannot show
  prefill-length effects on wall clock).  Chunked admission (8-token
  chunks interleaved with decode) must bound the short-request TTFT p99
  below the unchunked monolithic-prefill baseline.

REPRO_BENCH_TINY=1 shrinks iteration counts for the CI bench-smoke job.
Committed record: benchmarks/BENCH_admission.json (full-shape run).
"""
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.resil import VirtualClock
from repro.serve.admission import AdmissionConfig
from repro.serve.engine import ServeEngine

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
_ARCH = "tinyllama-1.1b-smoke"

#: modeled device cost per admitted prompt token (virtual ms) — what makes
#: a monolithic 128-bucket prefill visibly stall the tick on the clock
_MS_PER_UNIT = 0.25
#: modeled fused decode-step cost per tick (virtual ms)
_MS_PER_STEP = 1.0

_CACHE: dict = {}


def _model():
    if not _CACHE:
        cfg = get_config(_ARCH)
        m = build_model(cfg)
        _CACHE["m"] = m
        _CACHE["params"] = m.init(jax.random.PRNGKey(0), tp=1)
    return _CACHE["m"], _CACHE["params"]


def _prompts(n, lo, hi, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, _CACHE["m"].cfg.vocab,
                         int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# scenario 1: warmup closes the executable set
# ---------------------------------------------------------------------------


def _zero_recompile():
    m, params = _model()
    adm = AdmissionConfig(pack=2, chunk_tokens=8)
    t0 = time.perf_counter()
    eng = ServeEngine(m, params, slots=4, max_len=64, seed=0,
                      admission=adm, emitter=False)
    warm_us = (time.perf_counter() - t0) * 1e6
    wl = eng.workload
    before = dict(wl.trace_counts)
    n = 6 if _TINY else 20
    reqs = [eng.submit(p, 3)
            for p in _prompts(n, 2, wl.admission.buckets[-1] - 3, seed=5)]
    eng.run_until_drained()
    post = sum(wl.trace_counts[k] - before.get(k, 0)
               for k in wl.trace_counts)
    ok = sum(1 for r in reqs if r.status == "ok")
    yield ("adm.warmup", f"{warm_us:.1f}",
           f"buckets={len(wl.admission.buckets)}")
    yield ("adm.zero_recompile", "0",
           f"post_warmup_traces={post};buckets={len(wl.admission.buckets)};"
           f"prompts={n};ok={ok}")


# ---------------------------------------------------------------------------
# scenario 2: packed vs sequential admitted-requests/s
# ---------------------------------------------------------------------------


def _packed():
    m, params = _model()
    iters = 4 if _TINY else 30
    lens = [3, 7, 11, 14]                      # one 16-bucket, four rows

    def build(pack):
        adm = AdmissionConfig(buckets=(16,), pack=pack, warmup=True)
        eng = ServeEngine(m, params, slots=4, max_len=32, seed=0,
                          admission=adm, emitter=False)
        rng = np.random.default_rng(7)
        reqs = [eng.submit(rng.integers(1, m.cfg.vocab, l).astype(np.int32),
                           2) for l in lens]
        # pull them back out of the queue: the bench times admission alone
        eng.queue.clear()
        return eng, reqs

    def admit_all(eng, reqs, pack):
        wl = eng.workload
        for i in range(0, len(reqs), pack):
            group = [(s, r) for s, r in enumerate(reqs[i:i + pack])]
            eng.state, _ = wl.admit_batch(eng.params, eng.state, eng._feed,
                                          group, eng._degree)
        jax.block_until_ready(eng.state)

    walls = {}
    for pack in (4, 1):
        eng, reqs = build(pack)
        admit_all(eng, reqs, pack)             # warm the exact call pattern
        t0 = time.perf_counter()
        for _ in range(iters):
            for r in reqs:
                r.cursor = 0
            admit_all(eng, reqs, pack)
        walls[pack] = time.perf_counter() - t0
    n_req = len(lens) * iters
    rps = {p: n_req / walls[p] for p in walls}
    speedup = walls[1] / walls[4]
    yield ("adm.packed_prefill", f"{walls[4] / iters * 1e6:.1f}",
           f"rps={int(rps[4])}")
    yield ("adm.sequential_prefill", f"{walls[1] / iters * 1e6:.1f}",
           f"rps={int(rps[1])}")
    yield ("adm.packed_speedup", "0", f"speedup_x100={int(speedup * 100)}")


# ---------------------------------------------------------------------------
# scenario 3: chunked prefill bounds short-request TTFT
# ---------------------------------------------------------------------------


def _ttft_run(chunk_tokens):
    m, params = _model()
    adm = AdmissionConfig(pack=1, chunk_tokens=chunk_tokens)
    clock = VirtualClock()
    eng = ServeEngine(m, params, slots=2, max_len=160, seed=0,
                      admission=adm, emitter=False, clock=clock)
    rng = np.random.default_rng(11)
    long = eng.submit(rng.integers(1, m.cfg.vocab, 120).astype(np.int32), 4)
    shorts = [eng.submit(rng.integers(1, m.cfg.vocab, 3).astype(np.int32), 2)
              for _ in range(4)]
    units_seen = 0.0
    for _ in range(400):
        eng.tick()
        units = eng.stats.c_admit_units.value
        clock.advance(((units - units_seen) * _MS_PER_UNIT
                       + _MS_PER_STEP) / 1e3)
        units_seen = units
        if long.done and all(r.done for r in shorts):
            break
    ttfts = sorted((r.t_first_emit - r.t_enqueue) * 1e6 for r in shorts)
    p99 = ttfts[max(int(np.ceil(len(ttfts) * 0.99)) - 1, 0)]
    reqs = [long] + shorts
    lost = len(reqs) - len(eng.done)
    dup = len(eng.done) - len({r.rid for r in eng.done})
    short = sum(1 for r in reqs
                if r.status == "ok" and len(r.out) != r.budget)
    return p99, f"lost={lost},dup={dup},short={short}"


def _chunked_ttft():
    p99_c, acct_c = _ttft_run(8)
    p99_u, acct_u = _ttft_run(0)
    yield ("adm.chunked_ttft", "0",
           f"chunked_p99_us={int(p99_c)};unchunked_p99_us={int(p99_u)}")
    yield ("adm.chunked_accounting", "0", f"{acct_c};{acct_u}")


def rows():
    out = []
    out += list(_zero_recompile())
    out += list(_packed())
    out += list(_chunked_ttft())
    return out
