"""§Perf iteration table: baseline vs tagged hillclimb variants.

Reads experiments/dryrun/pod16x16/ (baseline) and pod16x16__<tag>/ variants,
prints the before/after roofline terms per hillclimb cell.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import ROOT, analyze_cell, fmt_s

CELLS = [
    ("mistral-nemo-12b", "decode_32k",
     ["kv_int8", "kv_int8_bf16", "serve_bf16"]),
    ("mistral-nemo-12b", "train_4k", ["bwd_bf16", "ring_tp", "accum4"]),
    ("qwen2-moe-a2.7b", "train_4k", ["moe_int8", "ring_moe"]),
]


def load(arch: str, shape: str, tag: str = ""):
    d = "pod16x16" + (f"__{tag}" if tag else "")
    p = ROOT / "experiments" / "dryrun" / d / f"{arch}__{shape}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    if rec.get("status") != "ok":
        return None
    return analyze_cell(rec)


def main() -> None:
    rows = ["| cell | variant | compute | memory | collective | dominant | "
            "roofline-frac | Δdominant |",
            "|---|---|---|---|---|---|---|---|"]
    for arch, shape, tags in CELLS:
        base = load(arch, shape)
        if base is None:
            continue
        base_dom = max(base["compute_s"], base["memory_s"], base["collective_s"])
        rows.append(
            f"| {arch} × {shape} | baseline | {fmt_s(base['compute_s'])} | "
            f"{fmt_s(base['memory_s'])} | {fmt_s(base['collective_s'])} | "
            f"{base['dominant']} | {base['roofline_frac']:.2%} | — |")
        for tag in tags:
            c = load(arch, shape, tag)
            if c is None:
                rows.append(f"| | {tag} | (missing) | | | | | |")
                continue
            dom = max(c["compute_s"], c["memory_s"], c["collective_s"])
            delta = (dom - base_dom) / base_dom
            rows.append(
                f"| | {tag} | {fmt_s(c['compute_s'])} | {fmt_s(c['memory_s'])} | "
                f"{fmt_s(c['collective_s'])} | {c['dominant']} | "
                f"{c['roofline_frac']:.2%} | {delta:+.1%} |")
    out = "\n".join(rows)
    print(out)
    (ROOT / "experiments" / "perf_table.md").write_text(out + "\n")


if __name__ == "__main__":
    main()
