"""Streaming DSP/vision serving on the generic engine (ISSUE 7, Ch. 7).

The claim under test: the SAME serving core that batches token decode also
serves the approximate FIR + conv2d pipeline frame-by-frame — steady-state
throughput, a PSNR-calibrated per-site degree ladder, and QoS rung moves at
ONE compiled step executable.  Rows:

* ``stream.slots{N}_frames_per_s`` — steady-state frames/s through the
  continuous-batching engine (warm jit; us column is µs per frame).
* ``stream.plan_search`` / ``stream.plan_rungs`` — the PSNR-metric
  calibration search (``tune.build_plan`` with ``psnr_metric``).
* ``stream.uniform_e{e}`` / ``stream.rung_{k}`` — ``err=..,cost=..`` pairs
  on the (neg-PSNR, modeled-cost) Pareto axes, same convention as
  bench_tune; ``stream.rung_{k}_psnr_db`` carries the rung's calibrated
  PSNR in dB (the gate checks it is monotone non-increasing down the
  ladder).
* ``stream.dominated_uniform_rungs`` — the mixed-ladder dominance verdict
  (asserted non-empty, like bench_tune).
* ``stream.qos_walk_compiles`` — number of compiled step executables after
  serving every ladder rung (asserted == 1: the traced degree vector keeps
  rung moves recompile-free).

REPRO_BENCH_TINY=1 shrinks clips/grid for the CI smoke job.  Committed
record: benchmarks/BENCH_stream.json (full-shape run).
"""
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.serve.stream import (StreamAdapter, StreamServeEngine, make_clip,
                                psnr_metric)
from repro.tune import build_plan, vector_cost
from repro.tune.autotune import _Prober

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def rows():
    out = []
    adapter = StreamAdapter()
    cfg = adapter.cfg
    params = adapter.init_params()

    # ---- PSNR-calibrated plan (the stream analogue of bench_tune) ----
    # the grid must reach below 6 even in tiny mode: dominance needs a
    # mixed vector that undercuts a uniform rung's cost (grid (8, 6) has
    # no room under uniform-6)
    n_clips, n_frames = (2, 4) if _TINY else (4, 8)
    grid = (8, 6, 4)
    calib = {"frames": np.stack([make_clip(n_frames, cfg.frame, q=cfg.q,
                                           seed=i) for i in range(n_clips)])}
    prober = _Prober(adapter, params, calib, metric=psnr_metric)
    plan = build_plan(adapter, params, calib, grid=grid, prober=prober,
                      metric=psnr_metric)
    us_per_cfg = plan.meta["tune_seconds"] * 1e6 / plan.meta["visited"]
    out.append(("stream.plan_search", round(us_per_cfg, 0),
                f"{plan.meta['strategy']}:{plan.meta['visited']}cfgs,"
                f"metric={plan.meta['metric']}"))
    out.append(("stream.plan_rungs", 0.0, len(plan.ladder)))

    # uniform baseline = the legacy global-knob QoS ladder (8..4), denser
    # than the search grid: the odd rungs are where one global ebits hurts
    # (e.g. e=5 rounds the conv weights to garbage while a mixed plan
    # holds conv at 6 and spends the savings on the FIR)
    S = len(plan.sites)
    uniform = {}
    for e in (8, 7, 6, 5, 4):
        vec = [int(e)] * S
        uniform[e] = (prober.error(vec), vector_cost(cfg, vec))
        out.append((f"stream.uniform_e{e}", 0.0,
                    f"err={uniform[e][0]:.4f},cost={uniform[e][1]:.4f}"))
    for pt in plan.ladder:
        out.append((f"stream.{pt.name}", 0.0,
                    f"deg={'.'.join(map(str, pt.degrees))},"
                    f"err={pt.error:.4f},cost={pt.cost:.4f}"))
        # the rung's calibrated quality in application units (dB): the
        # error axis is neg-PSNR, so quality is its negation
        out.append((f"stream.{pt.name}_psnr_db", 0.0, round(-pt.error, 2)))

    verdicts = []
    for e, (ue, uc) in sorted(uniform.items()):
        doms = [pt for pt in plan.ladder if pt.cost < uc and pt.error <= ue]
        if doms:
            best = min(doms, key=lambda p: p.cost)
            verdicts.append(f"e{e}<{best.name}"
                            f"(cost-{100 * (1 - best.cost / uc):.1f}%)")
    out.append(("stream.dominated_uniform_rungs", 0.0,
                "+".join(verdicts) if verdicts else "none"))
    assert verdicts, (
        "stream plan failed to dominate any uniform rung — the PSNR "
        "calibration or per-site degree plumbing regressed")

    # ---- steady-state serving throughput ----
    n_req, clip_frames = (3, 4) if _TINY else (8, 16)
    for slots in ((2,) if _TINY else (2, 4)):
        eng = StreamServeEngine(adapter, params, slots=slots, plan=plan)
        eng.submit(make_clip(2, cfg.frame, q=cfg.q, seed=99))
        eng.run_until_drained()                  # warm the compiled step
        eng.done.clear()
        t0 = time.perf_counter()
        for i in range(n_req):
            eng.submit(make_clip(clip_frames, cfg.frame, q=cfg.q, seed=i))
        done = eng.run_until_drained()
        dt = time.perf_counter() - t0
        frames = sum(len(r.out) for r in done)
        out.append((f"stream.slots{slots}_frames_per_s",
                    round(dt * 1e6 / max(frames, 1), 1),
                    round(frames / dt, 1)))

    # ---- QoS rung walk at one compile ----
    eng = StreamServeEngine(adapter, params, slots=2, plan=plan)
    for rung in range(len(plan.ladder)):
        eng._degree = jnp.asarray(plan.degrees(rung), jnp.int32)
        eng.submit(make_clip(2, cfg.frame, q=cfg.q, seed=rung))
        eng.run_until_drained()
    compiles = int(eng._step._cache_size())
    out.append(("stream.qos_walk_compiles", 0.0, compiles))
    assert compiles == 1, (
        f"rung walk recompiled the stream step ({compiles} executables) — "
        "the degree operand stopped being shape-stable")
    return out
