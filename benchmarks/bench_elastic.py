"""Elastic fleet serving through replica loss (repro.dist.fleet, ISSUE 9).

The claims under test, on a 3-replica stream fleet driven by one
deterministic :class:`~repro.resil.policy.VirtualClock` (one fleet tick
costs BASE_TICK_MS at the slowest live engine's rung — replicas run in
parallel, so virtual time advances once per fleet tick however many
replicas serve):

* **kill-one-of-three** — a scripted ``replica_loss`` lands mid-serve.
  Rows carry goodput (ok completions per virtual second) *before* the
  kill, *during* the rescale window, and *after* on the survivor mesh;
  the gate's headline is goodput > 0 on both sides of the event and the
  survivor plan matching ``elastic.plan_rescale``.  Survivors absorb the
  capacity dip through their own brownout ladders before anything sheds.
* **exactly-once accounting** — fleet-wide lost / duplicated / short
  counts must all be 0, and every ok payload must be bit-identical to a
  clean single-engine reference run (``fleet_corrupt_payloads == 0``).
* **ragged planning** — 7 survivors under tp=4 plan to a usable
  power-of-two subset with ``idle_devices`` reported, instead of raising
  out of the recovery path.
* **determinism** — a seeded stochastic loss schedule re-run at the same
  seed must reproduce the injected kills, the fleet recovery trace, and
  every payload bit-for-bit.
* **collective budget** — the sharded LM decode step's wire bytes with
  the int8 ppermute ring must stay within half the exact-f32 budget
  (measured from compiled HLO; computed in a subprocess when the host
  has a single visible device).

REPRO_BENCH_TINY=1 shrinks the fleet/clips for the CI dist-serve smoke.
Committed record: benchmarks/BENCH_elastic.json (full-shape run).
"""
import os
import subprocess
import sys

import numpy as np

from repro.core.dynamic import QoSController
from repro.dist.elastic import plan_rescale
from repro.dist.fleet import FleetSupervisor
from repro.resil import (FaultEvent, FaultPlan, FaultSpec, GuardConfig,
                         ServePolicy, VirtualClock)
from repro.serve.stream import StreamAdapter, StreamServeEngine, make_clip
from repro.tune import vector_cost

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"

#: virtual cost of one fleet tick at the exact rung (ms)
BASE_TICK_MS = 2.0
_LADDER_EBITS = (8, 7, 6, 5, 4)
RESCALE_MS = 5.0


def _ladder(cfg):
    return [{"degrees": [e] * (cfg.n_layers + 1)} for e in _LADDER_EBITS]


def _tick_cost_s(cfg, engines) -> float:
    """Virtual seconds one *fleet* tick costs: replicas step in parallel,
    the slowest live engine's rung sets the pace."""
    worst = 0.0
    for eng in engines:
        if eng.stats.degree_history:
            degrees = list(eng.stats.degree_history[-1][1])
        else:
            degrees = [8] * (cfg.n_layers + 1)
        worst = max(worst, vector_cost(cfg, degrees))
    return BASE_TICK_MS * (worst or 1.0) / 1e3


def _payload_key(req):
    return tuple(np.asarray(f).tobytes() for f in req.out)


def _statuses(reqs) -> dict:
    out: dict = {}
    for r in reqs:
        out[r.status] = out.get(r.status, 0) + 1
    return out


def _mix(st: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(st.items()))


def _fleet(*, replicas, slots, faults, clock, qos=True):
    """``qos=True`` arms the brownout ladder (approximate absorption of
    the capacity dip — payloads are then *approximate* by design);
    ``qos=False`` serves every request at the exact rung, the
    configuration the bit-identity oracle applies to."""
    cfg = StreamAdapter().cfg
    policy = ServePolicy(deadline_ms=None, ttft_deadline_ms=None,
                         max_queue=2 * slots if qos else None,
                         max_queue_age_ms=None, backoff_ms=0.5)

    def build(mesh, rid):
        return StreamServeEngine(
            slots=slots, clock=clock, policy=policy, guards=GuardConfig(),
            qos=QoSController(ladder=_ladder(cfg), low_water=0.25,
                              high_water=0.75, cooldown_steps=4)
            if qos else None)

    return FleetSupervisor(build, replicas, tp=1, clock=clock,
                           faults=faults, policy=policy,
                           rescale_ms=RESCALE_MS), cfg


def _drain(sup, clock, cfg, reqs, max_ticks=5000):
    """Tick the fleet until every request is terminal; returns the virtual
    timestamp of the replica-loss event (None if none fired)."""
    t_kill = None
    for _ in range(max_ticks):
        if all(r.done for r in reqs):
            break
        before = len([r for r in sup.replicas if not r.alive])
        sup.tick()
        if t_kill is None and \
                len([r for r in sup.replicas if not r.alive]) > before:
            t_kill = clock()   # rescale latency already charged this tick
        clock.advance(_tick_cost_s(cfg, [r.engine for r in sup.live]))
    assert all(r.done for r in reqs), "elastic scenario failed to drain"
    return t_kill


def _kill_scenario(*, replicas, slots, n_req, frames, kill_tick, qos=True):
    clock = VirtualClock()
    faults = FaultPlan(events=[FaultEvent(tick=kill_tick,
                                          kind="replica_loss", slot=1,
                                          target="replica")])
    sup, cfg = _fleet(replicas=replicas, slots=slots, faults=faults,
                      clock=clock, qos=qos)
    clips = [make_clip(frames, cfg.frame, q=cfg.q, seed=100 + i)
             for i in range(n_req)]
    t0 = clock()
    reqs = [sup.submit(c) for c in clips]
    t_kill = _drain(sup, clock, cfg, reqs)
    t_end = clock()
    return sup, cfg, clips, reqs, (t0, t_kill, t_end)


def _stochastic_run(seed, *, replicas, slots, n_req, frames):
    clock = VirtualClock()
    faults = FaultPlan(FaultSpec(replica_loss=0.04), seed=seed)
    sup, cfg = _fleet(replicas=replicas, slots=slots, faults=faults,
                      clock=clock)
    clips = [make_clip(frames, cfg.frame, q=cfg.q, seed=200 + i)
             for i in range(n_req)]
    reqs = [sup.submit(c) for c in clips]
    _drain(sup, clock, cfg, reqs)
    return sup, reqs


def _clean_reference(clips, *, slots):
    """Same clips, one engine, no faults: the payload oracle."""
    eng = StreamServeEngine(slots=slots, guards=GuardConfig(),
                            clock=VirtualClock())
    reqs = [eng.submit(c) for c in clips]
    for _ in range(5000):
        if all(r.done for r in reqs):
            break
        eng.tick()
    return [_payload_key(r) for r in reqs]


def _collective_bytes() -> tuple:
    """(ring_total, f32_total) wire bytes of one sharded smoke-LM decode
    step at tp=2.  Needs 2 devices — falls back to a subprocess with the
    host-device-count flag when the parent runs single-device."""
    import jax
    if len(jax.devices()) >= 2:
        from repro.serve.sharded import lm_decode_collective_bytes
        ring = lm_decode_collective_bytes(tp=2, ring=True)["total"]
        f32 = lm_decode_collective_bytes(tp=2, ring=False)["total"]
        return ring, f32
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=2'\n"
        "from repro.serve.sharded import lm_decode_collective_bytes as f\n"
        "print('RING', f(tp=2, ring=True)['total'])\n"
        "print('F32', f(tp=2, ring=False)['total'])\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    vals = {}
    for line in r.stdout.splitlines():
        parts = line.split()
        if len(parts) == 2 and parts[0] in ("RING", "F32"):
            vals[parts[0]] = float(parts[1])
    assert "RING" in vals and "F32" in vals, r.stderr[-2000:]
    return vals["RING"], vals["F32"]


def rows():
    out = []
    replicas = 2 if _TINY else 3
    n_req, frames, slots = (8, 4, 2) if _TINY else (18, 6, 2)
    # kill after the first admission wave completes, while later waves are
    # mid-decode: the event must interrupt live work AND leave completions
    # on both sides of it
    kill_tick = frames + 2

    # ---- kill one replica mid-serve -----------------------------------
    sup, cfg, clips, reqs, (t0, t_kill, t_end) = _kill_scenario(
        replicas=replicas, slots=slots, n_req=n_req, frames=frames,
        kill_tick=kill_tick)
    assert t_kill is not None, "scripted replica loss never fired"
    window = RESCALE_MS / 1e3   # the rescale + first-recovery window
    ok = [r for r in reqs if r.status == "ok"]
    before = sum(1 for r in ok if r.t_done < t_kill - window)
    during = sum(1 for r in ok if t_kill - window <= r.t_done < t_kill)
    after = sum(1 for r in ok if r.t_done >= t_kill)
    gp_before = before / max(t_kill - window - t0, 1e-9)
    gp_during = during / window
    gp_after = after / max(t_end - t_kill, 1e-9)
    out.append(("elastic.fleet_goodput_before", 0.0, round(gp_before, 2)))
    out.append(("elastic.fleet_goodput_during", 0.0, round(gp_during, 2)))
    out.append(("elastic.fleet_goodput_after", 0.0, round(gp_after, 2)))
    out.append(("elastic.fleet_replicas", 0.0,
                f"{replicas}->{len(sup.live)}"))
    out.append(("elastic.fleet_mix", 0.0, _mix(_statuses(reqs))))
    assert before > 0, "no completions before the kill — move it later"
    assert after > 0, "no completions on the survivor mesh"

    # survivors degrade before they shed: brownout rungs fleet-wide
    rungs = sum(int(r.engine.stats.c_brownout.value) for r in sup.replicas)
    out.append(("elastic.fleet_brownout_rungs", 0.0, rungs))

    # ---- exactly-once accounting + payload integrity -------------------
    # integrity runs at the exact rung (qos=False): the brownout ladder
    # above produces *approximate* payloads by design, so the bit-identity
    # oracle only applies to an exact-serving fleet
    sup_x, cfg_x, clips_x, reqs_x, _t = _kill_scenario(
        replicas=replicas, slots=slots, n_req=n_req, frames=frames,
        kill_tick=kill_tick, qos=False)
    done = sup_x.done
    rids = [r.rid for r in done]
    lost = len(reqs_x) - len(done)
    dup = len(rids) - len(set(rids))
    short = sum(1 for r in reqs_x
                if r.status == "ok" and len(r.out) != frames)
    out.append(("elastic.fleet_accounting", 0.0,
                f"lost={lost},dup={dup},short={short}"))
    assert lost == 0 and dup == 0 and short == 0, (lost, dup, short)
    ref = _clean_reference(clips_x, slots=slots)
    corrupt = sum(1 for r, k in zip(reqs_x, ref)
                  if r.status == "ok" and _payload_key(r) != k)
    out.append(("elastic.fleet_corrupt_payloads", 0.0, corrupt))
    assert corrupt == 0, (
        f"{corrupt} fleet payloads diverged from the clean reference")

    # ---- the survivor mesh plan (and the injected latency) -------------
    plan = sup.rescales[-1]
    out.append(("elastic.rescale_plan", 0.0,
                f"data={plan.data},model={plan.model},"
                f"idle={plan.idle_devices}"))
    out.append(("elastic.rescale_ms", 0.0, RESCALE_MS))

    # ---- ragged survivor counts never crash the recovery path ----------
    ragged = plan_rescale(7, target_global_batch=64, tp=4)
    out.append(("elastic.ragged_plan", 0.0,
                f"devices=7,tp=4,data={ragged.data},model={ragged.model},"
                f"idle={ragged.idle_devices}"))
    assert ragged.pods * ragged.data * ragged.model \
        + ragged.idle_devices == 7

    # ---- determinism: same seed => same kills, trace, bits -------------
    seed = 23
    sup_a, reqs_a = _stochastic_run(seed, replicas=replicas, slots=slots,
                                    n_req=n_req, frames=frames)
    sup_b, reqs_b = _stochastic_run(seed, replicas=replicas, slots=slots,
                                    n_req=n_req, frames=frames)
    identical = (
        [(e.tick, e.kind, e.slot) for e in sup_a.faults.injected]
        == [(e.tick, e.kind, e.slot) for e in sup_b.faults.injected]
        and sup_a.resil_log == sup_b.resil_log
        and [(r.status, _payload_key(r)) for r in reqs_a]
        == [(r.status, _payload_key(r)) for r in reqs_b])
    out.append(("elastic.determinism", 0.0,
                "identical" if identical else "DIVERGED"))
    assert identical, "same loss seed diverged (schedule/trace/payloads)"

    # ---- decode-step collective bytes within the compressed budget -----
    ring, f32 = _collective_bytes()
    out.append(("elastic.decode_collective_bytes", 0.0,
                f"ring={int(ring)},f32={int(f32)}"))
    assert 0 < ring <= 0.5 * f32, (
        f"int8 ring decode bytes {ring} exceed half the f32 budget {f32}")
    return out
