"""GEMM-side perf trajectory: quantize-once weight residency x fused
epilogues on the gated-MLP hot-path shape (DESIGN.md §9).

A/B grid (prepack on/off x fused on/off), all timed through the axqmm Pallas
kernels (interpret mode on CPU — the relative ordering is the claim there;
TPU runs the compiled kernels):

  fly_unfused     the seed cost model: three on-the-fly GEMM calls
                  (weights re-quantized+transposed per call), gate applied
                  between HBM roundtrips, residual added outside
  fly_fused       fused gated kernel + fused residual epilogue, but weights
                  still quantized per call
  packed_unfused  prepacked weights, three separate kernel calls
  packed_fused    the PR 4 serve path: prepacked weights + fused gated
                  kernel + residual epilogue — per-call work is activation
                  quantization only

``prepack_us`` is the one-time load-cost the residency layer moves out of
the steady-state loop.  The module asserts packed_fused strictly beats
fly_unfused — the committed BENCH_gemm.json row pair is the regression
anchor for the GEMM trajectory.
"""
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels.axqmm import axqmm, axqmm_gated, axqmm_gated_packed, axqmm_packed
from repro.kernels.qstore import prepack_weight

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def _time(f, reps: int = 5) -> float:
    f().block_until_ready()              # warmup/compile outside the window
    t0 = time.perf_counter()
    for _ in range(reps):
        f().block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    M, d, d_ff = (64, 256, 512) if _TINY else (128, 512, 1024)
    blk = 256
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (M, d), jnp.float32)
    wu = jax.random.normal(jax.random.fold_in(k, 1), (d, d_ff), jnp.float32)
    wg = jax.random.normal(jax.random.fold_in(k, 2), (d, d_ff), jnp.float32)
    wd = jax.random.normal(jax.random.fold_in(k, 3), (d_ff, d), jnp.float32)
    res = jax.random.normal(jax.random.fold_in(k, 4), (M, d), jnp.float32)

    t0 = time.perf_counter()
    pu, pg, pd_ = (prepack_weight(wu, blk), prepack_weight(wg, blk),
                   prepack_weight(wd, blk))
    jax.block_until_ready((pu, pg, pd_))
    prepack_us = (time.perf_counter() - t0) * 1e6

    @jax.jit
    def fly_unfused(x, wu, wg, wd, res):
        up = axqmm(x, wu, block=blk)
        gate = axqmm(x, wg, block=blk)
        h = jax.nn.silu(gate) * up
        return axqmm(h, wd, block=blk) + res

    @jax.jit
    def fly_fused(x, wu, wg, wd, res):
        h = axqmm_gated(x, wu, wg, block=blk)
        return axqmm(h, wd, block=blk, residual=res)

    @jax.jit
    def packed_unfused(x, res):
        up = axqmm_packed(x, pu)
        gate = axqmm_packed(x, pg)
        h = jax.nn.silu(gate) * up
        return axqmm_packed(h, pd_) + res

    @jax.jit
    def packed_fused(x, res):
        h = axqmm_gated_packed(x, pu, pg)
        return axqmm_packed(h, pd_, residual=res)

    us = {
        "fly_unfused": _time(lambda: fly_unfused(x, wu, wg, wd, res)),
        "fly_fused": _time(lambda: fly_fused(x, wu, wg, wd, res)),
        "packed_unfused": _time(lambda: packed_unfused(x, res)),
        "packed_fused": _time(lambda: packed_fused(x, res)),
    }
    assert us["packed_fused"] < us["fly_unfused"], (
        "prepacked+fused must beat the on-the-fly three-call path", us)
    shape = f"M{M} d{d} dff{d_ff} b{blk}"
    out = [(f"gemm.mlp_{name}_us", round(v, 0),
            shape if name == "fly_unfused"
            else f"{us['fly_unfused'] / v:.2f}x vs fly_unfused")
           for name, v in us.items()]
    out.append(("gemm.prepack_us", round(prepack_us, 0),
                "one-time load cost (quantize-once)"))
    return out
