"""Ch. 7 (Tables 7.6/7.7, Figs. 7.11-7.12): approximate CNN accelerators.
Trains a small CNN on a synthetic 4-class task (exact fp32), then runs
inference through the approximation dispatch (conv as im2col x approx_matmul)
at several configurations — reproducing the 0-5% accuracy-loss claim and the
MAx-DNN fine-grained per-layer exploration."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.kernels.ops import approx_matmul

# ---------------------------------------------------------------- dataset


def make_data(n, key):
    """16x16 images; class = quadrant containing the bright blob."""
    ks = jax.random.split(key, 4)
    labels = jax.random.randint(ks[0], (n,), 0, 4)
    base = 0.9 * jax.random.normal(ks[1], (n, 16, 16))
    # jittered blob centers + distractor blob -> non-trivial task (~90% acc)
    jit = jax.random.randint(ks[2], (2, n), -2, 3)
    cy = (labels // 2) * 8 + 4 + jit[0]
    cx = (labels % 2) * 8 + 4 + jit[1]
    yy, xx = jnp.mgrid[0:16, 0:16]
    blob = jnp.exp(-(((yy[None] - cy[:, None, None]) ** 2
                      + (xx[None] - cx[:, None, None]) ** 2) / 5.0))
    dcy = jax.random.randint(ks[3], (n,), 0, 16)
    dist = jnp.exp(-(((yy[None] - dcy[:, None, None]) ** 2
                      + (xx[None] - dcy[:, None, None]) ** 2) / 3.0))
    return (base + 1.3 * blob + 0.9 * dist)[..., None], labels


# ------------------------------------------------------------------ model


def _im2col(x, k=3):
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, dy:dy + H, dx:dx + W, :] for dy in range(k) for dx in range(k)]
    return jnp.concatenate(cols, axis=-1)  # (B,H,W,k*k*C)


def conv_apply(w, x, policy, path):
    cols = _im2col(x)
    B, H, W, D = cols.shape
    y = approx_matmul(cols.reshape(-1, D), w, policy.spec_for(path))
    return y.reshape(B, H, W, -1)


def init_cnn(key):
    ks = jax.random.split(key, 4)
    g = jax.nn.initializers.he_normal()
    return {
        "c1": g(ks[0], (9 * 1, 16), jnp.float32),
        "c2": g(ks[1], (9 * 16, 32), jnp.float32),
        "fc1": g(ks[2], (4 * 4 * 32, 64), jnp.float32),
        "fc2": g(ks[3], (64, 4), jnp.float32),
    }


def forward(params, x, policy):
    h = jax.nn.relu(conv_apply(params["c1"], x, policy, "c1"))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(conv_apply(params["c2"], h, policy, "c2"))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(approx_matmul(h, params["fc1"], policy.spec_for("fc1")))
    return approx_matmul(h, params["fc2"], policy.spec_for("fc2"))


def accuracy(params, x, y, policy):
    logits = forward(params, x, policy)
    return float((jnp.argmax(logits, -1) == y).mean())


POLICIES = {
    "exact": ApproxPolicy(),
    "axq8": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ, ebits=8, block=64)),
    "axq6": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ, ebits=6, block=64)),
    "axq4": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ, ebits=4, block=64)),
    "axq3": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ, ebits=3, block=64)),
    "pr_p2r4": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.PR_EMUL, p=2, r=4)),
    "pr_p1r2": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.PR_EMUL, p=1, r=2)),
    "rad16": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.RAD_EMUL, k=4)),
    "pow2_w": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.POW2_W)),
    # MAx-DNN fine-grained: first conv exact, rest aggressive
    "maxdnn_mixed": ApproxPolicy(rules=[
        (r"c1", ApproxSpec()),
        (r".*", ApproxSpec(mode=ApproxMode.AXQ, ebits=5, block=64)),
    ]),
}


def rows():
    out = []
    key = jax.random.PRNGKey(0)
    xtr, ytr = make_data(2048, key)
    xte, yte = make_data(1024, jax.random.fold_in(key, 1))
    params = init_cnn(jax.random.fold_in(key, 2))
    exact = ApproxPolicy()

    def loss_fn(p, x, y):
        lg = forward(p, x, exact)
        return -jnp.take_along_axis(jax.nn.log_softmax(lg), y[:, None], 1).mean()

    @jax.jit
    def step(p, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    t0 = time.perf_counter()
    for i in range(120):
        s = (i * 256) % 2048
        params, l = step(params, xtr[s:s + 256], ytr[s:s + 256])
    train_us = (time.perf_counter() - t0) * 1e6
    base = accuracy(params, xte, yte, exact)
    out.append(("cnn.exact_acc", round(train_us, 0), round(base, 4)))
    for name, pol in POLICIES.items():
        if name == "exact":
            continue
        acc = accuracy(params, xte, yte, pol)
        out.append((f"cnn.{name}_acc_drop_pct", 0.0,
                    round(100 * (base - acc), 2)))
    return out
