"""Ch. 6 (Figs. 6.4-6.6): cooperative-approximation design space + Pareto
front resolution."""
from repro.core import pareto


def rows():
    pts = pareto.explore(n=16, num_samples=1 << 15)
    front = pareto.front(pts)
    out = [
        ("pareto.space_size", 0.0, len(pts)),
        ("pareto.front_size", 0.0, len(front)),
        ("pareto.front_families", 0.0,
         "+".join(sorted({p.fam for p in front}))),
    ]
    roup_on_front = sum(1 for p in front if p.fam == "ROUP")
    out.append(("pareto.roup_points_on_front", 0.0, roup_on_front))
    for budget in (0.005, 0.01, 0.02):
        sel = pareto.best_under_error(pts, budget)
        base = [p for p in pts if p.fam == "CMB"][0]
        gain = 100 * (1 - sel.energy / base.energy)
        out.append((f"pareto.best_at_mred{budget}", 0.0,
                    f"{sel.name}:energy_gain={gain:.1f}%"))
    return out
