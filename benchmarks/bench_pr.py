"""Ch. 5 (Tables 5.2-5.4): AxFXU perforation+rounding fixed-point errors and
AxFPU floating-point errors (fp32 via the int64-exact numpy mirror)."""
import numpy as np

from repro.core import area_model, axmult, error_analysis as ea


def rows():
    out = []
    n = 16
    base_en = area_model.energy_proxy("CMB", n)
    for p, r in [(1, 0), (2, 0), (0, 4), (0, 8), (1, 4), (2, 4), (2, 8), (3, 8)]:
        rep = ea.evaluate_sampled(
            lambda a, b: axmult.np_mult_pr(a, b, n=n, p=p, r=r), n, num=1 << 18)
        gain = 100 * (1 - area_model.energy_proxy("PR", n, p=p, r=r) / base_en)
        out.append((f"pr.AxFXU_p{p}r{r}_mred_pct", 0.0, round(100 * rep.mred, 4)))
        out.append((f"pr.AxFXU_p{p}r{r}_energy_gain_pct", 0.0, round(gain, 1)))
    # AxFPU fp32 (24-bit significand): perforation/rounding on the mantissa
    for p, r in [(0, 0), (2, 8), (4, 12), (6, 16)]:
        rep = ea.evaluate_float(
            lambda a, b: axmult.np_axfpu_multiply(a, b, p=p, r=r), num=1 << 17)
        out.append((f"pr.AxFPU32_p{p}r{r}_mred", 0.0, f"{rep.mred:.3e}"))
    return out
