"""Chaos scenarios for the resilient serving stack (repro.resil, ISSUE 8).

The claims under test, all on the stream workload with a deterministic
:class:`~repro.resil.policy.VirtualClock` (virtual tick cost = BASE_TICK_MS
x the modeled per-rung cost from ``tune.autotune.vector_cost``, so rung
moves change serving speed the way they would on the paper's hardware —
CPU emulation runs identical work per degree, wall clock can't show it):

* **overload burst** — the same 4x-capacity burst under two policies at an
  equal deadline: shed-only (exact arithmetic, queue cap sheds overflow)
  vs brownout (QoS forced down the approximation ladder before shedding).
  Rows carry goodput (in-deadline completions per virtual second) and the
  terminal-status mix; the gate's headline invariant is
  ``brownout_goodput >= shed_goodput`` — graceful degradation dominates
  availability-by-shedding at equal overload.
* **fault storm** — seeded SEU/NaN/spike/drop storm through guards +
  quarantine + scrubbing.  Every surviving payload is compared against a
  clean run: ``chaos.storm_corrupt_payloads`` MUST be 0 (no injected fault
  ever reaches an emitted payload), and the accounting row proves zero
  lost / duplicated / short requests.
* **mixed-deadline traffic** — tight- and loose-deadline classes under the
  same faulty overload; the loose class must miss no more than the tight.
* **determinism** — the storm re-run at the same seed must reproduce the
  injected-fault sequence, recovery trace, and every payload bit-for-bit.

REPRO_BENCH_TINY=1 shrinks bursts/clips for the CI chaos-smoke job.
Committed record: benchmarks/BENCH_chaos.json (full-shape run).
"""
import os

import numpy as np

from repro.core.dynamic import QoSController
from repro.resil import (FaultPlan, FaultSpec, GuardConfig, ServePolicy,
                         VirtualClock)
from repro.serve.stream import StreamAdapter, StreamServeEngine, make_clip
from repro.tune import vector_cost

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"

#: virtual cost of one engine tick at the exact rung (ms); deeper rungs
#: scale by vector_cost (< 1), so brownout genuinely drains faster
BASE_TICK_MS = 2.0
_LADDER_EBITS = (8, 7, 6, 5, 4)


def _ladder(cfg):
    return [{"degrees": [e] * (cfg.n_layers + 1)} for e in _LADDER_EBITS]


def _tick_cost_s(cfg, eng) -> float:
    """Virtual seconds one tick costs at the engine's current rung."""
    if eng.stats.degree_history:
        degrees = list(eng.stats.degree_history[-1][1])
    else:
        degrees = [8] * (cfg.n_layers + 1)
    return BASE_TICK_MS * vector_cost(cfg, degrees) / 1e3


def _drain(eng, clock, cfg, reqs, max_ticks=5000) -> float:
    """Tick until every request is terminal; returns the virtual wall."""
    t0 = clock()
    for _ in range(max_ticks):
        if all(r.done for r in reqs):
            break
        eng.tick()
        clock.advance(_tick_cost_s(cfg, eng))
    assert all(r.done for r in reqs), "chaos scenario failed to drain"
    return clock() - t0


def _statuses(reqs) -> dict:
    out: dict = {}
    for r in reqs:
        out[r.status] = out.get(r.status, 0) + 1
    return out


def _mix(st: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(st.items()))


def _accounting(eng, reqs, expect_frames=None) -> str:
    """lost / duplicated / short-payload accounting (all must be 0)."""
    rids = [r.rid for r in eng.done]
    lost = len(reqs) - len(eng.done)
    dup = len(rids) - len(set(rids))
    short = sum(1 for r in reqs
                if r.status == "ok" and expect_frames is not None
                and len(r.out) != expect_frames)
    return f"lost={lost},dup={dup},short={short}"


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


def _overload(brownout: bool, *, n_req, frames, slots, deadline_ms,
              max_queue):
    adapter = StreamAdapter()
    cfg = adapter.cfg
    clock = VirtualClock()
    qos = QoSController(ladder=_ladder(cfg), low_water=0.25, high_water=0.75,
                        cooldown_steps=4) if brownout else None
    policy = ServePolicy(deadline_ms=deadline_ms, max_queue=max_queue,
                         brownout=brownout)
    eng = StreamServeEngine(adapter, slots=slots, qos=qos,
                            guards=GuardConfig(), policy=policy, clock=clock)
    clip = make_clip(frames, cfg.frame, q=cfg.q, seed=0)
    reqs = [eng.submit(clip) for _ in range(n_req)]   # one 4x-capacity burst
    wall = _drain(eng, clock, cfg, reqs)
    st = _statuses(reqs)
    goodput = st.get("ok", 0) / max(wall, 1e-9)
    return eng, st, goodput


def _storm(seed: int, *, n_req, frames, slots):
    adapter = StreamAdapter()
    cfg = adapter.cfg
    clock = VirtualClock()
    spec = FaultSpec(seu_state=0.08, seu_param=0.05, nan=0.08, spike=0.03,
                     drop=0.03)
    eng = StreamServeEngine(adapter, slots=slots,
                            faults=FaultPlan(spec, seed=seed),
                            guards=GuardConfig(),
                            policy=ServePolicy(max_retries=6, backoff_ms=0.5),
                            clock=clock)
    clips = [make_clip(frames, cfg.frame, q=cfg.q, seed=i)
             for i in range(n_req)]
    reqs = [eng.submit(c) for c in clips]
    _drain(eng, clock, cfg, reqs)
    return eng, reqs, clips


def _clean_reference(clips, *, slots):
    """The same clips through a guarded engine with NO faults — the oracle
    payloads a stormed run must reproduce bit-for-bit."""
    adapter = StreamAdapter()
    eng = StreamServeEngine(adapter, slots=slots, guards=GuardConfig(),
                            clock=VirtualClock())
    reqs = [eng.submit(c) for c in clips]
    for _ in range(5000):
        if all(r.done for r in reqs):
            break
        eng.tick()
    return [tuple(np.asarray(f).tobytes() for f in r.out) for r in reqs]


def _payload_key(req):
    return tuple(np.asarray(f).tobytes() for f in req.out)


def rows():
    out = []
    n_req, frames, slots = (8, 3, 2) if _TINY else (16, 6, 4)

    # ---- overload burst: brownout vs shed-only at equal load ----------
    deadline_ms, max_queue = 40.0, slots
    e_shed, st_shed, gp_shed = _overload(
        False, n_req=n_req, frames=frames, slots=slots,
        deadline_ms=deadline_ms, max_queue=max_queue)
    e_brown, st_brown, gp_brown = _overload(
        True, n_req=n_req, frames=frames, slots=slots,
        deadline_ms=deadline_ms, max_queue=max_queue)
    out.append(("chaos.overload_shed_goodput", 0.0, round(gp_shed, 2)))
    out.append(("chaos.overload_shed_mix", 0.0, _mix(st_shed)))
    out.append(("chaos.overload_brownout_goodput", 0.0, round(gp_brown, 2)))
    out.append(("chaos.overload_brownout_mix", 0.0, _mix(st_brown)))
    out.append(("chaos.overload_brownout_rungs", 0.0,
                int(e_brown.stats.c_brownout.value)))
    gain = gp_brown / max(gp_shed, 1e-9)
    out.append(("chaos.overload_brownout_gain", 0.0, f"{gain:.2f}x"))
    assert gp_brown >= gp_shed, (
        f"brownout goodput {gp_brown:.2f}/s < shed-only {gp_shed:.2f}/s — "
        "graceful degradation stopped paying for itself")
    acc = (f"{_accounting(e_shed, list(e_shed.done))};"
           f"{_accounting(e_brown, list(e_brown.done))}")
    out.append(("chaos.overload_accounting", 0.0, acc))

    # ---- fault storm through guards/quarantine/scrub ------------------
    storm_seed = 20
    e_storm, storm_reqs, clips = _storm(storm_seed, n_req=n_req,
                                        frames=frames, slots=slots)
    injected: dict = {}
    for ev in e_storm.faults.injected:
        injected[ev.kind] = injected.get(ev.kind, 0) + 1
    out.append(("chaos.storm_injected", 0.0, _mix(injected)))
    trips = int(e_storm.stats.c_guard_trips.labels(reason="slot").value)
    recovery = (f"trips={trips},"
                f"retries={int(e_storm.stats.c_retries.value)},"
                f"failed={int(e_storm.stats.c_failed.value)},"
                f"scrubs={int(e_storm.stats.c_scrubs.value)}")
    out.append(("chaos.storm_recovery", 0.0, recovery))
    assert sum(injected.values()) >= 1 and trips >= 1, (
        f"fault storm was vacuous (injected={injected}, trips={trips}) — "
        "raise the rates or rethink the seed")
    ref = _clean_reference(clips, slots=slots)
    corrupt = sum(1 for r, k in zip(storm_reqs, ref)
                  if r.status == "ok" and _payload_key(r) != k)
    out.append(("chaos.storm_corrupt_payloads", 0.0, corrupt))
    out.append(("chaos.storm_mix", 0.0, _mix(_statuses(storm_reqs))))
    out.append(("chaos.storm_accounting", 0.0,
                _accounting(e_storm, storm_reqs, expect_frames=frames)))
    assert corrupt == 0, (
        f"{corrupt} stormed payloads diverged from the clean reference — "
        "an injected fault reached an emitted payload")

    # ---- mixed-deadline traffic under the same faults ------------------
    # no queue cap: misses here come from deadline enforcement alone, so
    # the tight class absorbs every miss and the loose class rides it out
    adapter = StreamAdapter()
    cfg = adapter.cfg
    clock = VirtualClock()
    eng = StreamServeEngine(
        adapter, slots=slots,
        faults=FaultPlan(FaultSpec(nan=0.05, drop=0.03), seed=storm_seed),
        guards=GuardConfig(),
        policy=ServePolicy(backoff_ms=0.5),
        clock=clock)
    clip = make_clip(frames, cfg.frame, q=cfg.q, seed=0)
    tight, loose = [], []
    for i in range(n_req):
        tight.append(eng.submit(clip, deadline_ms=30.0))
        loose.append(eng.submit(clip, deadline_ms=2000.0))
    _drain(eng, clock, cfg, tight + loose)
    miss = {name: sum(1 for r in rs if r.status != "ok") / len(rs)
            for name, rs in (("tight", tight), ("loose", loose))}
    out.append(("chaos.mixed_deadline_miss", 0.0,
                f"tight={miss['tight']:.3f},loose={miss['loose']:.3f}"))
    out.append(("chaos.mixed_accounting", 0.0,
                _accounting(eng, tight + loose)))
    assert miss["loose"] <= miss["tight"], (
        f"loose-deadline class missed more than tight ({miss}) — deadline "
        "enforcement ordering regressed")

    # ---- determinism: same seed => same faults, same recovery, same bits
    eng2, reqs2, _ = _storm(storm_seed, n_req=n_req, frames=frames,
                            slots=slots)
    identical = (
        e_storm.faults is not eng2.faults
        and list(eng2.faults.injected) == list(e_storm.faults.injected)
        and eng2.resil_log == e_storm.resil_log
        and [(r.status, _payload_key(r)) for r in reqs2]
        == [(r.status, _payload_key(r)) for r in storm_reqs])
    out.append(("chaos.determinism", 0.0,
                "identical" if identical else "DIVERGED"))
    assert identical, "same fault seed diverged (schedule/trace/payloads)"
    return out
