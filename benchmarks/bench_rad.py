"""Ch. 4 (Table 4.6, Figs. 4.4-4.6): RAD multiplier error + resource table.
Error metrics are EXACT (operand-marginal enumeration, the paper's own
accelerated method); area/energy from the unit-gate model."""
import time

import numpy as np

from repro.core import area_model, error_analysis as ea


def rows():
    out = []
    n = 16
    base_area = area_model.area_cmb(n)
    base_en = area_model.energy_proxy("CMB", n)
    for k in (4, 6, 8, 10):
        t0 = time.perf_counter()
        rep = ea.rad_operand_marginal(n, k)
        us = (time.perf_counter() - t0) * 1e6
        area_gain = 100 * (1 - area_model.area_rad(n, k) / base_area)
        en_gain = 100 * (1 - area_model.energy_proxy("RAD", n, k=k) / base_en)
        out.append((f"rad.RAD{2**k}_mred_pct", round(us, 1), round(100 * rep.mred, 4)))
        out.append((f"rad.RAD{2**k}_pred2", 0.0, round(rep.pred2, 4)))
        out.append((f"rad.RAD{2**k}_bias", 0.0, f"{rep.mean_err:+.2e}"))
        out.append((f"rad.RAD{2**k}_area_gain_pct", 0.0, round(area_gain, 1)))
        out.append((f"rad.RAD{2**k}_energy_gain_pct", 0.0, round(en_gain, 1)))
    # scaled bit-width (Fig. 4.7): error stays ~constant as n grows
    # (wide operands sampled -- enumeration is 2^n)
    from repro.core import encodings as enc

    rng = np.random.default_rng(0)
    for nn in (16, 24, 32):
        b = rng.integers(-(1 << (nn - 1)), 1 << (nn - 1), 1 << 20)
        bh = enc.np_rad_encode(b, nn, 8)
        nz = b != 0
        mred = float(np.mean(np.abs((bh[nz] - b[nz]) / b[nz].astype(np.float64))))
        out.append((f"rad.RAD256_n{nn}_mred_pct", 0.0, round(100 * mred, 4)))
    return out
