"""Ch. 7 (Tables 7.1/7.2/7.5, Fig. 7.8): approximate DSP accelerators —
1D FIR filtering and 2D Gaussian blur with the paper's multipliers, SNR/PSNR
vs the exact fixed-point pipeline.  The PR path runs through the
``kernels.dispatch`` fir/conv2d routes (the same router the serve engine
uses), so the bench exercises the accelerator datapath end to end."""
import time

import numpy as np

from repro.core import encodings as enc
from repro.core.error_analysis import psnr_db, snr_db
from repro.kernels import dispatch as kdispatch


def _fir_exact(sig_q, taps_q):
    acc = np.zeros(len(sig_q) - len(taps_q), np.int64)
    for i, t in enumerate(taps_q):
        acc += t * sig_q[i:i + len(acc)]
    return acc


def rows():
    out = []
    rng = np.random.default_rng(0)
    # ---- FIR (16-bit fixed point, 32 taps) ----
    t = np.arange(4096)
    sig = (np.sin(0.02 * t) + 0.5 * np.sin(0.31 * t)
           + 0.1 * rng.standard_normal(len(t)))
    sig_q = np.clip(np.round(sig / np.abs(sig).max() * 2**14), -2**15, 2**15 - 1
                    ).astype(np.int32)
    taps = np.hamming(32)
    taps_q = np.round(taps / np.abs(taps).max() * 2**14).astype(np.int32)
    ref = _fir_exact(sig_q, taps_q)
    for p, r in [(1, 4), (2, 8), (3, 8)]:
        t0 = time.perf_counter()
        y = kdispatch.fir(sig_q, taps_q, p=p, r=r)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"dsp.fir_pr_p{p}r{r}_snr_db", round(us, 0),
                    round(snr_db(ref, y), 1)))
    # RAD FIR (taps approximately encoded — weight-stationary accelerator)
    for k in (6, 8):
        taps_rad = enc.np_rad_encode(taps_q, 16, k)
        y = _fir_exact(sig_q, taps_rad)
        out.append((f"dsp.fir_rad{2**k}_snr_db", 0.0, round(snr_db(ref, y), 1)))

    # ---- Gaussian blur (8-bit image, 5x5 kernel) ----
    img = (rng.random((128, 128)) * 255).astype(np.int32)
    img[32:96, 32:96] += 60  # structure
    g1 = np.array([1, 4, 6, 4, 1], np.int64)
    g2 = np.outer(g1, g1).astype(np.int32)  # sum 256 == 2**8

    def blur(p, r):
        y = kdispatch.conv2d(img[None], g2, p=p, r=r, shift=8, pad="edge")
        return np.clip(np.asarray(y)[0], 0, 255)

    ref_img = blur(0, 0)
    for p, r in [(1, 2), (2, 4)]:
        approx = blur(p, r)
        out.append((f"dsp.blur_pr_p{p}r{r}_psnr_db", 0.0,
                    round(psnr_db(ref_img, approx, peak=255), 1)))
    return out
