"""Ch. 7 (Tables 7.1/7.2/7.5, Fig. 7.8): approximate DSP accelerators —
1D FIR filtering and 2D Gaussian blur with the paper's multipliers, SNR/PSNR
vs the exact fixed-point pipeline.  The PR path runs through the
kernels/axmult_elem Pallas kernel (the accelerator datapath)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings as enc
from repro.kernels.axmult_elem import pr_multiply


def _snr(ref, x):
    err = ref.astype(np.float64) - x.astype(np.float64)
    return 10 * np.log10((ref.astype(np.float64) ** 2).mean()
                         / np.maximum((err ** 2).mean(), 1e-30))


def _fir_exact(sig_q, taps_q):
    acc = np.zeros(len(sig_q) - len(taps_q), np.int64)
    for i, t in enumerate(taps_q):
        acc += t * sig_q[i:i + len(acc)]
    return acc


def _fir_pr(sig_q, taps_q, p, r):
    """All taps in one batched DyFXU call: operands stacked (taps, Lpad),
    tap rows broadcast against their shifted signal windows."""
    T = len(taps_q)
    L = len(sig_q) - T
    Lpad = ((L + 2047) // 2048) * 2048
    a = np.ascontiguousarray(np.broadcast_to(taps_q[:, None], (T, Lpad)))
    b = np.zeros((T, Lpad), np.int32)
    b[:, :L] = np.lib.stride_tricks.sliding_window_view(sig_q, L)[:T]
    prod = np.asarray(pr_multiply(jnp.asarray(a), jnp.asarray(b), p, r, n=16))
    return prod.astype(np.int64).sum(axis=0)[:L]


def rows():
    out = []
    rng = np.random.default_rng(0)
    # ---- FIR (16-bit fixed point, 32 taps) ----
    t = np.arange(4096)
    sig = (np.sin(0.02 * t) + 0.5 * np.sin(0.31 * t)
           + 0.1 * rng.standard_normal(len(t)))
    sig_q = np.clip(np.round(sig / np.abs(sig).max() * 2**14), -2**15, 2**15 - 1
                    ).astype(np.int32)
    taps = np.hamming(32)
    taps_q = np.round(taps / np.abs(taps).max() * 2**14).astype(np.int32)
    ref = _fir_exact(sig_q, taps_q)
    for p, r in [(1, 4), (2, 8), (3, 8)]:
        t0 = time.perf_counter()
        y = _fir_pr(sig_q, taps_q, p, r)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"dsp.fir_pr_p{p}r{r}_snr_db", round(us, 0),
                    round(_snr(ref, y), 1)))
    # RAD FIR (taps approximately encoded — weight-stationary accelerator)
    for k in (6, 8):
        taps_rad = enc.np_rad_encode(taps_q, 16, k)
        y = _fir_exact(sig_q, taps_rad)
        out.append((f"dsp.fir_rad{2**k}_snr_db", 0.0, round(_snr(ref, y), 1)))

    # ---- Gaussian blur (8-bit image, 5x5 kernel) ----
    img = (rng.random((128, 128)) * 255).astype(np.int32)
    img[32:96, 32:96] += 60  # structure
    g1 = np.array([1, 4, 6, 4, 1], np.int64)
    g2 = np.outer(g1, g1)  # sum 256
    def blur(mul):
        padded = np.pad(img, 2, mode="edge")
        acc = np.zeros_like(img, np.int64)
        for dy in range(5):
            for dx in range(5):
                w = int(g2[dy, dx])
                patch = padded[dy:dy + 128, dx:dx + 128]
                acc += mul(np.full_like(patch, w), patch)
        return np.clip(acc >> 8, 0, 255)

    ref_img = blur(lambda w, x: w.astype(np.int64) * x)
    for p, r in [(1, 2), (2, 4)]:
        approx = blur(lambda w, x: np.asarray(
            enc.np_perforate_operand(x, 16, p)) * enc.np_round_operand(w, r))
        mse = ((ref_img - approx) ** 2).mean()
        psnr = 10 * np.log10(255**2 / max(mse, 1e-12))
        out.append((f"dsp.blur_pr_p{p}r{r}_psnr_db", 0.0, round(psnr, 1)))
    return out
