"""Serving engine throughput/latency (continuous batching; smoke-scale model
on CPU — the decode dry-run cells carry the production-shape numbers)."""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def rows():
    cfg = get_config("tinyllama-1.1b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = []
    for slots in (2, 8):
        eng = ServeEngine(model, params, slots=slots, max_len=128)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for _ in range(12):
            eng.submit(rng.integers(0, cfg.vocab, 4), 16)
        done = eng.run_until_drained()
        dt = time.perf_counter() - t0
        tot = sum(len(r.out_tokens) for r in done)
        lat = [r.t_done - r.t_enqueue for r in done]
        out.append((f"serve.slots{slots}_tok_per_s", round(dt / tot * 1e6, 0),
                    round(tot / dt, 1)))
        out.append((f"serve.slots{slots}_p95_latency_ms", 0.0,
                    round(float(np.percentile(lat, 95)) * 1e3, 0)))
    return out
