"""Serving engine throughput/latency (continuous batching; smoke-scale model
on CPU — the decode dry-run cells carry the production-shape numbers).

Row convention (matches run.py header ``name,us_per_call,derived``): the
``us_per_call`` column is microseconds per *fused serve step* (one engine
tick over all slots), and ``derived`` is the quantity named by the row
suffix.  The fused prefill + serve step are compiled in a warmup drain
outside the timed window, so rows track steady-state serving.
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.metrics import summarize


def rows():
    cfg = get_config("tinyllama-1.1b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = []
    for slots in (2, 8):
        eng = ServeEngine(model, params, slots=slots, max_len=128)
        rng = np.random.default_rng(0)
        # warmup: compile fused prefill (per prompt length) + serve step
        for _ in range(2):
            eng.submit(rng.integers(0, cfg.vocab, 4), 4)
        eng.run_until_drained()
        steps0 = eng.stats.decode_steps
        pf0, dec0 = eng.stats.prefill_tokens, eng.stats.decode_tokens
        t0 = time.perf_counter()
        for _ in range(12):
            eng.submit(rng.integers(0, cfg.vocab, 4), 16)
        done = eng.run_until_drained()[2:]          # drop warmup requests
        dt = time.perf_counter() - t0
        steps = eng.stats.decode_steps - steps0
        s = summarize(done, eng.stats, wall_s=dt)
        us_per_step = round(dt / max(steps, 1) * 1e6, 1)
        out.append((f"serve.slots{slots}_gen_tok_per_s", us_per_step,
                    s["gen_tok_per_s"]))
        out.append((f"serve.slots{slots}_ttft_p95_ms", 0.0, s["ttft_p95_ms"]))
        out.append((f"serve.slots{slots}_tpot_p50_ms", 0.0, s["tpot_p50_ms"]))
        out.append((f"serve.slots{slots}_prefill_vs_decode_tok", 0.0,
                    f"{eng.stats.prefill_tokens - pf0}"
                    f"/{eng.stats.decode_tokens - dec0}"))
    return out
