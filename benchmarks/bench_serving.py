"""Serving engine throughput/latency (continuous batching; smoke-scale model
on CPU — the decode dry-run cells carry the production-shape numbers).

Row convention (matches run.py header ``name,us_per_call,derived``): the
``us_per_call`` column is microseconds per *fused serve step* (one engine
tick over all slots), and ``derived`` is the quantity named by the row
suffix.  The fused prefill + serve step are compiled in a warmup drain
outside the timed window, so rows track steady-state serving.

A/B over kernel backends: rows are emitted for the jnp (xla) path under the
PR 2 names (``serve.slots8_*`` — trajectory continuity) and for the Pallas
path (flash_decode fused step) as ``serve.pallas_slots8_*``.  On CPU the
Pallas numbers run the interpreter and measure correctness-path overhead,
not TPU speed.  Standalone: ``python -m benchmarks.bench_serving --kernels
both``.  REPRO_BENCH_TINY=1 shrinks the workload for the CI smoke job.
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.kernels import dispatch
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.metrics import summarize

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def _bench_one(model, cfg, params, backend: str, slots: int,
               requests: int, new_tokens: int):
    dispatch.set_backend(backend)
    try:
        eng = ServeEngine(model, params, slots=slots, max_len=128)
        rng = np.random.default_rng(0)
        # warmup: compile fused prefill (per prompt length) + serve step
        for _ in range(2):
            eng.submit(rng.integers(0, cfg.vocab, 4), 4)
        eng.run_until_drained()
        steps0 = eng.stats.decode_steps
        pf0, dec0 = eng.stats.prefill_tokens, eng.stats.decode_tokens
        t0 = time.perf_counter()
        for _ in range(requests):
            eng.submit(rng.integers(0, cfg.vocab, 4), new_tokens)
        done = eng.run_until_drained()[2:]          # drop warmup requests
        dt = time.perf_counter() - t0
        steps = eng.stats.decode_steps - steps0
        s = summarize(done, eng.stats, wall_s=dt)
        us_per_step = round(dt / max(steps, 1) * 1e6, 1)
        pre = "serve." if backend == "xla" else f"serve.{backend}_"
        return [
            (f"{pre}slots{slots}_gen_tok_per_s", us_per_step,
             s["gen_tok_per_s"]),
            (f"{pre}slots{slots}_ttft_p95_ms", 0.0, s["ttft_p95_ms"]),
            (f"{pre}slots{slots}_tpot_p50_ms", 0.0, s["tpot_p50_ms"]),
            (f"{pre}slots{slots}_prefill_vs_decode_tok", 0.0,
             f"{eng.stats.prefill_tokens - pf0}"
             f"/{eng.stats.decode_tokens - dec0}"),
        ]
    finally:
        dispatch.set_backend(None)


def rows(kernels=("xla", "pallas")):
    cfg = get_config("tinyllama-1.1b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots_list = (2,) if _TINY else (2, 8)
    requests = 6 if _TINY else 12
    new_tokens = 8 if _TINY else 16
    out = []
    for backend in kernels:
        for slots in slots_list:
            out += _bench_one(model, cfg, params, backend, slots,
                              requests, new_tokens)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", default="both",
                    choices=("xla", "pallas", "both"),
                    help="A/B the jnp decode path vs the fused flash_decode "
                         "kernel in one run")
    args = ap.parse_args()
    kernels = ("xla", "pallas") if args.kernels == "both" else (args.kernels,)
    print("name,us_per_call,derived")
    for row in rows(kernels):
        print(",".join(str(x) for x in row), flush=True)


if __name__ == "__main__":
    main()
