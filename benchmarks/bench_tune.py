"""Uniform-vs-planned approximation degree A/B (repro.tune, DESIGN.md §10).

The claim under test is the dissertation's (and the Leon et al. surveys'):
a *mixed per-layer* degree assignment found by calibration-driven search
dominates the *uniform global* degree on the quality-vs-cost front.  The
module tunes an ApproxPlan for the smoke LM on a fixed calibration batch,
measures every uniform assignment with the same prober, and emits both
tables plus the dominance verdict — and **asserts** that at least one
uniform rung is strictly dominated (a planned rung with lower modeled cost
at equal-or-better measured error), so a regression in the tuner or the
per-layer degree plumbing fails the bench.

Row convention (run.py header ``name,us_per_call,derived``): the
``us_per_call`` column is microseconds per measured configuration during
the search; quality rows carry ``err=..,cost=..`` in ``derived``.  Errors
are normalized RMS logit deviation vs exact arithmetic; costs are the
unit-gate energy proxy normalized to uniform-8 (autotune.vector_cost).
REPRO_BENCH_TINY=1 shrinks the calibration batch and grid for the CI smoke
job.  Committed record: benchmarks/BENCH_tune.json (full-shape run).
"""
import os

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.models.registry import concrete_batch
from repro.tune import ApproxPlan, build_plan, vector_cost
from repro.tune.autotune import _Prober
from repro.tune.plan import site_names

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
_ARCH = "tinyllama-1.1b-smoke"
_BLOCK = 64


def rows():
    cfg = get_config(_ARCH)
    policy = ApproxPlan(arch=cfg.name, sites=site_names(cfg), ladder=[],
                        block=_BLOCK).policy(dynamic=True)
    model = build_model(cfg, policy)
    params = model.init(jax.random.PRNGKey(0), tp=1)
    seq, batch = (16, 2) if _TINY else (32, 4)
    grid = (8, 7, 6) if _TINY else (8, 7, 6, 5, 4)
    calib = concrete_batch(cfg, seq, batch, key=jax.random.PRNGKey(7))
    # one prober shared with the search: the uniform rows below re-query
    # its error memo instead of re-running calibration forwards
    prober = _Prober(model, params, calib)
    plan = build_plan(model, params, calib, grid=grid, block=_BLOCK,
                      prober=prober)
    us_per_cfg = plan.meta["tune_seconds"] * 1e6 / plan.meta["visited"]
    out = [
        ("tune.search", us_per_cfg,
         f"{plan.meta['strategy']}:{plan.meta['visited']}cfgs"),
        ("tune.plan_rungs", 0.0, len(plan.ladder)),
    ]

    S = len(plan.sites)
    uniform = {}
    for e in grid:
        vec = [int(e)] * S
        uniform[e] = (prober.error(vec), vector_cost(cfg, vec))
        out.append((f"tune.uniform_e{e}", 0.0,
                    f"err={uniform[e][0]:.5f},cost={uniform[e][1]:.4f}"))
    for pt in plan.ladder:
        out.append((f"tune.{pt.name}", 0.0,
                    f"deg={'.'.join(map(str, pt.degrees))},"
                    f"err={pt.error:.5f},cost={pt.cost:.4f}"))

    # dominance: a planned rung with strictly lower cost at <= error
    verdicts = []
    for e, (ue, uc) in sorted(uniform.items()):
        doms = [pt for pt in plan.ladder if pt.cost < uc and pt.error <= ue]
        if doms:
            best = min(doms, key=lambda p: p.cost)
            verdicts.append(
                f"e{e}<{best.name}(cost-{100 * (1 - best.cost / uc):.1f}%"
                f",err-{100 * (1 - best.error / ue) if ue else 0.0:.1f}%)")
    out.append(("tune.dominated_uniform_rungs", 0.0,
                "+".join(verdicts) if verdicts else "none"))
    assert verdicts, (
        "planned ladder failed to dominate any uniform rung — per-layer "
        "tuning regressed (see tune.uniform_* / tune.rung_* rows)")
    return out
