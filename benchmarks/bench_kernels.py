"""Kernel-level timings + correctness envelopes (CPU interpret mode — TPU is
the target; numbers prove correctness, degree-scaling, and that the
skip grids actually skip, not TPU speed).

REPRO_BENCH_TINY=1 shrinks shapes for the CI smoke job.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import qmm_ref
from repro.kernels.axqmm import axqmm

_TINY = os.environ.get("REPRO_BENCH_TINY", "0") == "1"


def _time(f, reps: int = 3) -> float:
    def ready(y):
        (y[0] if isinstance(y, tuple) else y).block_until_ready()

    ready(f())  # warmup/compile outside the timed window
    t0 = time.perf_counter()
    for _ in range(reps):
        ready(f())
    return (time.perf_counter() - t0) / reps * 1e6


def _axqmm_rows():
    out = []
    k = jax.random.PRNGKey(0)
    M, K, N = (128, 512, 128) if _TINY else (256, 1024, 256)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    exact = x @ w
    for e in (8, 6, 4):
        f = jax.jit(lambda x, w, e=e: axqmm(x, w, ebits=e))
        f(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            y = f(x, w).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        rel = float(jnp.abs(y - exact).mean() / jnp.abs(exact).mean())
        out.append((f"kern.axqmm_e{e}_relerr", round(us, 0), f"{rel:.4f}"))
        yr = qmm_ref(x, w, block=512, ebits=e)
        out.append((f"kern.axqmm_e{e}_vs_ref_maxdiff", 0.0,
                    f"{float(jnp.abs(y-yr).max()):.2e}"))
    return out


def _flash_rows():
    """Skip-grid block-step accounting + timings: the in-kernel counter is
    the proof the causal/banded grids skip (dense = n^2 steps per BH)."""
    from repro.kernels.flash_attention import flash_attention, planned_grid_steps

    out = []
    BH, S, D, blk = (2, 128, 32, 32) if _TINY else (4, 512, 64, 64)
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (BH, S, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, D), jnp.float32)

    def run(skip, window=None):
        return flash_attention(q, kk, v, causal=True, window=window,
                               bq=blk, bk=blk, skip_grid=skip,
                               return_steps=True)

    (y_skip, st_skip) = run(True)
    (y_dense, st_dense) = run(False)
    assert (np.asarray(y_skip) == np.asarray(y_dense)).all(), \
        "skip grid output not bit-identical to dense grid"
    assert int(st_skip) == planned_grid_steps(BH, S, causal=True,
                                              bq=blk, bk=blk)
    us_skip = _time(lambda: run(True), reps=3)
    us_dense = _time(lambda: run(False), reps=3)
    out.append(("kern.flash_causal_skip_us", round(us_skip, 0),
                f"steps {int(st_skip)}/{int(st_dense)} (skip/dense)"))
    out.append(("kern.flash_causal_dense_us", round(us_dense, 0),
                f"{int(st_dense)} steps"))
    w = S // 8
    (_, st_band) = run(True, window=w)
    us_band = _time(lambda: run(True, w), reps=3)
    # shape-stable row name (W = S/8 differs between tiny and full runs;
    # the regression gate diffs fresh-vs-committed rows by name)
    out.append(("kern.flash_banded_us", round(us_band, 0),
                f"W={w}, steps {int(st_band)} (O(S*W) vs {int(st_dense)} dense)"))
    return out


def _decode_rows():
    """Fused decode kernel vs the jnp full-T einsum it replaces."""
    from repro.kernels.flash_decode import decode_attn_flash
    from repro.models import attention as attn

    out = []
    B, T, KVr, G, D = (4, 64, 2, 2, 32) if _TINY else (8, 256, 2, 2, 64)
    H = KVr * G
    k = jax.random.PRNGKey(0)
    cache = attn.init_kv_cache(B, T, KVr, D, dtype=jnp.float32)
    cache = cache._replace(
        k=jax.random.normal(k, cache.k.shape, jnp.float32),
        v=jax.random.normal(jax.random.fold_in(k, 1), cache.v.shape,
                            jnp.float32),
        length=jnp.full((B,), T // 2, jnp.int32))
    q1 = jax.random.normal(jax.random.fold_in(k, 2), (B, 1, H, D), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(k, 3), (B, 1, KVr, D), jnp.float32)
    vn = jax.random.normal(jax.random.fold_in(k, 4), (B, 1, KVr, D), jnp.float32)

    f_jnp = jax.jit(lambda q, kn, vn, c: attn.decode_attn(q, kn, vn, c)[0])
    f_pls = jax.jit(lambda q, kn, vn, c: decode_attn_flash(q, kn, vn, c)[0])
    y_jnp = f_jnp(q1, kn, vn, cache)
    y_pls = f_pls(q1, kn, vn, cache)
    maxdiff = float(jnp.abs(y_jnp - y_pls).max())
    us_jnp = _time(lambda: f_jnp(q1, kn, vn, cache), reps=5)
    us_pls = _time(lambda: f_pls(q1, kn, vn, cache), reps=5)
    out.append(("kern.decode_jnp_us", round(us_jnp, 0),
                f"B{B} T{T} KVr{KVr} G{G} D{D}"))
    out.append(("kern.decode_flash_us", round(us_pls, 0),
                f"maxdiff {maxdiff:.2e} vs jnp"))
    return out


def rows():
    return _axqmm_rows() + _flash_rows() + _decode_rows()
