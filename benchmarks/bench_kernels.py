"""Kernel-level timings + correctness envelopes (CPU interpret mode — TPU is
the target; numbers prove correctness and degree-scaling, not TPU speed)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import qmm_ref
from repro.kernels.axqmm import axqmm


def rows():
    out = []
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (256, 1024), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (1024, 256), jnp.float32)
    exact = x @ w
    for e in (8, 6, 4):
        f = jax.jit(lambda x, w, e=e: axqmm(x, w, ebits=e))
        f(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            y = f(x, w).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        rel = float(jnp.abs(y - exact).mean() / jnp.abs(exact).mean())
        out.append((f"kern.axqmm_e{e}_relerr", round(us, 0), f"{rel:.4f}"))
        yr = qmm_ref(x, w, block=512, ebits=e)
        out.append((f"kern.axqmm_e{e}_vs_ref_maxdiff", 0.0,
                    f"{float(jnp.abs(y-yr).max()):.2e}"))
    return out
