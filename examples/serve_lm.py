"""Batched serving driver: continuous batching over the ServeEngine.

  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
  # execute a tuned per-layer plan (emitted by approx_pareto_explore.py),
  # QoS stepping its calibrated degree ladder under load:
  PYTHONPATH=src python examples/serve_lm.py --plan plans/approx_plan.json

Every run writes observability artifacts (repro.obs): a Chrome trace of
the engine lifecycle (open --trace-out in chrome://tracing / Perfetto)
and a Prometheus text snapshot of the engine counters and histograms.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.dynamic import QoSController
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--plan", default=None,
                    help="ApproxPlan JSON from approx_pareto_explore.py: "
                         "serve under its per-layer degree ladder")
    ap.add_argument("--trace-out", default="serve_trace.json",
                    help="Chrome trace_event JSON path ('' disables)")
    ap.add_argument("--metrics-out", default="serve_metrics.prom",
                    help="Prometheus text-format path ('' disables)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    plan = qos = None
    if args.plan is not None:
        from repro.tune import ApproxPlan

        plan = ApproxPlan.load(args.plan)
        plan.validate_for(cfg)
        qos = QoSController(ladder=plan.qos_ladder(), low_water=0.25,
                            high_water=0.75, cooldown_steps=4)
        model = build_model(cfg, plan.policy(dynamic=True))
    else:
        model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.trace_out:
        obs_trace.enable()
    registry = obs_metrics.get_registry() if args.metrics_out else None
    eng = ServeEngine(model, params, slots=args.slots, max_len=256,
                      plan=plan, qos=qos, registry=registry)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(2, 12))
        eng.submit(rng.integers(0, cfg.vocab, plen), args.new_tokens)
    done = eng.run_until_drained()
    dt = time.time() - t0
    tot = sum(len(r.out_tokens) for r in done)
    print(f"[serve_lm] {len(done)} requests, {tot} tokens in {dt:.2f}s "
          f"({tot/dt:.1f} tok/s), slots={args.slots}")
    lat = [r.t_done - r.t_enqueue for r in done]
    print(f"[serve_lm] latency p50={np.percentile(lat,50)*1e3:.0f}ms "
          f"p95={np.percentile(lat,95)*1e3:.0f}ms")
    if plan is not None and eng.stats.degree_history:
        rungs = {tuple(d) for _, d in eng.stats.degree_history}
        print(f"[serve_lm] plan ladder: visited {len(rungs)} of "
              f"{len(plan.ladder)} rungs; final degrees = "
              f"{list(eng.stats.degree_history[-1][1])}")
    if args.trace_out:
        obs_trace.get_tracer().write(args.trace_out)
        print(f"[serve_lm] wrote Chrome trace -> {args.trace_out}")
    if args.metrics_out:
        obs_metrics.get_registry().write(args.metrics_out)
        print(f"[serve_lm] wrote Prometheus metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
