"""Batched serving driver: continuous batching over the ServeEngine.

  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=args.slots, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(2, 12))
        eng.submit(rng.integers(0, cfg.vocab, plen), args.new_tokens)
    done = eng.run_until_drained()
    dt = time.time() - t0
    tot = sum(len(r.out_tokens) for r in done)
    print(f"[serve_lm] {len(done)} requests, {tot} tokens in {dt:.2f}s "
          f"({tot/dt:.1f} tok/s), slots={args.slots}")
    lat = [r.t_done - r.t_enqueue for r in done]
    print(f"[serve_lm] latency p50={np.percentile(lat,50)*1e3:.0f}ms "
          f"p95={np.percentile(lat,95)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
