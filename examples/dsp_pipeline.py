"""Approximate DSP pipeline (Ch. 7): FIR + Gaussian blur through the paper's
PR multiplier running as the Pallas accelerator kernel.

  PYTHONPATH=src python examples/dsp_pipeline.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import encodings as enc
from repro.kernels.axmult_elem import pr_multiply


def snr(ref, x):
    e = ref.astype(np.float64) - x.astype(np.float64)
    return 10 * np.log10((ref ** 2).mean() / max((e ** 2).mean(), 1e-30))


rng = np.random.default_rng(0)
t = np.arange(8192)
sig = np.sin(0.02 * t) + 0.4 * np.sin(0.4 * t) + 0.05 * rng.standard_normal(len(t))
sig_q = np.round(sig / np.abs(sig).max() * 2**14).astype(np.int32)
taps_q = np.round(np.hamming(32) * 2**14).astype(np.int32)

L = len(sig_q) - 32
Lp = ((L + 2047) // 2048) * 2048
ref = np.zeros(L, np.int64)
for i, tap in enumerate(taps_q):
    ref += tap.astype(np.int64) * sig_q[i:i + L]

# one batched DyFXU call per degree: taps stacked against their shifted
# signal windows as (taps, Lp) operand planes
T = len(taps_q)
a = np.ascontiguousarray(np.broadcast_to(taps_q[:, None], (T, Lp)))
b = np.zeros((T, Lp), np.int32)
b[:, :L] = np.lib.stride_tricks.sliding_window_view(sig_q, L)[:T]
for p, r in [(0, 0), (1, 4), (2, 8), (4, 8)]:
    prod = np.asarray(pr_multiply(jnp.asarray(a), jnp.asarray(b), p, r, n=16))
    acc = prod.astype(np.int64).sum(axis=0)
    print(f"FIR with DyFXU(p={p},r={r}): SNR = {snr(ref, acc[:L]):6.1f} dB")
print("(p=0,r=0 is the exact datapath; SNR degrades gracefully with degree — "
      "the Ch. 7 QoS/resource trade)")
