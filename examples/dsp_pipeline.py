"""Approximate DSP pipeline (Ch. 7): FIR filtering through the paper's PR
multiplier running as the Pallas accelerator kernel, reached via the
``kernels.dispatch.fir`` route (the same router the serve engine uses).

  PYTHONPATH=src python examples/dsp_pipeline.py
"""
import numpy as np

from repro.core.error_analysis import snr_db
from repro.kernels import dispatch as kdispatch

rng = np.random.default_rng(0)
t = np.arange(8192)
sig = np.sin(0.02 * t) + 0.4 * np.sin(0.4 * t) + 0.05 * rng.standard_normal(len(t))
sig_q = np.round(sig / np.abs(sig).max() * 2**14).astype(np.int32)
taps_q = np.round(np.hamming(32) * 2**14).astype(np.int32)

# the p=0,r=0 route is the exact datapath — it doubles as the reference
ref = kdispatch.fir(sig_q, taps_q, p=0, r=0)
for p, r in [(0, 0), (1, 4), (2, 8), (4, 8)]:
    y = kdispatch.fir(sig_q, taps_q, p=p, r=r)
    print(f"FIR with DyFXU(p={p},r={r}): SNR = {snr_db(ref, y):6.1f} dB"
          f"   [route: {kdispatch.last_route['fir']}]")
print("(p=0,r=0 is the exact datapath; SNR degrades gracefully with degree — "
      "the Ch. 7 QoS/resource trade)")
