"""Ch. 6 exploration, both stages, ending in a deployable artifact:

1. circuit-level — sweep the cooperative multiplier space and print its
   Pareto front (core/pareto.py);
2. network-level — profile per-layer error sensitivity of a smoke LM on a
   calibration batch and search mixed per-layer degree assignments
   (repro.tune), emitting an ``ApproxPlan`` JSON whose degree ladder
   ``examples/serve_lm.py --plan`` (and ``launch.serve --plan``) executes
   at runtime with zero recompiles.

  PYTHONPATH=src python examples/approx_pareto_explore.py \
      [--arch tinyllama-1.1b-smoke] [--plan-out plans/approx_plan.json]
"""
import argparse

import jax

from repro.core import pareto

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
ap.add_argument("--plan-out", default="plans/approx_plan.json")
ap.add_argument("--block", type=int, default=64)
args = ap.parse_args()

# ---- stage 1: the multiplier design space (Figs. 6.4-6.6) -----------------
pts = pareto.explore(n=16, num_samples=1 << 15)
front = pareto.front(pts)
print(f"design space: {len(pts)} configs; Pareto front: {len(front)} points")
for p in front:
    print("  " + p.row())

# ---- stage 2: per-layer plan for a deployed network (repro.tune) ----------
from repro.models import build_model
from repro.models.registry import concrete_batch
from repro.tune import ApproxPlan, build_plan
from repro.tune.plan import site_names
from repro.configs import get_config

cfg = get_config(args.arch)
policy = ApproxPlan(arch=cfg.name, sites=site_names(cfg), ladder=[],
                    block=args.block).policy(dynamic=True)
model = build_model(cfg, policy)
params = model.init(jax.random.PRNGKey(0), tp=1)
calib = concrete_batch(cfg, 32, 4, key=jax.random.PRNGKey(7))
print(f"\ntuning {cfg.name}: per-layer sensitivity + mixed-degree search ...")
plan = build_plan(model, params, calib, grid=(8, 7, 6, 5, 4),
                  block=args.block)
path = plan.save(args.plan_out)
print(f"plan ({plan.meta['strategy']}, {plan.meta['visited']} configs "
      f"measured) -> {path}")
for pt in plan.ladder:
    print(f"  {pt.name}: degrees={list(pt.degrees)} "
          f"err={pt.error:.5f} cost={pt.cost:.4f}")
print("deploy it:  PYTHONPATH=src python examples/serve_lm.py "
      f"--plan {path}")
