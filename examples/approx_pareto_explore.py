"""Ch. 6 exploration driver + Ch. 5 dynamic (QoS) demo:
sweep the cooperative approximation space, print the Pareto front, then show
the QoS controller walking the effective-bits ladder on a live quality signal.

  PYTHONPATH=src python examples/approx_pareto_explore.py
"""
import numpy as np

from repro.core import pareto
from repro.core.dynamic import QoSController

pts = pareto.explore(n=16, num_samples=1 << 15)
front = pareto.front(pts)
print(f"design space: {len(pts)} configs; Pareto front: {len(front)} points")
for p in front:
    print("  " + p.row())

print("\nQoS-driven dynamic approximation (Ch. 5 runtime configuration):")
qos = QoSController(ladder=[{"ebits": 8}, {"ebits": 7}, {"ebits": 6},
                            {"ebits": 5}],
                    low_water=0.0, high_water=0.08, cooldown_steps=2)
rng = np.random.default_rng(0)
for step in range(30):
    # synthetic quality signal: fine until step 15, then degradation
    sig = -0.01 if step < 15 else 0.2
    kw = qos.update(step, sig + 0.01 * rng.standard_normal())
    if step % 5 == 0 or step == 16:
        print(f"  step {step:>2}: quality_ema={qos.ema:+.3f} -> degree {kw}")
print("controller ramped approximation while quality held, backed off on "
      "violation — the paper's DyFXU runtime knob at system level.")
