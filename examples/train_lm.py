"""End-to-end training driver: a TinyLlama-family model trained for a few
hundred steps on the deterministic synthetic pipeline, with checkpointing,
straggler watchdog, and (optionally) QoS-driven dynamic approximation.

  PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 300
  PYTHONPATH=src python examples/train_lm.py --scale 20m  --steps 200   # CPU-sized

--scale 100m is the deliverable configuration (~100M params); 20m fits a
CPU-only box in minutes.  Loss curve lands in experiments/train_lm_<scale>.json.
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.configs import get_config
from repro.core.dynamic import QoSController
from repro.data.pipeline import make_pipeline
from repro.models import build_model
from repro.train import step as step_mod
from repro.train.trainer import Trainer, TrainerConfig

SCALES = {
    # name: (n_layers, d_model, n_heads, n_kv, d_ff, vocab) ~ params
    "100m": (12, 768, 12, 4, 2048, 32000),   # ~100M
    "20m": (6, 384, 6, 2, 1024, 8192),       # ~20M
    "tiny": (2, 64, 4, 2, 128, 512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="20m", choices=SCALES)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--qos", action="store_true",
                    help="enable runtime approximation control (DyFXU analogue)")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    L, d, h, kv, ff, v = SCALES[args.scale]
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"), n_layers=L, d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab=v,
        name=f"tinyllama-{args.scale}")
    model = build_model(cfg)
    n_params = cfg.param_count()[0]
    print(f"[train_lm] {cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    pipe = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch)
    qos = None
    if args.qos:
        qos = QoSController(
            ladder=[{"ebits": 8}, {"ebits": 7}, {"ebits": 6}, {"ebits": 5}],
            low_water=-0.005, high_water=0.05)
    trainer = Trainer(
        model,
        step_mod.StepConfig(remat="none", total_steps=args.steps,
                            warmup=max(args.steps // 20, 5)),
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 25),
                      ckpt_dir=args.ckpt_dir, log_every=10, qos=qos),
        pipe,
    )
    out = trainer.run()
    losses = [h["loss"] for h in out["history"]]
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    rec = {"scale": args.scale, "params": n_params, "history": out["history"],
           "stragglers": out["stragglers"]}
    outp = Path("experiments") / f"train_lm_{args.scale}.json"
    outp.parent.mkdir(exist_ok=True)
    outp.write_text(json.dumps(rec))
    print(f"[train_lm] wrote {outp}")


if __name__ == "__main__":
    main()
