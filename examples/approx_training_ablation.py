"""Ch. 7 software-exploration at LM scale: train the same model exact vs
through the approximate-arithmetic dispatch, compare loss trajectories —
the LM-scale analogue of the dissertation's CNN accuracy tables.

  PYTHONPATH=src python examples/approx_training_ablation.py --steps 60
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.configs import get_config
from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.data.pipeline import make_pipeline
from repro.models import build_model
from repro.train import step as step_mod


def run(policy_name: str, policy, cfg, steps: int, seq: int, batch: int):
    model = build_model(cfg, policy)
    state = step_mod.init_state(model, jax.random.PRNGKey(0))
    scfg = step_mod.StepConfig(remat="none", total_steps=steps, warmup=5)
    pipe = make_pipeline(cfg, seq_len=seq, global_batch=batch)
    f = jax.jit(lambda s, b: step_mod.train_step(model, scfg, s, b))
    losses = []
    for step in range(steps):
        b = {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, metrics = f(state, b)
        losses.append(float(metrics["loss"]))
    print(f"  {policy_name:<12} loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"), n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab=4096, name="ablation-8m")
    print(f"[ablation] {cfg.param_count()[0]/1e6:.1f}M params, "
          f"{args.steps} steps")
    curves = {}
    policies = {
        "exact": ApproxPolicy(),
        "axq8": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ,
                                                ebits=8, block=64)),
        "axq5": ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ,
                                                ebits=5, block=64)),
        "mlp_only_axq6": ApproxPolicy(rules=[
            (r".*mlp.*", ApproxSpec(mode=ApproxMode.AXQ, ebits=6, block=64))]),
    }
    for name, pol in policies.items():
        curves[name] = run(name, pol, cfg, args.steps, args.seq, args.batch)
    gap8 = curves["axq8"][-1] - curves["exact"][-1]
    gap5 = curves["axq5"][-1] - curves["exact"][-1]
    print(f"[ablation] final-loss gap vs exact: axq8 {gap8:+.4f}, "
          f"axq5 {gap5:+.4f} (graceful degradation, Ch.7 claim at LM scale)")
    Path("experiments").mkdir(exist_ok=True)
    Path("experiments/approx_training_ablation.json").write_text(
        json.dumps(curves))


if __name__ == "__main__":
    main()
