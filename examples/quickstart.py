"""Quickstart: the paper's approximation techniques in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import axmult, error_analysis as ea, pareto
from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.configs import get_config
from repro.models import build_model, concrete_batch

# 1. The arithmetic: a RAD-256 approximate product and its exact error profile
rep = ea.rad_operand_marginal(16, 8)
print(f"RAD256 16-bit multiplier: MRED={100*rep.mred:.3f}%  "
      f"bias={rep.mean_err:+.1e}  Pr[RED<=2%]={rep.pred2:.3f}")

# 2. The design space: Ch.6 cooperative Pareto front under an error budget
pts = pareto.explore(n=16, num_samples=1 << 14)
best = pareto.best_under_error(pts, 0.01)
print(f"best design under MRED<=1%: {best.name} "
      f"(energy proxy {best.energy:.0f} vs exact "
      f"{[p for p in pts if p.fam=='CMB'][0].energy:.0f})")

# 3. The system: an LM whose every matmul runs through the approximation layer
cfg = get_config("tinyllama-1.1b-smoke")
policy = ApproxPolicy(rules=[
    (r".*mlp.*", ApproxSpec(mode=ApproxMode.AXQ, ebits=6, block=64)),
])
model = build_model(cfg, policy)
params = model.init(jax.random.PRNGKey(0))
batch = concrete_batch(cfg, seq=32, batch=2)
loss_exact, _ = build_model(cfg).loss(params, batch)
loss_approx, _ = model.loss(params, batch)
print(f"LM loss exact={float(loss_exact):.4f} "
      f"approx(MLP int8@6bits)={float(loss_approx):.4f}")
print("quickstart OK")
