"""MoE routing correctness vs a dense (all-experts) reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as moe_mod


def _dense_reference(params, x, cfg):
    """Compute the same top-k mixture with a brute-force dense loop."""
    m = cfg.moe
    d = cfg.d_model
    T = x.shape[0] * x.shape[1]
    xt = np.asarray(x, np.float32).reshape(T, d)
    rw = np.asarray(params["router"]["w"], np.float32)
    logits = xt @ rw
    E = logits.shape[1]
    logits[:, m.n_experts:] = -1e9
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    topk = np.argsort(-probs, axis=-1)[:, :m.top_k]
    up = np.asarray(params["experts"]["up"], np.float32)
    gate = np.asarray(params["experts"]["gate"], np.float32)
    down = np.asarray(params["experts"]["down"], np.float32)
    out = np.zeros_like(xt)
    for t in range(T):
        g = probs[t, topk[t]]
        g = g / g.sum()
        for j, e in enumerate(topk[t]):
            h = (xt[t] @ up[e]) * (jax.nn.silu(xt[t] @ gate[e]))
            out[t] += g[j] * np.asarray(h @ down[e])
    return out.reshape(x.shape)


def test_moe_matches_dense_reference():
    cfg = get_config("qwen2-moe-a2.7b-smoke")
    import dataclasses

    # large capacity so nothing is dropped; no shared experts for the ref
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, n_shared=0))
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    from repro.core.approx import ApproxPolicy

    y, aux = moe_mod.moe_apply(params, x, cfg, ApproxPolicy(), "moe")
    ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32), ref, atol=2e-3,
                               rtol=2e-2)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens_gracefully():
    cfg = get_config("qwen2-moe-a2.7b-smoke")
    import dataclasses

    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    from repro.core.approx import ApproxPolicy

    y, _ = moe_mod.moe_apply(params, x, cfg, ApproxPolicy(), "moe")
    assert bool(jnp.isfinite(y).all())
