"""flash_decode vs the jnp decode paths: dense, ring wraparound, int8
dequant-in-kernel with the runtime ebits degree, and freed-slot masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import decode_attn_flash
from repro.models import attention as attn

B, T, KVr, D, H = 3, 32, 2, 16, 4
KEY = jax.random.PRNGKey(0)


def _filled_cache(lengths, quant=False, window=None):
    """Fill a cache through the real decode write path, then pin per-slot
    lengths (mixed fill levels, like a live engine)."""
    if quant:
        c = attn.init_quant_kv_cache(B, T, KVr, D)
    else:
        c = attn.init_kv_cache(B, T, KVr, D, dtype=jnp.float32)
    for t in range(max(lengths)):
        q1 = jax.random.normal(jax.random.fold_in(KEY, 100 + t),
                               (B, 1, H, D), jnp.float32)
        kn = jax.random.normal(jax.random.fold_in(KEY, 200 + t),
                               (B, 1, KVr, D), jnp.float32)
        vn = jax.random.normal(jax.random.fold_in(KEY, 300 + t),
                               (B, 1, KVr, D), jnp.float32)
        step = attn.decode_attn_quant if quant else attn.decode_attn
        _, c = step(q1, kn, vn, c, window=window)
    return c._replace(length=jnp.asarray(lengths, jnp.int32))


def _qkv():
    q1 = jax.random.normal(KEY, (B, 1, H, D), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(KEY, 1), (B, 1, KVr, D),
                           jnp.float32)
    vn = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 1, KVr, D),
                           jnp.float32)
    return q1, kn, vn


@pytest.mark.parametrize("window,lengths", [
    (None, [0, 5, 31]),        # dense cache, mixed fill incl. empty slot
    (None, [40, 33, 50]),      # saturated (length past capacity)
    (32, [40, 33, 7]),         # ring buffer, wrapped slots
    (8, [3, 50, 12]),          # ring with window < T
])
def test_flash_decode_matches_decode_attn(window, lengths):
    cache = _filled_cache(lengths, window=window)
    q1, kn, vn = _qkv()
    o_ref, c_ref = attn.decode_attn(q1, kn, vn, cache, window=window)
    o, c2 = decode_attn_flash(q1, kn, vn, cache, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)
    assert (np.asarray(c2.k) == np.asarray(c_ref.k)).all()
    assert (np.asarray(c2.v) == np.asarray(c_ref.v)).all()
    assert (np.asarray(c2.length) == np.asarray(c_ref.length)).all()


def test_flash_decode_quant_matches_decode_attn_quant():
    cache = _filled_cache([4, 18, 31], quant=True)
    q1, kn, vn = _qkv()
    o_ref, c_ref = attn.decode_attn_quant(q1, kn, vn, cache)
    o8, c2 = decode_attn_flash(q1, kn, vn, cache, degree=8)
    np.testing.assert_allclose(np.asarray(o8), np.asarray(o_ref), atol=1e-5)
    assert (np.asarray(c2.k) == np.asarray(c_ref.k)).all()
    assert (np.asarray(c2.ks) == np.asarray(c_ref.ks)).all()


def test_flash_decode_quant_runtime_degree():
    """ebits < 8 must actually degrade (DyFXU knob reaches the kernel) and
    stay a single executable with the degree traced."""
    cache = _filled_cache([4, 18, 31], quant=True)
    q1, kn, vn = _qkv()
    f = jax.jit(lambda q, kn, vn, c, e: decode_attn_flash(
        q, kn, vn, c, degree=e)[0])
    y8 = f(q1, kn, vn, cache, jnp.int32(8))
    y4 = f(q1, kn, vn, cache, jnp.int32(4))
    assert float(jnp.abs(y8 - y4).max()) > 1e-4


def test_flash_decode_freed_slot_masking():
    cache = _filled_cache([4, 18, 31])
    q1, kn, vn = _qkv()
    act = jnp.asarray([True, False, True])
    o, _ = decode_attn_flash(q1, kn, vn, cache, active=act)
    o_all, _ = decode_attn_flash(q1, kn, vn, cache)
    assert (np.asarray(o[1]) == 0).all()          # freed slot: exact zeros
    np.testing.assert_array_equal(np.asarray(o[0]), np.asarray(o_all[0]))
    np.testing.assert_array_equal(np.asarray(o[2]), np.asarray(o_all[2]))


@pytest.mark.parametrize("quant", [False, True])
def test_flash_decode_odd_cache_capacity(quant):
    """Non-power-of-two T must keep full-width tiles (ragged final tile,
    masked in-kernel) instead of degrading toward 1-token tiles — and stay
    NaN-free past the valid length."""
    Todd = 135            # > bt=128: ragged final tile with OOB lanes
    if quant:
        c = attn.init_quant_kv_cache(B, Todd, KVr, D)
        step = attn.decode_attn_quant
    else:
        c = attn.init_kv_cache(B, Todd, KVr, D, dtype=jnp.float32)
        step = attn.decode_attn
    for t in range(9):
        q1 = jax.random.normal(jax.random.fold_in(KEY, 400 + t),
                               (B, 1, H, D), jnp.float32)
        kn = jax.random.normal(jax.random.fold_in(KEY, 500 + t),
                               (B, 1, KVr, D), jnp.float32)
        vn = jax.random.normal(jax.random.fold_in(KEY, 600 + t),
                               (B, 1, KVr, D), jnp.float32)
        _, c = step(q1, kn, vn, c)
    c = c._replace(length=jnp.asarray([2, 99, 134], jnp.int32))
    q1, kn, vn = _qkv()
    if quant:
        o_ref, _ = attn.decode_attn_quant(q1, kn, vn, c)
    else:
        o_ref, _ = attn.decode_attn(q1, kn, vn, c)
    o, _ = decode_attn_flash(q1, kn, vn, c)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)
