"""Per-kernel shape/dtype/degree sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.axmult_elem import pr_multiply
from repro.kernels.axqmm import axqmm


@pytest.mark.parametrize("shape", [(128, 512, 128), (256, 1024, 384),
                                   (64, 512, 256)])
@pytest.mark.parametrize("e", [8, 5])
def test_axqmm_matches_ref(shape, e):
    M, K, N = shape
    k = jax.random.PRNGKey(M + K + N + e)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    y = axqmm(x, w, block=512, ebits=e)
    yr = ref.axqmm_ref(x, w, block=512 if K % 512 == 0 else 256, ebits=e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 256, 96), (3, 512, 130), (1, 256, 64)])
def test_axqmm_decode_shapes_pad_to_tile(shape):
    """Serving-shaped inputs (M = slots, ragged N) must pad to the tile
    multiple and slice back instead of raising 'shape not tileable'."""
    M, K, N = shape
    k = jax.random.PRNGKey(M + K + N)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    y = axqmm(x, w, block=256)
    assert y.shape == (M, N)
    yr = ref.axqmm_ref(x, w, block=256, ebits=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-4)


def test_axqmm_dynamic_degree_single_executable():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (128, 512), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 128), jnp.float32)
    f = jax.jit(lambda x, w, e: axqmm(x, w, ebits=e))
    y8, y4 = f(x, w, jnp.int32(8)), f(x, w, jnp.int32(4))
    exact = x @ w
    assert float(jnp.abs(y8 - exact).mean()) < float(jnp.abs(y4 - exact).mean())


@pytest.mark.parametrize("p,r", [(0, 0), (1, 2), (2, 4), (4, 8)])
def test_pr_multiply_kernel_bit_exact(p, r):
    rng = np.random.default_rng(p * 10 + r)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 4096), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 4096), jnp.int32)
    y = pr_multiply(a, b, p, r, n=16)
    yr = ref.pr_multiply_ref(a, b, p, r, n=16)
    assert (np.asarray(y) == np.asarray(yr)).all()


@given(st.integers(0, 4), st.integers(0, 8))
@settings(max_examples=12, deadline=None)
def test_pr_multiply_kernel_property(p, r):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    y = pr_multiply(a, b, p, r, n=16)
    yr = ref.pr_multiply_ref(a, b, p, r, n=16)
    assert (np.asarray(y) == np.asarray(yr)).all()


@pytest.mark.parametrize("shape", [(4, 256, 64), (2, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal):
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    BH, S, D = shape
    k = jax.random.PRNGKey(S + D)
    q = jax.random.normal(k, (BH, S, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, D), jnp.float32)
    y = flash_attention(q, kk, v, causal=causal, bq=128, bk=128)
    yr = flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


def test_flash_attention_odd_blocks():
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (2, 192, 64), jnp.float32)   # S not /128 -> bq 64
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 192, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 192, 64), jnp.float32)
    y = flash_attention(q, kk, v, causal=True)
    yr = flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)
