"""Per-kernel shape/dtype/degree sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.axmult_elem import pr_multiply
from repro.kernels.axqmm import axqmm


@pytest.mark.parametrize("shape", [(128, 512, 128), (256, 1024, 384),
                                   (64, 512, 256)])
@pytest.mark.parametrize("e", [8, 5])
def test_axqmm_matches_ref(shape, e):
    M, K, N = shape
    k = jax.random.PRNGKey(M + K + N + e)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    y = axqmm(x, w, block=512, ebits=e)
    yr = ref.axqmm_ref(x, w, block=512 if K % 512 == 0 else 256, ebits=e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 256, 96), (3, 512, 130), (1, 256, 64)])
def test_axqmm_decode_shapes_pad_to_tile(shape):
    """Serving-shaped inputs (M = slots, ragged N) must pad to the tile
    multiple and slice back instead of raising 'shape not tileable'."""
    M, K, N = shape
    k = jax.random.PRNGKey(M + K + N)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    y = axqmm(x, w, block=256)
    assert y.shape == (M, N)
    yr = ref.axqmm_ref(x, w, block=256, ebits=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-4)


def test_axqmm_dynamic_degree_single_executable():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (128, 512), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 128), jnp.float32)
    f = jax.jit(lambda x, w, e: axqmm(x, w, ebits=e))
    y8, y4 = f(x, w, jnp.int32(8)), f(x, w, jnp.int32(4))
    exact = x @ w
    assert float(jnp.abs(y8 - exact).mean()) < float(jnp.abs(y4 - exact).mean())


@pytest.mark.parametrize("p,r", [(0, 0), (1, 2), (2, 4), (4, 8)])
def test_pr_multiply_kernel_bit_exact(p, r):
    rng = np.random.default_rng(p * 10 + r)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 4096), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 4096), jnp.int32)
    y = pr_multiply(a, b, p, r, n=16)
    yr = ref.pr_multiply_ref(a, b, p, r, n=16)
    assert (np.asarray(y) == np.asarray(yr)).all()


@given(st.integers(0, 4), st.integers(0, 8))
@settings(max_examples=12, deadline=None)
def test_pr_multiply_kernel_property(p, r):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    y = pr_multiply(a, b, p, r, n=16)
    yr = ref.pr_multiply_ref(a, b, p, r, n=16)
    assert (np.asarray(y) == np.asarray(yr)).all()


@pytest.mark.parametrize("shape", [(4, 256, 64), (2, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal):
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    BH, S, D = shape
    k = jax.random.PRNGKey(S + D)
    q = jax.random.normal(k, (BH, S, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, D), jnp.float32)
    y = flash_attention(q, kk, v, causal=causal, bq=128, bk=128)
    yr = flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


def test_flash_attention_odd_blocks():
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (2, 192, 64), jnp.float32)   # S not /128 -> bq 64
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 192, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 192, 64), jnp.float32)
    y = flash_attention(q, kk, v, causal=True)
    yr = flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


@pytest.mark.parametrize("window", [16, 40, 500])
def test_flash_attention_window_matches_ref(window):
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    k = jax.random.PRNGKey(window)
    q = jax.random.normal(k, (2, 128, 32), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 128, 32), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 128, 32), jnp.float32)
    y = flash_attention(q, kk, v, causal=True, window=window, bq=32, bk=32)
    yr = flash_attention_ref(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


@pytest.mark.parametrize("shape,causal", [((2, 100, 16), True),
                                          ((3, 130, 16), False),
                                          ((1, 1, 8), True)])
def test_flash_attention_nonpow2_seq_pads_to_tile(shape, causal):
    """Non-power-of-two S must pad to the block multiple and slice back
    (the seed's bq //= 2 loop degraded to degenerate tiles instead)."""
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    BH, S, D = shape
    k = jax.random.PRNGKey(S)
    q = jax.random.normal(k, shape, jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), shape, jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), shape, jnp.float32)
    y = flash_attention(q, kk, v, causal=causal)
    assert y.shape == shape
    yr = flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


def test_flash_causal_skip_grid_steps_and_bit_identity():
    """The causal grid must *execute* <= n(n+1)/2 block-steps per BH (vs n^2
    dense) — asserted on the in-kernel counter, not the plan — with output
    bit-identical to the dense grid."""
    from repro.kernels.flash_attention import flash_attention, planned_grid_steps

    BH, S, D, blk = 2, 256, 16, 32
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (BH, S, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, D), jnp.float32)
    y_skip, st_skip = flash_attention(q, kk, v, causal=True, bq=blk, bk=blk,
                                      return_steps=True)
    y_dense, st_dense = flash_attention(q, kk, v, causal=True, bq=blk, bk=blk,
                                        skip_grid=False, return_steps=True)
    n = S // blk
    assert int(st_skip) == BH * n * (n + 1) // 2 == planned_grid_steps(
        BH, S, causal=True, bq=blk, bk=blk)
    assert int(st_dense) == BH * n * n
    assert (np.asarray(y_skip) == np.asarray(y_dense)).all()


def test_flash_banded_grid_steps():
    """Sliding-window layers must execute O(S*W) block-steps."""
    from repro.kernels.flash_attention import flash_attention, planned_grid_steps

    BH, S, D, blk, w = 2, 256, 16, 32, 40
    k = jax.random.PRNGKey(1)
    q = jax.random.normal(k, (BH, S, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (BH, S, D), jnp.float32)
    y, steps = flash_attention(q, kk, v, causal=True, window=w, bq=blk,
                               bk=blk, return_steps=True)
    n = S // blk
    band = (w - 1 + blk - 1) // blk + 1
    assert int(steps) == BH * n * band == planned_grid_steps(
        BH, S, causal=True, window=w, bq=blk, bk=blk)
    assert int(steps) < BH * n * (n + 1) // 2  # beats the triangular walk too
    y_dense = flash_attention(q, kk, v, causal=True, window=w, bq=blk, bk=blk,
                              skip_grid=False)
    assert (np.asarray(y) == np.asarray(y_dense)).all()


def test_flash_attention_vjp_grad_matches_ref():
    from repro.kernels.flash_attention import (flash_attention_ref,
                                               flash_attention_vjp)

    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (2, 64, 16), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 64, 16), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 64, 16), jnp.float32)
    g1 = jax.grad(lambda q, kk, v: flash_attention_vjp(
        q, kk, v, True, None).sum(), argnums=(0, 1, 2))(q, kk, v)
    g2 = jax.grad(lambda q, kk, v: flash_attention_ref(
        q, kk, v, causal=True).sum(), argnums=(0, 1, 2))(q, kk, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
