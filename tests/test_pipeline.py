import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_pipeline


def test_deterministic_and_resumable():
    cfg = get_config("tinyllama-1.1b-smoke")
    p1 = make_pipeline(cfg, 32, 4, seed=7)
    p2 = make_pipeline(cfg, 32, 4, seed=7)
    b1, b2 = p1.batch_at(123), p2.batch_at(123)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"] == b2["labels"]).all()
    b3 = p1.batch_at(124)
    assert not (b1["tokens"] == b3["tokens"]).all()


def test_labels_are_shifted_tokens():
    cfg = get_config("tinyllama-1.1b-smoke")
    p = make_pipeline(cfg, 16, 2)
    b = p.batch_at(0)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_markov_structure_learnable():
    """The chain must be more predictable than uniform (so training curves
    mean something)."""
    cfg = get_config("tinyllama-1.1b-smoke")
    p = make_pipeline(cfg, 256, 8)
    b = p.batch_at(0)
    toks = b["tokens"]
    # copy dependency: token[t] == token[t-64] more often than chance
    eq = (toks[:, 64:] == toks[:, :-64]).mean()
    assert eq > 0.05


def test_frontend_batches():
    for name in ("hubert-xlarge", "internvl2-1b"):
        cfg = get_config(name + "-smoke")
        p = make_pipeline(cfg, 32, 2)
        b = p.batch_at(0)
        if name.startswith("hubert"):
            assert "frame_feats" in b and b["labels"].shape == (2, 32)
            assert (b["labels"] >= -1).all()
        else:
            assert "patch_embeds" in b
            assert b["tokens"].shape[1] == 32 - cfg.frontend_tokens
