"""repro.tune: plan round-tripping, per-layer == global degree equivalence
across all four families, QoS plan-ladder stepping, and the zero-recompile
contract of the per-layer degree vector."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.approx import ApproxMode, ApproxSpec, uniform
from repro.core.dynamic import QoSController
from repro.models import build_model
from repro.models.degrees import num_sites, split_degree
from repro.models.registry import concrete_batch
from repro.serve.engine import ServeEngine
from repro.tune import (ApproxPlan, PlanPoint, build_plan, uniform_plan,
                        vector_cost)
from repro.tune.plan import site_names

FAMILIES = ["tinyllama-1.1b-smoke", "qwen2-moe-a2.7b-smoke",
            "mamba2-370m-smoke", "recurrentgemma-2b-smoke"]

_CACHE: dict = {}


def _setup(arch: str):
    """Model under the plan-execution policy (uniform dynamic AXQ)."""
    if arch not in _CACHE:
        cfg = get_config(arch)
        policy = uniform(ApproxSpec(mode=ApproxMode.AXQ, ebits=8,
                                    dynamic=True, block=64))
        m = build_model(cfg, policy)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[arch] = (cfg, m, params)
    return _CACHE[arch]


def _tuned_plan():
    if "plan" not in _CACHE:
        cfg, m, params = _setup("tinyllama-1.1b-smoke")
        calib = concrete_batch(cfg, 16, 2, key=jax.random.PRNGKey(7))
        _CACHE["plan"] = build_plan(m, params, calib, grid=(8, 7, 6),
                                    block=64, max_rungs=4)
    return _CACHE["plan"]


# ---------------------------------------------------------------------------
# plan serialization
# ---------------------------------------------------------------------------


def test_plan_roundtrip_bit_stable(tmp_path):
    plan = _tuned_plan()
    path = plan.save(tmp_path / "plan.json")
    loaded = ApproxPlan.load(path)
    assert loaded == plan
    assert loaded.to_dict() == plan.to_dict()
    # degrees survive exactly (ints, not floats)
    for a, b in zip(plan.ladder, loaded.ladder):
        assert a.degrees == b.degrees
        assert isinstance(b.degrees[0], int)
    # saving the loaded plan reproduces the bytes
    p2 = loaded.save(tmp_path / "plan2.json")
    assert p2.read_bytes() == path.read_bytes()


def test_plan_validate_mismatch():
    cfg = get_config("tinyllama-1.1b-smoke")
    plan = uniform_plan(cfg)
    plan.validate_for(cfg)
    # wrong arch: calibrated numbers don't transfer, even at equal depth
    other = get_config("recurrentgemma-2b-smoke")
    with pytest.raises(ValueError, match="tuned for"):
        plan.validate_for(other)
    # right arch, corrupted site list
    bad = ApproxPlan(arch=cfg.name, sites=site_names(cfg)[:-1],
                     ladder=uniform_plan(cfg).ladder)
    with pytest.raises(ValueError, match="sites"):
        bad.validate_for(cfg)
    with pytest.raises(ValueError, match="empty ladder"):
        ApproxPlan(arch=cfg.name, sites=site_names(cfg),
                   ladder=[]).validate_for(cfg)


def test_uniform_plan_shape():
    cfg = get_config("tinyllama-1.1b-smoke")
    plan = uniform_plan(cfg, ebits_ladder=(8, 6))
    assert plan.num_sites() == num_sites(cfg) == cfg.n_layers + 1
    assert (plan.degrees(0) == 8).all() and (plan.degrees(1) == 6).all()
    assert plan.qos_ladder() == [{"degrees": [8] * 3}, {"degrees": [6] * 3}]


def test_split_degree_contract():
    assert split_degree(None, 4) == (None, None)
    l, h = split_degree(6, 4)
    assert l.shape == (4,) and h.shape == ()
    l, h = split_degree(jnp.asarray([8, 7, 6, 5, 4], jnp.int32), 4)
    assert l.tolist() == [8, 7, 6, 5] and int(h) == 4
    with pytest.raises(ValueError, match="per-layer degree"):
        split_degree(jnp.asarray([8, 7], jnp.int32), 4)


# ---------------------------------------------------------------------------
# per-layer == global when uniform (all four families)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILIES)
def test_uniform_vector_equals_global_scalar(arch):
    """A uniform plan rung must execute bit-identically to the legacy global
    scalar degree — forward and decode."""
    cfg, m, params = _setup(arch)
    batch = concrete_batch(cfg, 16, 2, key=jax.random.PRNGKey(3))
    vec = jnp.asarray([6] * num_sites(cfg), jnp.int32)
    ls, _ = m.forward(params, batch, degree=jnp.asarray(6, jnp.int32))
    lv, _ = m.forward(params, batch, degree=vec)
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lv))

    cache = m.init_cache(tp=1, batch=2, max_len=32)
    toks = np.array([[3], [5]], np.int32)
    ds, _ = m.decode_step(params, cache, toks, degree=jnp.asarray(6, jnp.int32))
    dv, _ = m.decode_step(params, cache, toks, degree=vec)
    np.testing.assert_array_equal(np.asarray(ds), np.asarray(dv))


@pytest.mark.parametrize("arch", FAMILIES)
def test_mixed_vector_changes_output(arch):
    """A genuinely mixed assignment must not silently collapse to uniform."""
    cfg, m, params = _setup(arch)
    batch = concrete_batch(cfg, 16, 2, key=jax.random.PRNGKey(3))
    S = num_sites(cfg)
    mixed = jnp.asarray([8, 4] + [6] * (S - 2), jnp.int32)
    lu, _ = m.forward(params, batch, degree=jnp.asarray(6, jnp.int32))
    lm, _ = m.forward(params, batch, degree=mixed)
    assert not np.array_equal(np.asarray(lu), np.asarray(lm))


def test_prefill_accepts_plan_vector():
    cfg, m, params = _setup("tinyllama-1.1b-smoke")
    S = num_sites(cfg)
    cache = m.init_cache(tp=1, batch=2, max_len=32)
    vec = jnp.asarray([7] * S, jnp.int32)
    lg_v, _ = m.prefill(params, cache, jnp.asarray([1, 2, 3], jnp.int32),
                        jnp.asarray(0), degree=vec)
    lg_s, _ = m.prefill(params, cache, jnp.asarray([1, 2, 3], jnp.int32),
                        jnp.asarray(0), degree=jnp.asarray(7, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_v), np.asarray(lg_s))


# ---------------------------------------------------------------------------
# tuner output
# ---------------------------------------------------------------------------


def test_plan_ladder_is_pareto_and_ordered():
    plan = _tuned_plan()
    pts = plan.ladder
    assert len(pts) >= 2
    # most accurate first; monotone cost descent along the ladder
    costs = [p.cost for p in pts]
    assert costs == sorted(costs, reverse=True)
    # no rung dominates another (front property survives subsampling)
    for a in pts:
        for b in pts:
            if a is b:
                continue
            assert not (a.cost <= b.cost and a.error <= b.error
                        and (a.cost < b.cost or a.error < b.error))
    # rung 0 is the most accurate configuration visited
    assert pts[0].error == min(p.error for p in pts)


def test_vector_cost_monotone():
    cfg = get_config("tinyllama-1.1b-smoke")
    S = num_sites(cfg)
    costs = [vector_cost(cfg, [e] * S) for e in (8, 7, 6, 5, 4)]
    assert costs[0] == pytest.approx(1.0)
    assert all(a > b for a, b in zip(costs, costs[1:]))


# ---------------------------------------------------------------------------
# serving: QoS ladder stepping + zero recompiles
# ---------------------------------------------------------------------------


def test_qos_plan_ladder_steps_every_rung_zero_recompiles():
    """Under sustained overload the QoS controller must walk the plan's
    ladder rung by rung — and the whole walk must reuse ONE compiled serve
    step (the degree vector is a traced operand)."""
    cfg, m, params = _setup("tinyllama-1.1b-smoke")
    plan = _tuned_plan()
    qos = QoSController(ladder=[], low_water=0.25, high_water=0.75,
                        cooldown_steps=1)
    eng = ServeEngine(m, params, slots=2, max_len=64, qos=qos, plan=plan)
    assert qos.ladder == plan.qos_ladder()
    rng = np.random.default_rng(0)
    for _ in range(12):                   # overload: queue >> slots
        eng.submit(rng.integers(0, cfg.vocab, 4), 8)
    done = eng.run_until_drained()
    assert len(done) == 12
    visited = {d for _, d in eng.stats.degree_history}
    assert visited == {tuple(pt.degrees) for pt in plan.ladder}, visited
    assert eng._step._cache_size() == 1, "degree ladder must not recompile"


def test_engine_plan_static_degree_no_qos():
    """plan without qos: engine serves the most-accurate rung statically."""
    cfg, m, params = _setup("tinyllama-1.1b-smoke")
    plan = _tuned_plan()
    eng = ServeEngine(m, params, slots=2, max_len=64, plan=plan)
    eng.submit(np.array([1, 2, 3]), 4)
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == 4
    assert np.asarray(eng._degree).tolist() == list(plan.ladder[0].degrees)


def test_engine_plan_matches_manual_degree():
    """Serving under a plan rung == serving with that vector passed as the
    static degree (the plan is transport, not arithmetic)."""
    cfg, m, params = _setup("tinyllama-1.1b-smoke")
    plan = _tuned_plan()
    rung = plan.ladder[-1]
    prompt = np.array([5, 6, 7])
    a = ServeEngine(m, params, slots=2, max_len=64, plan=plan,
                    degree=rung.degree_array())
    a.submit(prompt, 5)
    ta = a.run_until_drained()[0].out_tokens
    b = ServeEngine(m, params, slots=2, max_len=64,
                    degree=rung.degree_array())
    b.submit(prompt, 5)
    tb = b.run_until_drained()[0].out_tokens
    assert ta == tb


def test_degree_operand_decoder():
    """The one shared ladder-entry decoder + record rule (engine, trainer)."""
    from repro.core.dynamic import degree_operand, degree_record

    d = degree_operand({"degrees": [8, 7, 6]})
    assert d.shape == (3,) and d.dtype == jnp.int32
    s = degree_operand({"ebits": 5})
    assert s.shape == () and int(s) == 5
    assert degree_record(d) == (8, 7, 6)
    assert degree_record(s) == 5


def test_site_degree_helper():
    from repro.kernels.dispatch import site_degree

    assert site_degree(None, 2) is None
    sc = site_degree(jnp.asarray(6, jnp.int32), 2)
    assert sc.ndim == 0 and int(sc) == 6          # scalar passes through
    vec = jnp.asarray([8, 7, 6], jnp.int32)
    assert int(site_degree(vec, 1)) == 7


def test_qos_degrees_ladder_without_plan_no_retrace():
    """A controller carrying per-layer rungs but no plan= must still start
    on its current rung (vector), not a scalar — a scalar->vector swap at
    the first update would recompile the serve step."""
    cfg, m, params = _setup("tinyllama-1.1b-smoke")
    plan = _tuned_plan()
    qos = QoSController(ladder=plan.qos_ladder(), low_water=0.25,
                        high_water=0.75, cooldown_steps=1)
    eng = ServeEngine(m, params, slots=2, max_len=64, qos=qos)
    assert np.asarray(eng._degree).shape == (num_sites(cfg),)
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab, 4), 6)
    eng.run_until_drained()
    assert eng._step._cache_size() == 1
