import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, concrete_batch
from repro.train import step as step_mod


def test_overfit_tiny_batch():
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    state = step_mod.init_state(m, jax.random.PRNGKey(0))
    scfg = step_mod.StepConfig(remat="none", total_steps=60, warmup=5)
    batch = concrete_batch(cfg, seq=16, batch=2)
    f = jax.jit(lambda s, b: step_mod.train_step(m, scfg, s, b))
    losses = []
    for _ in range(40):
        state, metrics = f(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_grad_accum_matches_full_batch():
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    batch = concrete_batch(cfg, seq=16, batch=4)
    s1 = step_mod.init_state(m, key)
    s2 = step_mod.init_state(m, key)
    c1 = step_mod.StepConfig(remat="none", grad_accum=1, total_steps=10, warmup=0)
    c2 = step_mod.StepConfig(remat="none", grad_accum=2, total_steps=10, warmup=0)
    n1, m1 = jax.jit(lambda s, b: step_mod.train_step(m, c1, s, b))(s1, batch)
    n2, m2 = jax.jit(lambda s, b: step_mod.train_step(m, c2, s, b))(s2, batch)
    p1 = jax.tree_util.tree_leaves(n1.params)[0]
    p2 = jax.tree_util.tree_leaves(n2.params)[0]
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=2e-4)


def test_qos_controller_integration():
    from repro.core.dynamic import QoSController
    from repro.data.pipeline import make_pipeline
    from repro.train.trainer import Trainer, TrainerConfig
    import tempfile, shutil

    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    pipe = make_pipeline(cfg, seq_len=16, global_batch=2)
    d = tempfile.mkdtemp()
    qos = QoSController(ladder=[{"ebits": 8}, {"ebits": 6}], low_water=-10.0,
                        high_water=10.0, cooldown_steps=0)
    t = Trainer(m, step_mod.StepConfig(remat="none", total_steps=20, warmup=2),
                TrainerConfig(total_steps=8, ckpt_every=100, ckpt_dir=d,
                              log_every=100, qos=qos, qos_every=2),
                pipe)
    out = t.run()
    shutil.rmtree(d, ignore_errors=True)
    assert out["final_step"] == 8
    assert len(qos.history) > 0


def test_compressed_grads_training_converges():
    """Beyond-paper: int8 quantize-dequantize on grads (the pjit-path
    emulation of compressed all-reduce) must not break optimization."""
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    state = step_mod.init_state(m, jax.random.PRNGKey(0))
    scfg = step_mod.StepConfig(remat="none", total_steps=40, warmup=2,
                               compress_grads=True)
    batch = concrete_batch(cfg, seq=16, batch=2)
    f = jax.jit(lambda s, b: step_mod.train_step(m, scfg, s, b))
    losses = []
    for _ in range(30):
        state, metrics = f(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.8, (losses[0], losses[-1])


def test_ring_tp_training_subprocess():
    """§Perf A2 wiring: int8-ring TP reductions keep training converging."""
    import subprocess
    import sys
    from pathlib import Path

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_RING_TP"] = "1"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.dist import meshctx
from repro.configs import get_config
from repro.models import build_model
from repro.train import step as step_mod
mesh = meshctx.make_mesh((2, 4), ("data", "model"))
meshctx.set_mesh(mesh)
cfg = get_config("tinyllama-1.1b-smoke")
m = build_model(cfg)
state = step_mod.init_state(m, jax.random.PRNGKey(0), tp=4)
scfg = step_mod.StepConfig(remat="none", total_steps=40, warmup=2)
fn = jax.jit(partial(step_mod.train_step, m, scfg, tp=4))
bt = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, 512, (4, 32)), jnp.int32)}
bt["labels"] = bt["tokens"]
losses = []
for _ in range(25):
    state, metrics = fn(state, bt)
    losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
print("RING_TRAIN_OK")
"""
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=root,
                       env={"PYTHONPATH": str(root / "src"),
                            "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert "RING_TRAIN_OK" in r.stdout, r.stderr[-2000:]
