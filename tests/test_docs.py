"""Docs health inside the tier-1 suite: the same gates the CI `docs` job
runs (tools/check_docs.py) — intra-repo markdown links resolve and the
docs/ python snippets compile."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    root = check_docs.ROOT
    assert (root / "docs" / "approximation.md").exists()
    assert (root / "docs" / "plans.md").exists()


def test_intra_repo_links_resolve():
    errors = [e for p in check_docs.doc_paths() for e in check_docs.check_links(p)]
    assert not errors, "\n".join(errors)


def test_doc_snippets_compile():
    docs = sorted((check_docs.ROOT / "docs").glob("*.md"))
    assert docs
    errors = [e for p in docs for e in check_docs.check_snippets(p)]
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_link(tmp_path, monkeypatch):
    """The gate itself must fail on rot (guards against a regex regression
    making the job vacuously green)."""
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does/not/exist.md) and "
                   "[ok](https://example.com)\n"
                   "```python\ndef broken(:\n```\n")
    assert check_docs.check_links(bad)
    assert check_docs.check_snippets(bad)
