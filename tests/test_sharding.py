"""Partition-rule unit tests + a subprocess micro dry-run on 8 fake devices
(XLA device-count flag must precede jax import, hence the subprocess)."""
import subprocess
import sys
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import spec_for_param

ROOT = Path(__file__).resolve().parents[1]


def test_param_rules():
    assert spec_for_param("layers/wq/w", 3) == P(None, None, "model")
    assert spec_for_param("layers/wo/w", 3) == P(None, "model", None)
    assert spec_for_param("embed/emb", 2) == P("model", None)
    assert spec_for_param("layers/moe/experts/up", 4) == P(None, "model", None, None)
    assert spec_for_param("layers/ln1/scale", 2) == P(None, None)
    assert spec_for_param("layers/mlp/up/w", 3) == P(None, None, "model")
    assert spec_for_param("layers/wx/w", 3) == P(None, None, "model")


def test_padded_dims():
    from repro.configs import get_config

    pd = get_config("internvl2-1b").padded(16)
    assert pd.n_heads == 16 and pd.n_kv_rep == 16 and pd.q_group == 1
    pd = get_config("mistral-nemo-12b").padded(16)
    assert pd.n_heads == 32 and pd.n_kv_rep == 16 and pd.q_group == 2
    pd = get_config("qwen2-moe-a2.7b").padded(16)
    assert pd.n_experts == 64
    pd = get_config("granite-moe-3b-a800m").padded(16)
    assert pd.n_heads == 32 and pd.n_experts == 48
    # single-device (tests): no padding
    pd1 = get_config("internvl2-1b").padded(1)
    assert pd1.n_heads == 14 and pd1.n_kv_rep == 2


@pytest.mark.slow
def test_micro_mesh_dryrun_subprocess():
    """Lower+compile the smoke tinyllama train step on a 2x4 fake mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from functools import partial
from repro.configs import get_config
from repro.dist import meshctx, sharding
from repro.models import build_model
from repro.train import step as step_mod
import jax.numpy as jnp

mesh = meshctx.make_mesh((2, 4), ("data", "model"))
meshctx.set_mesh(mesh)
cfg = get_config("tinyllama-1.1b-smoke")
m = build_model(cfg)
state_sds = jax.eval_shape(partial(step_mod.init_state, m, tp=4), jax.random.PRNGKey(0))
pspecs = sharding.partition_params(state_sds.params, cfg.family)
sspecs = step_mod.TrainState(pspecs, sharding.partition_opt_state(state_sds.opt, pspecs), jax.sharding.PartitionSpec())
batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
bspecs = sharding.partition_batch(batch)
scfg = step_mod.StepConfig(remat="full")
fn = partial(step_mod.train_step, m, scfg, tp=4)
j = jax.jit(fn, in_shardings=(sharding.named(sspecs, mesh), sharding.named(bspecs, mesh)), donate_argnums=(0,))
c = j.lower(state_sds, batch).compile()
assert c.memory_analysis().temp_size_in_bytes > 0
print("MICRO_DRYRUN_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "MICRO_DRYRUN_OK" in r.stdout, r.stderr[-2000:]
