"""Partition-rule unit tests + a subprocess micro dry-run on 8 fake devices
(XLA device-count flag must precede jax import, hence the subprocess).

The real-tree suite (ISSUE 9) validates the name-pattern rules against the
*actual* param and decode-cache trees of all three LM families plus the
stream workload: every sharded axis divides its leaf dim, and no weight
matrix silently falls through to replicated."""
import subprocess
import sys
from functools import partial
from math import prod
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (_key_str, partition_cache, partition_params,
                                 spec_for_param)

ROOT = Path(__file__).resolve().parents[1]

# the mesh the divisibility checks assume: the CI dry-run shape (2, 4)
_AXIS_SIZES = {"data": 2, "model": 4}
_TP = _AXIS_SIZES["model"]
_FAMILIES = ["tinyllama-1.1b-smoke", "mamba2-370m-smoke",
             "recurrentgemma-2b-smoke"]


def _entries(tree, specs):
    """(path-name, shape, spec) per leaf — specs flattened in the same
    order as the tree they were mapped from."""
    tl = jax.tree_util.tree_flatten_with_path(tree)[0]
    sl = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(tl) == len(sl)
    for (path, leaf), spec in zip(tl, sl):
        yield "/".join(_key_str(k) for k in path), tuple(leaf.shape), spec


def _spec_axes(spec):
    """Flat mesh-axis names a spec shards over."""
    out = []
    for entry in spec:
        if entry is None:
            continue
        out.extend((entry,) if isinstance(entry, str) else tuple(entry))
    return out


def _assert_divides(name, shape, spec):
    assert len(spec) <= len(shape), (name, shape, spec)
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = prod(_AXIS_SIZES[a] for a in axes)
        assert shape[i] % size == 0, \
            f"{name}: dim {i} of {shape} not divisible by {axes}={size}"


def _abstract_model(arch):
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(partial(model.init, tp=_TP),
                            jax.random.PRNGKey(0))
    cache = jax.eval_shape(partial(model.init_cache, tp=_TP, batch=4,
                                   max_len=16))
    return cfg, params, cache


def test_param_rules():
    assert spec_for_param("layers/wq/w", 3) == P(None, None, "model")
    assert spec_for_param("layers/wo/w", 3) == P(None, "model", None)
    assert spec_for_param("embed/emb", 2) == P("model", None)
    assert spec_for_param("layers/moe/experts/up", 4) == P(None, "model", None, None)
    assert spec_for_param("layers/ln1/scale", 2) == P(None, None)
    assert spec_for_param("layers/mlp/up/w", 3) == P(None, None, "model")
    assert spec_for_param("layers/wx/w", 3) == P(None, None, "model")


def test_padded_dims():
    from repro.configs import get_config

    pd = get_config("internvl2-1b").padded(16)
    assert pd.n_heads == 16 and pd.n_kv_rep == 16 and pd.q_group == 1
    pd = get_config("mistral-nemo-12b").padded(16)
    assert pd.n_heads == 32 and pd.n_kv_rep == 16 and pd.q_group == 2
    pd = get_config("qwen2-moe-a2.7b").padded(16)
    assert pd.n_experts == 64
    pd = get_config("granite-moe-3b-a800m").padded(16)
    assert pd.n_heads == 32 and pd.n_experts == 48
    # single-device (tests): no padding
    pd1 = get_config("internvl2-1b").padded(1)
    assert pd1.n_heads == 14 and pd1.n_kv_rep == 2


# ---------------------------------------------------------------------------
# real trees: all three LM families + the stream workload (ISSUE 9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", _FAMILIES)
def test_param_rules_cover_real_trees(arch):
    """Every param leaf of the real init tree resolves to a spec whose
    sharded axes divide the leaf dims on the (2, 4) dry-run mesh."""
    cfg, params, _ = _abstract_model(arch)
    specs = partition_params(params, cfg.family)
    n = 0
    for name, shape, spec in _entries(params, specs):
        _assert_divides(name, shape, spec)
        n += 1
    assert n > 0


@pytest.mark.parametrize("arch", _FAMILIES)
def test_no_silent_replicated_weight_matrices(arch):
    """Weight-matrix leaves (projections, embeddings, expert stacks) must
    shard over ``model`` — a replicated fallthrough would silently waste
    the whole tensor-parallel axis."""
    _MATRIX_LEAVES = {"w", "emb", "up", "gate", "down"}
    cfg, params, _ = _abstract_model(arch)
    specs = partition_params(params, cfg.family)
    checked = 0
    for name, shape, spec in _entries(params, specs):
        parts = name.lower().split("/")
        leaf, module = parts[-1], parts[-2] if len(parts) >= 2 else ""
        if leaf not in _MATRIX_LEAVES or len(shape) < 2:
            continue
        if module in ("router", "conv") or leaf == "conv":
            continue   # deliberately replicated (small, latency-bound)
        assert "model" in _spec_axes(spec), \
            f"{name} {shape} fell through to replicated: {spec}"
        checked += 1
    assert checked >= 3   # non-vacuous: every family has projections


@pytest.mark.parametrize("arch", _FAMILIES)
def test_cache_rules_cover_real_trees(arch):
    """Decode-cache leaves (KV stacks, SSM states, conv tails) resolve to
    specs that divide the real init_cache shapes; the per-slot batch dim
    shards over the data axes."""
    cfg, _, cache = _abstract_model(arch)
    specs = partition_cache(cache, cfg.family)
    n = 0
    for name, shape, spec in _entries(cache, specs):
        _assert_divides(name, shape, spec)
        n += 1
    assert n > 0


def test_stream_state_partition_covers_real_tree():
    from repro.serve.stream import StreamAdapter

    ad = StreamAdapter()
    state = jax.eval_shape(partial(ad.init_state, batch=4, max_len=0))
    specs = partition_cache(state, "stream")
    for name, shape, spec in _entries(state, specs):
        _assert_divides(name, shape, spec)
    pspecs = partition_params(ad.init_params(), "stream")
    for name, shape, spec in _entries(ad.init_params(), pspecs):
        _assert_divides(name, shape, spec)


@pytest.mark.slow
def test_micro_mesh_dryrun_subprocess():
    """Lower+compile the smoke tinyllama train step on a 2x4 fake mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from functools import partial
from repro.configs import get_config
from repro.dist import meshctx, sharding
from repro.models import build_model
from repro.train import step as step_mod
import jax.numpy as jnp

mesh = meshctx.make_mesh((2, 4), ("data", "model"))
meshctx.set_mesh(mesh)
cfg = get_config("tinyllama-1.1b-smoke")
m = build_model(cfg)
state_sds = jax.eval_shape(partial(step_mod.init_state, m, tp=4), jax.random.PRNGKey(0))
pspecs = sharding.partition_params(state_sds.params, cfg.family)
sspecs = step_mod.TrainState(pspecs, sharding.partition_opt_state(state_sds.opt, pspecs), jax.sharding.PartitionSpec())
batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
bspecs = sharding.partition_batch(batch)
scfg = step_mod.StepConfig(remat="full")
fn = partial(step_mod.train_step, m, scfg, tp=4)
j = jax.jit(fn, in_shardings=(sharding.named(sspecs, mesh), sharding.named(bspecs, mesh)), donate_argnums=(0,))
c = j.lower(state_sds, batch).compile()
assert c.memory_analysis().temp_size_in_bytes > 0
print("MICRO_DRYRUN_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "MICRO_DRYRUN_OK" in r.stdout, r.stderr[-2000:]
