import numpy as np

from repro.core import error_analysis as ea


def test_exact_multiplier_reports_zero():
    rep = ea.evaluate_exhaustive(lambda a, b: a * b, 6)
    assert rep.mred == 0 and rep.error_rate == 0 and rep.pred2 == 1.0


def test_constant_bias_detected():
    rep = ea.evaluate_sampled(lambda a, b: a * b + 100, 8, num=4096)
    assert rep.error_rate == 1.0 and rep.mean_err > 0


def test_pred2_semantics():
    rep = ea.evaluate_sampled(lambda a, b: (a * b * 1.01).astype(np.int64),
                              8, num=4096)
    assert rep.pred2 > 0.95  # 1% error is within 2% threshold


# ---- PSNR / SSIM / SNR (stream-workload quality metrics, ISSUE 7) ---------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st


def _ref_signal(n=256):
    t = np.arange(n, dtype=np.float64)
    return 100.0 * np.sin(0.07 * t) + 20.0 * np.cos(0.31 * t)


@given(st.integers(1, 50), st.integers(51, 120))
@settings(max_examples=16, deadline=None)
def test_psnr_monotone_in_mse(a, b):
    """Larger perturbation -> larger MSE -> strictly smaller PSNR."""
    ref = _ref_signal()
    noise = np.sign(np.sin(np.arange(ref.size)))      # deterministic +-1
    xa, xb = ref + a * noise, ref + b * noise
    assert ea.mse(ref, xa) < ea.mse(ref, xb)
    assert ea.psnr_db(ref, xa) > ea.psnr_db(ref, xb)


def test_psnr_finite_and_capped_on_identical():
    ref = _ref_signal()
    v = ea.psnr_db(ref, ref)
    assert np.isfinite(v) and v == 180.0              # floored MSE ceiling


def test_ssim_identical_is_one():
    ref = _ref_signal()
    assert ea.ssim(ref, ref) == 1.0


def test_metrics_finite_on_constant_signals():
    const = np.full(128, 7.0)
    assert np.isfinite(ea.psnr_db(const, const))
    assert np.isfinite(ea.ssim(const, const))
    assert ea.ssim(const, const) == 1.0
    # constant vs different constant: zero variance everywhere, the
    # stabilizing constants keep SSIM finite (and below 1)
    other = np.full(128, 9.0)
    assert np.isfinite(ea.ssim(const, other))
    assert ea.ssim(const, other) < 1.0
    assert np.isfinite(ea.psnr_db(const, other))


def test_ssim_degrades_with_noise():
    ref = _ref_signal()
    noisy = ref + 30.0 * np.sign(np.cos(np.arange(ref.size)))
    assert ea.ssim(ref, noisy) < ea.ssim(ref, ref)


def test_snr_db_matches_shared_formula():
    """snr_db is the single home of the helper bench_dsp/dsp_pipeline
    previously duplicated."""
    ref = _ref_signal()
    x = ref + 5.0
    err = ref - x
    want = 10 * np.log10((ref ** 2).mean() / (err ** 2).mean())
    assert abs(ea.snr_db(ref, x) - want) < 1e-12
