import numpy as np

from repro.core import error_analysis as ea


def test_exact_multiplier_reports_zero():
    rep = ea.evaluate_exhaustive(lambda a, b: a * b, 6)
    assert rep.mred == 0 and rep.error_rate == 0 and rep.pred2 == 1.0


def test_constant_bias_detected():
    rep = ea.evaluate_sampled(lambda a, b: a * b + 100, 8, num=4096)
    assert rep.error_rate == 1.0 and rep.mean_err > 0


def test_pred2_semantics():
    rep = ea.evaluate_sampled(lambda a, b: (a * b * 1.01).astype(np.int64),
                              8, num=4096)
    assert rep.pred2 > 0.95  # 1% error is within 2% threshold
