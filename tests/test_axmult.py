"""Multiplier-family error properties (Ch. 4-6 claims)."""
import numpy as np
import pytest

from repro.core import axmult, error_analysis as ea


def test_rad_relative_error_independent_of_A():
    """Ch. 4 key property: RED depends only on the encoded operand."""
    n, k = 16, 8
    rng = np.random.default_rng(0)
    b = rng.integers(-2**15, 2**15, 64)
    for a1, a2 in [(3, 1000), (-7, 12345)]:
        p1 = axmult.np_mult_rad(np.full_like(b, a1), b, n, k)
        p2 = axmult.np_mult_rad(np.full_like(b, a2), b, n, k)
        nz = b != 0
        r1 = (p1[nz] - a1 * b[nz]) / (a1 * b[nz])
        r2 = (p2[nz] - a2 * b[nz]) / (a2 * b[nz])
        np.testing.assert_allclose(r1, r2, rtol=1e-12)


def test_rad_mred_monotone_in_k_and_within_paper_band():
    reps = {k: ea.rad_operand_marginal(16, k) for k in (4, 6, 8, 10)}
    ms = [reps[k].mred for k in (4, 6, 8, 10)]
    assert ms == sorted(ms)
    assert ms[-1] < 0.03  # "mean relative error up to ~2%" band
    for r in reps.values():
        assert abs(r.mean_err) < 1e-6  # near-zero-mean error distribution


def test_rad_marginal_matches_full_simulation():
    n, k = 12, 6
    marg = ea.rad_operand_marginal(n, k)
    full = ea.evaluate_exhaustive(
        lambda a, b: axmult.np_mult_rad(a, b, n, k), 8) if False else None
    samp = ea.evaluate_sampled(
        lambda a, b: axmult.np_mult_rad(a, b, n=n, k=k), n, num=1 << 16)
    assert abs(marg.mred - samp.mred) / max(marg.mred, 1e-12) < 0.1


@pytest.mark.parametrize("p,r", [(0, 0), (1, 0), (0, 4), (2, 4)])
def test_pr_exactness_and_monotonicity(p, r):
    n = 16
    rep = ea.evaluate_sampled(
        lambda a, b: axmult.np_mult_pr(a, b, n=n, p=p, r=r), n, num=1 << 14)
    if p == 0 and r == 0:
        assert rep.mred == 0.0
    else:
        assert 0 < rep.mred < 0.1


def test_pr_error_grows_with_degree():
    n = 16
    m = lambda p, r: ea.evaluate_sampled(
        lambda a, b: axmult.np_mult_pr(a, b, n=n, p=p, r=r), n, num=1 << 14).mred
    assert m(1, 0) < m(2, 0) < m(4, 0)
    assert m(0, 2) < m(0, 6) < m(0, 10)


def test_dynamic_matches_static():
    import jax.numpy as jnp

    n = 16
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    b = jnp.asarray(rng.integers(-2**15, 2**15, 2048), jnp.int32)
    for p, r in [(0, 0), (2, 4), (4, 8)]:
        stat = axmult.mult_pr(a, b, n, p, r)
        dyn = axmult.pr_multiply_dynamic(a, b, n, jnp.int32(p), jnp.int32(r))
        assert (np.asarray(stat) == np.asarray(dyn)).all()


def test_axfpu_fp32_truncation_only_error():
    rng = np.random.default_rng(4)
    a = (rng.standard_normal(20000) * 5).astype(np.float32)
    b = (rng.standard_normal(20000) * 5).astype(np.float32)
    out = axmult.np_axfpu_multiply(a, b, 0, 0)
    rel = np.abs(out.astype(np.float64) - a.astype(np.float64) * b.astype(np.float64))
    rel /= np.abs(a.astype(np.float64) * b.astype(np.float64))
    assert rel.max() < 2**-22  # <= 1 ulp truncation


def test_axfpu_bf16_ingraph_matches_numpy_semantics():
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    a = (rng.standard_normal(4096) * 3).astype(np.float32)
    b = (rng.standard_normal(4096) * 3).astype(np.float32)
    y = axmult.axfpu_multiply(jnp.asarray(a, jnp.bfloat16),
                              jnp.asarray(b, jnp.bfloat16), "bf16", p=1, r=2)
    exact = a.astype(np.float64) * b.astype(np.float64)
    rel = np.abs(np.asarray(y, np.float64) - exact) / np.maximum(np.abs(exact), 1e-12)
    assert np.median(rel) < 0.05


def test_roup_between_components():
    """ROUP(k, p, r) error should exceed pure RAD(k) and pure PR(p, r)."""
    n = 16
    e = lambda f: ea.evaluate_sampled(f, n, num=1 << 14).mred
    m_rad = e(lambda a, b: axmult.np_mult_rad(a, b, n=n, k=6))
    m_roup = e(lambda a, b: axmult.np_mult_roup(a, b, n=n, k=6, p=1, r=4))
    assert m_roup >= m_rad * 0.9
