"""Bit-exact validation of the paper's encodings (Ch. 3-5 definitions)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.core import encodings as enc


def signed_range(n):
    return jnp.arange(-(1 << (n - 1)), 1 << (n - 1), dtype=jnp.int32)


@pytest.mark.parametrize("n", [4, 8, 10, 16])
def test_booth_recombination_identity(n):
    if n == 16:
        v = jnp.asarray(np.random.default_rng(0).integers(-2**15, 2**15, 4096), jnp.int32)
    else:
        v = signed_range(n)
    assert (enc.recombine_radix4(enc.booth_digits(v, n)) == v).all()


def test_dlsb_equivalence_exhaustive_8bit():
    n = 8
    v = signed_range(n)
    a, b = jnp.meshgrid(v, v, indexing="ij")
    for ap in (0, 1):
        for bp in (0, 1):
            apv, bpv = jnp.full_like(a, ap), jnp.full_like(b, bp)
            ref = (a + ap) * (b + bp)
            assert (enc.mult_dlsb_straightforward(a, apv, b, bpv, n) == ref).all()
            assert (enc.mult_dlsb_sophisticated(a, apv, b, bpv, n) == ref).all()


@given(st.integers(-2**15, 2**15 - 1), st.integers(-2**15, 2**15 - 1),
       st.integers(0, 1), st.integers(0, 1))
@settings(max_examples=200, deadline=None)
def test_dlsb_equivalence_property_16bit(a, b, ap, bp):
    aj = jnp.asarray([a], jnp.int32)
    bj = jnp.asarray([b], jnp.int32)
    apv, bpv = jnp.asarray([ap], jnp.int32), jnp.asarray([bp], jnp.int32)
    ref = (a + ap) * (b + bp)
    assert int(enc.mult_dlsb_sophisticated(aj, apv, bj, bpv, 16)[0]) == ref


@pytest.mark.parametrize("p", [1, 2, 3, 4])
def test_perforation_equals_digit_drop(p):
    n = 10
    v = signed_range(n)
    d = enc.booth_digits(v, n).at[..., :p].set(0)
    assert (enc.perforate_operand(v, n, p) == enc.recombine_radix4(d)).all()


def test_perforation_rounding_identity_at_zero_degree():
    v = signed_range(8)
    assert (enc.perforate_operand(v, 8, 0) == v).all()
    assert (enc.round_operand(v, 0) == v).all()


@given(st.integers(-2**15, 2**15 - 1), st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_rounding_is_nearest_multiple(a, r):
    got = int(enc.round_operand(jnp.asarray([a], jnp.int32), r)[0])
    assert got % (1 << r) == 0
    assert abs(got - a) <= (1 << (r - 1))


@pytest.mark.parametrize("k", [4, 6, 8])
def test_rad_digit_set(k):
    """Approximate high-radix digit lands in {0, +-2^(k-4..k-1)} (Table 4.2)."""
    n = 16
    v = jnp.asarray(np.random.default_rng(1).integers(-2**15, 2**15, 8192), jnp.int32)
    y0 = enc.highradix_digit(v, n, k)
    y0h = enc.approx_highradix_digit(y0, k)
    allowed = {0} | {s * (1 << e) for s in (1, -1) for e in range(k - 4, k)}
    assert set(np.unique(np.asarray(y0h))).issubset(allowed)


def test_rad_jnp_matches_numpy_mirror():
    n, k = 16, 8
    v = np.random.default_rng(2).integers(-2**15, 2**15, 8192)
    got = np.asarray(enc.rad_encode(jnp.asarray(v, jnp.int32), n, k))
    ref = enc.np_rad_encode(v, n, k)
    assert (got == ref).all()


def test_pow2_snap():
    x = jnp.asarray([0.0, 0.7, 1.0, 3.0, -5.0, 100.0])
    y = np.asarray(enc.pow2_snap(x))
    for v in y[np.nonzero(y)]:
        assert np.log2(abs(v)) == round(np.log2(abs(v)))
