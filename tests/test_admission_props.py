"""Property tests over the admission pipeline (ISSUE 10 satellites 1-2).

Hypothesis-style properties (the container has no hypothesis wheel, so the
deterministic _hypothesis_fallback shim drives the draws):

  * bucket-padded prefill is BIT-identical to exact-length prefill across
    all three LM families (dense / SSM / hybrid) — cache contents AND the
    greedy decode continuation;
  * packed multi-row admission is bit-identical to sequential admission
    for random packings;
  * chunked prefill interleaved with decode preserves exactly-once
    {ok,failed,shed,deadline} accounting and same-seed determinism under
    a fault storm.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.resil import FaultPlan, FaultSpec, ServePolicy, VirtualClock
from repro.serve.admission import AdmissionConfig
from repro.serve.engine import ServeEngine

FAMILIES = ["tinyllama-1.1b-smoke", "mamba2-370m-smoke",
            "recurrentgemma-2b-smoke"]

# Many-example property sweeps over three model families: minutes on CPU.
# Tier-1 (`pytest -q`) runs them; CI's fast lane deselects with -m 'not slow'.
pytestmark = pytest.mark.slow

_CACHE: dict = {}


def _setup(arch):
    if arch not in _CACHE:
        cfg = get_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[arch] = (m, params)
    return _CACHE[arch]


def _assert_cache_equal(a, b, msg=""):
    for name in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=f"{msg}: cache.{name}")


# ---------------------------------------------------------------------------
# bucket-padded prefill == exact-length prefill, bit for bit (satellite 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILIES)
def test_padded_bucket_prefill_bit_identical(arch):
    m, params = _setup(arch)
    Pb, slots, max_len = 16, 4, 32

    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        lens = rng.integers(1, Pb + 1, 3)
        rows = [rng.integers(1, m.cfg.vocab, int(n)).astype(np.int32)
                for n in lens]
        # exact: one sequential prefill per row into its slot
        exact = m.init_cache(tp=1, batch=slots, max_len=max_len)
        for i, row in enumerate(rows):
            _, exact = m.prefill(params, exact,
                                 jnp.asarray(row), jnp.asarray(i, jnp.int32),
                                 tp=1)
        # padded: one bucketed call, every row padded to Pb
        toks = np.zeros((len(rows), Pb), np.int32)
        for i, row in enumerate(rows):
            toks[i, :row.size] = row
        padded = m.prefill_batch(
            params, m.init_cache(tp=1, batch=slots, max_len=max_len),
            jnp.asarray(toks), jnp.arange(len(rows), dtype=jnp.int32),
            jnp.asarray(lens, jnp.int32), tp=1)
        _assert_cache_equal(exact, padded, f"{arch} seed={seed}")
        # the decode continuation must also agree bit-for-bit
        nxt = rng.integers(1, m.cfg.vocab, (slots, 1)).astype(np.int32)
        le, _ = m.decode_step(params, exact, jnp.asarray(nxt), tp=1)
        lp, _ = m.decode_step(params, padded, jnp.asarray(nxt), tp=1)
        np.testing.assert_array_equal(np.asarray(le), np.asarray(lp),
                                      err_msg=f"{arch} decode seed={seed}")

    prop()


@pytest.mark.parametrize("arch", FAMILIES)
def test_dummy_pack_rows_leave_cache_untouched(arch):
    """Out-of-bounds dummy rows (slot = batch) must be dropped entirely by
    scatter.  Both calls run the SAME (pack=3, bucket=16) executable — only
    the dummy rows' garbage content differs — so the caches must be
    bit-identical: dummy content can never influence served state."""
    m, params = _setup(arch)
    Pb, slots, max_len = 16, 3, 32

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        row = rng.integers(1, m.cfg.vocab, 7).astype(np.int32)
        slot_vec = jnp.asarray([1, slots, slots], jnp.int32)  # OOB dummies
        len_vec = jnp.asarray([7, 0, 0], jnp.int32)
        caches = []
        for _ in range(2):                 # two different garbage fills
            toks = np.zeros((3, Pb), np.int32)
            toks[0, :7] = row
            toks[1:] = rng.integers(1, m.cfg.vocab, (2, Pb))
            caches.append(m.prefill_batch(
                params, m.init_cache(tp=1, batch=slots, max_len=max_len),
                jnp.asarray(toks), slot_vec, len_vec, tp=1))
        _assert_cache_equal(caches[0], caches[1], f"{arch} seed={seed}")
        # and the real row still decodes: scatter dropped rows, not data
        nxt = rng.integers(1, m.cfg.vocab, (slots, 1)).astype(np.int32)
        l0, _ = m.decode_step(params, caches[0], jnp.asarray(nxt), tp=1)
        l1, _ = m.decode_step(params, caches[1], jnp.asarray(nxt), tp=1)
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1),
                                      err_msg=f"{arch} decode seed={seed}")

    prop()


# ---------------------------------------------------------------------------
# packed admission == sequential admission at the engine (satellite 1)
# ---------------------------------------------------------------------------


def test_packed_admission_bit_identical_to_sequential():
    m, params = _setup("tinyllama-1.1b-smoke")

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(1, m.cfg.vocab,
                                int(rng.integers(2, 30))).astype(np.int32)
                   for _ in range(6)]
        outs = {}
        for pack in (1, 3):
            adm = AdmissionConfig(pack=pack, warmup=False)
            eng = ServeEngine(m, params, slots=4, max_len=64, seed=13,
                              admission=adm, emitter=False)
            reqs = [eng.submit(p, 4) for p in prompts]
            eng.run_until_drained()
            outs[pack] = [r.out for r in reqs]
        assert outs[1] == outs[3], f"seed={seed}"

    prop()


# ---------------------------------------------------------------------------
# chunked prefill: exactly-once accounting + determinism (satellite 2)
# ---------------------------------------------------------------------------


def test_chunked_storm_exactly_once_and_deterministic():
    m, params = _setup("tinyllama-1.1b-smoke")
    adm = AdmissionConfig(pack=2, chunk_tokens=8, warmup=False)

    def run(storm_seed):
        clock = VirtualClock()
        eng = ServeEngine(
            m, params, slots=2, max_len=64, seed=3, admission=adm,
            emitter=False, clock=clock,
            faults=FaultPlan(FaultSpec(nan=0.15, drop=0.1),
                             seed=storm_seed),
            policy=ServePolicy(max_retries=8, backoff_ms=0.01))
        rng = np.random.default_rng(42)
        reqs = []
        for ln in (3, 50, 5, 40, 2):      # two chunked long prompts
            reqs.append(eng.submit(
                rng.integers(1, m.cfg.vocab, ln).astype(np.int32), 3))
        for _ in range(400):
            eng.tick()
            clock.advance(0.001)
            if all(r.done for r in reqs):
                break
        return eng, reqs

    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def prop(storm_seed):
        eng, reqs = run(storm_seed)
        # exactly-once: every request terminates once, with a valid status
        assert all(r.done for r in reqs)
        assert len(eng.done) == len(reqs)
        assert len({r.rid for r in eng.done}) == len(reqs)
        assert {r.status for r in reqs} <= {"ok", "failed", "shed",
                                            "deadline"}
        for r in reqs:
            assert len(r.out) <= r.budget
            if r.status == "ok":
                assert len(r.out) == 3
        # same-seed determinism: identical recovery trace and outputs
        eng2, reqs2 = run(storm_seed)
        assert eng2.resil_log == eng.resil_log
        assert [r.out for r in reqs2] == [r.out for r in reqs]
        assert eng2.faults.injected == eng.faults.injected

    prop()


def test_quarantine_mid_chunk_rewinds_cursor():
    """A guard trip against a request whose slot already finished chunked
    admission must rewind cursor to zero — the retry re-admits from
    scratch, bit-identical to a fresh run."""
    from repro.resil import FaultEvent

    m, params = _setup("tinyllama-1.1b-smoke")
    adm = AdmissionConfig(chunk_tokens=8, warmup=False)
    # nan lands on the first decode tick AFTER the 4-call chunked admission
    events = [FaultEvent(tick=5, kind="nan", slot=0, value=float("nan"))]
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=5, admission=adm,
                      emitter=False, faults=FaultPlan(events=events),
                      policy=ServePolicy(backoff_ms=0.01))
    prompt = np.random.default_rng(8).integers(
        1, m.cfg.vocab, 30).astype(np.int32)
    req = eng.submit(prompt, 4)
    eng.run_until_drained()
    assert req.status == "ok" and req.retries == 1
    events_seen = [n for _, n, _ in eng.resil_log]
    assert "retry" in events_seen
    ref = ServeEngine(m, params, slots=1, max_len=64, seed=5, admission=adm,
                      emitter=False)
    rr = ref.submit(prompt, 4)
    ref.run_until_drained()
    assert req.out == rr.out              # recovery == never-faulted run
