"""Fault-tolerance behaviours: preemption checkpoint, restart-resume,
straggler flagging."""
import shutil
import tempfile

import jax

from repro.configs import get_config
from repro.data.pipeline import make_pipeline
from repro.models import build_model
from repro.train import step as step_mod
from repro.train.trainer import StragglerWatchdog, Trainer, TrainerConfig


def _mk(tmp, total=20, ckpt_every=50):
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    pipe = make_pipeline(cfg, seq_len=16, global_batch=2)
    return Trainer(
        m, step_mod.StepConfig(remat="none", total_steps=total, warmup=2),
        TrainerConfig(total_steps=total, ckpt_every=ckpt_every, ckpt_dir=tmp,
                      log_every=1000),
        pipe)


class _PreemptingPipeline:
    """Raises the trainer's preemption flag at a given step (stands in for
    SIGTERM from the cluster scheduler)."""

    def __init__(self, inner, trainer_box, at_step):
        self.inner = inner
        self.box = trainer_box
        self.at = at_step

    def batch_at(self, step):
        if step >= self.at:
            self.box[0]._preempted = True
        return self.inner.batch_at(step)


def test_preemption_checkpoints_and_exits():
    tmp = tempfile.mkdtemp()
    try:
        t = _mk(tmp, total=50, ckpt_every=100)
        box = [t]
        t.pipeline = _PreemptingPipeline(t.pipeline, box, at_step=3)
        out = t.run()
        assert out["preempted"]
        assert out["final_step"] <= 5
        assert t.ckpt.latest_valid_step() == out["final_step"]
        # restart resumes from the preemption point
        t2 = _mk(tmp, total=8, ckpt_every=100)
        out2 = t2.run()
        assert out2["history"][0]["step"] == out["final_step"]
        assert out2["final_step"] == 8
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_straggler_watchdog_flags_outliers():
    w = StragglerWatchdog(factor=2.0)
    for i in range(20):
        assert not w.observe(i, 0.1)
    assert w.observe(20, 0.5)
    assert w.flagged and w.flagged[0][0] == 20
