import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# for the _hypothesis_fallback shim (tests/ has no __init__.py)
sys.path.insert(0, os.path.dirname(__file__))
