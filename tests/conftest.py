import os
import signal
import sys
import threading

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# for the _hypothesis_fallback shim (tests/ has no __init__.py)
sys.path.insert(0, os.path.dirname(__file__))

# Per-test wall-clock limit for the fast suite (seconds; 0 disables).  A
# hung test — a drain loop that never drains, a deadlocked thread — fails
# with a TimeoutError and a clean traceback instead of eating the CI job's
# whole 30-minute budget.  `slow`-marked tests are exempt; hangs inside
# long-running C calls are covered by pytest's faulthandler_timeout dump
# (pyproject.toml) since SIGALRM only interrupts Python-level execution.
_TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    limit = 0 if item.get_closest_marker("slow") else _TEST_TIMEOUT_S
    if (limit > 0 and hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread()):
        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded {limit}s (REPRO_TEST_TIMEOUT_S)")

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.alarm(limit)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
    else:
        yield
