import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dynamic import QoSController
from repro.models import build_model
from repro.serve.engine import ServeEngine

FAMILIES = ["tinyllama-1.1b-smoke", "mamba2-370m-smoke", "recurrentgemma-2b-smoke"]

_CACHE: dict = {}


def _setup(arch: str = "tinyllama-1.1b-smoke"):
    if arch not in _CACHE:
        cfg = get_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[arch] = (m, params)
    return _CACHE[arch]


def test_drains_queue():
    m, params = _setup()
    eng = ServeEngine(m, params, slots=2, max_len=64)
    reqs = [eng.submit(np.array([1, 2, 3]), max_new_tokens=4) for _ in range(5)]
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_slot_isolation():
    """A request's output must not depend on co-batched requests."""
    m, params = _setup()
    prompt = np.array([5, 6, 7, 8])
    solo = ServeEngine(m, params, slots=2, max_len=64)
    solo.submit(prompt, max_new_tokens=5)
    ref = solo.run_until_drained()[0].out_tokens

    busy = ServeEngine(m, params, slots=2, max_len=64)
    busy.submit(np.array([9, 10]), max_new_tokens=5)
    busy.submit(prompt, max_new_tokens=5)
    busy.submit(np.array([11, 12, 13]), max_new_tokens=5)
    done = busy.run_until_drained()
    got = [r for r in done if r.prompt.tolist() == prompt.tolist()][0].out_tokens
    assert got == ref, (got, ref)


@pytest.mark.parametrize("arch", FAMILIES)
def test_slot_reuse_after_free(arch):
    """A request admitted into a previously-freed slot must produce tokens
    bit-identical to a solo run on a fresh engine (stale-slot regression)."""
    m, params = _setup(arch)
    eng = ServeEngine(m, params, slots=2, max_len=64)
    eng.submit(np.array([9, 10, 11]), max_new_tokens=6)
    eng.submit(np.array([3, 4]), max_new_tokens=6)
    eng.run_until_drained()          # both slots now used and freed
    prompt = np.array([5, 6, 7, 8])
    reused = eng.submit(prompt, max_new_tokens=6)
    eng.run_until_drained()

    fresh = ServeEngine(m, params, slots=2, max_len=64)
    solo = fresh.submit(prompt, max_new_tokens=6)
    fresh.run_until_drained()
    assert reused.out_tokens == solo.out_tokens, (reused.out_tokens,
                                                  solo.out_tokens)


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_matches_teacher_forced(arch):
    """Fused prefill's cache region + last-position logits must agree with
    teacher-forcing the prompt through per-token decode steps."""
    m, params = _setup(arch)
    slots, slot = 3, 1
    prompt = np.array([5, 6, 7, 8, 9], np.int32)
    cache_ref = m.init_cache(tp=1, batch=slots, max_len=64)
    toks = np.zeros((slots, 1), np.int32)
    logits_ref = None
    for t in prompt:
        toks[slot, 0] = t
        logits_ref, cache_ref = m.decode_step(params, cache_ref,
                                              jnp.asarray(toks))
    cache_pf = m.init_cache(tp=1, batch=slots, max_len=64)
    lp, cache_pf = m.prefill(params, cache_pf, jnp.asarray(prompt),
                             jnp.int32(slot))
    lr = np.asarray(logits_ref)[slot, 0]
    lp = np.asarray(lp)[0]
    assert int(np.asarray(cache_pf.length)[slot]) == len(prompt)
    # prefill touches only the target slot's metadata
    assert np.asarray(cache_pf.length)[[0, 2]].tolist() == [0, 0]
    assert lp.argmax() == lr.argmax()
    np.testing.assert_allclose(lp, lr, atol=0.1)
    # the caches must agree under continued decode, not just at the boundary
    toks[slot, 0] = int(lr.argmax())
    l2r, _ = m.decode_step(params, cache_ref, jnp.asarray(toks))
    l2p, _ = m.decode_step(params, cache_pf, jnp.asarray(toks))
    a, b = np.asarray(l2r)[slot, 0], np.asarray(l2p)[slot, 0]
    assert a.argmax() == b.argmax()
    np.testing.assert_allclose(a, b, atol=0.1)


def test_free_slots_masked():
    """Slots never admitted must not advance: their cache region stays at
    the init state while other slots serve."""
    m, params = _setup()
    eng = ServeEngine(m, params, slots=3, max_len=64)
    eng.submit(np.array([5, 6, 7, 8]), max_new_tokens=5)
    eng.run_until_drained()
    lengths = np.asarray(eng.cache.length)
    assert lengths[1] == 0 and lengths[2] == 0, lengths


def test_sampling_deterministic():
    """temperature/top-k sampling is reproducible from the engine seed."""
    m, params = _setup()
    kw = dict(slots=2, max_len=64, greedy=False, temperature=0.8, top_k=5)
    a = ServeEngine(m, params, seed=7, **kw)
    b = ServeEngine(m, params, seed=7, **kw)
    ra = a.submit(np.array([5, 6, 7, 8]), max_new_tokens=8)
    rb = b.submit(np.array([5, 6, 7, 8]), max_new_tokens=8)
    a.run_until_drained()
    b.run_until_drained()
    assert ra.out_tokens == rb.out_tokens
    c = ServeEngine(m, params, seed=8, **kw)
    rc = c.submit(np.array([5, 6, 7, 8]), max_new_tokens=8)
    c.run_until_drained()
    # 8 draws from a 5-way top-k at T=0.8: collision with seed 7 is ~0
    assert rc.out_tokens != ra.out_tokens


def test_rid_unique_with_inflight():
    """rids stay unique while requests are in flight (monotone counter; the
    old len(queue)+len(done) scheme collided once slots held requests)."""
    m, params = _setup()
    eng = ServeEngine(m, params, slots=2, max_len=64)
    r0 = eng.submit(np.array([1, 2]), max_new_tokens=6)
    eng.tick()                        # r0 admitted: queue and done both empty
    r1 = eng.submit(np.array([3, 4]), max_new_tokens=6)
    r2 = eng.submit(np.array([5, 6]), max_new_tokens=6)
    eng.run_until_drained()
    rids = [r0.rid, r1.rid, r2.rid]
    assert len(set(rids)) == 3, rids
    assert rids == sorted(rids)


def test_eos_not_emitted_not_charged():
    """Hitting eos_id finishes the request without emitting the EOS token or
    charging it against max_new_tokens; eos_id=-1 (default) disables EOS."""
    m, params = _setup()
    probe = ServeEngine(m, params, slots=1, max_len=64)
    r = probe.submit(np.array([5, 6, 7, 8]), max_new_tokens=6)
    probe.run_until_drained()
    assert len(r.out_tokens) == 6     # eos disabled: full budget generated
    eos = r.out_tokens[2]
    eng = ServeEngine(m, params, slots=1, max_len=64, eos_id=eos)
    r2 = eng.submit(np.array([5, 6, 7, 8]), max_new_tokens=6)
    eng.run_until_drained()
    assert r2.done
    assert eos not in r2.out_tokens
    assert r2.out_tokens == r.out_tokens[:r.out_tokens.index(eos)]


def test_prompt_capacity_rejected_at_submit():
    """Oversized prompts fail loudly at submit (a mid-tick failure would
    drop the request after it left the queue); dense-attention capacity is
    max_len, stateful families are unbounded."""
    m, params = _setup()
    eng = ServeEngine(m, params, slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(np.arange(20), max_new_tokens=4)
    assert not eng.queue
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32))
    m2, params2 = _setup("mamba2-370m-smoke")
    ssm_eng = ServeEngine(m2, params2, slots=1, max_len=16)
    r = ssm_eng.submit(np.arange(20) % 100, max_new_tokens=3)
    ssm_eng.run_until_drained()
    assert len(r.out_tokens) == 3


def test_first_token_eos_excluded_from_ttft():
    """A request that EOSes before emitting anything reports no first-token
    time and is excluded from the TTFT aggregate."""
    m, params = _setup()
    from repro.serve.metrics import summarize

    probe = ServeEngine(m, params, slots=1, max_len=64)
    r = probe.submit(np.array([5, 6, 7, 8]), max_new_tokens=3)
    probe.run_until_drained()
    eng = ServeEngine(m, params, slots=1, max_len=64, eos_id=r.out_tokens[0])
    r2 = eng.submit(np.array([5, 6, 7, 8]), max_new_tokens=3)
    eng.run_until_drained()
    assert r2.done and r2.out_tokens == []
    assert r2.t_first_token == 0.0
    s = summarize([r2])
    assert s["ttft_p50_ms"] == 0.0


def test_qos_degree_moves_with_load():
    """Overload drives the DyFXU degree down the ladder; the traced degree
    does not change greedy outputs under the (EXACT) default policy."""
    m, params = _setup()
    base = ServeEngine(m, params, slots=2, max_len=64)
    refs = [base.submit(np.array([1, 2, 3]), 8) for _ in range(6)]
    base.run_until_drained()

    qos = QoSController(ladder=[{"ebits": 8}, {"ebits": 6}],
                        low_water=0.5, high_water=0.9, cooldown_steps=0)
    eng = ServeEngine(m, params, slots=2, max_len=64, qos=qos)
    outs = [eng.submit(np.array([1, 2, 3]), 8) for _ in range(6)]
    eng.run_until_drained()
    # history entries are tuple-normalized at record time (a global scalar
    # ladder records 1-tuples — core.dynamic.degree_record(as_tuple=True))
    ebits_seen = {e for _, e in eng.stats.degree_history}
    assert (6,) in ebits_seen         # overloaded -> approximated harder
    assert [r.out_tokens for r in outs] == [r.out_tokens for r in refs]


def test_metrics_accounting():
    m, params = _setup()
    from repro.serve.metrics import summarize

    eng = ServeEngine(m, params, slots=2, max_len=64)
    for _ in range(3):
        eng.submit(np.array([1, 2, 3, 4]), max_new_tokens=5)
    done = eng.run_until_drained()
    s = summarize(done, eng.stats, wall_s=1.0)
    assert s["requests"] == 3
    assert s["generated_tokens"] == 15
    assert s["prompt_tokens"] == 12
    assert s["engine_prefill_tokens"] == 9      # 3 admissions x (P-1)
    assert s["engine_prefill_calls"] == 3
    assert s["engine_decode_tokens"] >= 15
    assert all(r.t_first_token >= r.t_admitted >= r.t_enqueue for r in done)
    assert all(r.t_done >= r.t_first_token for r in done)


@pytest.mark.parametrize("arch", FAMILIES)
def test_quarantine_reset_bit_identical_to_fresh_admission(arch):
    """ISSUE 8: a guard-tripped slot is reset through the same cache_ops
    reset a fresh admission uses, so the retried request's tokens must be
    bit-identical to a run that never saw the fault — across every model
    family's state layout (KV ring / Mamba recurrent / RG hybrid)."""
    from repro.resil import FaultEvent, FaultPlan

    m, params = _setup(arch)
    prompt = np.array([5, 6, 7, 8])
    plan = FaultPlan(events=[FaultEvent(tick=2, kind="nan", slot=0,
                                        value=float("nan"))])
    eng = ServeEngine(m, params, slots=2, max_len=64, faults=plan)
    hit = eng.submit(prompt, max_new_tokens=6)
    eng.run_until_drained()
    assert hit.status == "ok" and hit.retries == 1
    assert len(plan.injected) == 1
    events = [name for _, name, _ in eng.resil_log]
    assert events == ["fault_injected", "guard_tripped", "retry"]

    from repro.resil import GuardConfig
    ref_eng = ServeEngine(m, params, slots=2, max_len=64,
                          guards=GuardConfig())
    ref = ref_eng.submit(prompt, max_new_tokens=6)
    ref_eng.run_until_drained()
    assert hit.out_tokens == ref.out_tokens, (hit.out_tokens, ref.out_tokens)
    assert ref_eng.resil_log == []      # the clean twin saw nothing
