import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def _setup():
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    return m, params


def test_drains_queue():
    m, params = _setup()
    eng = ServeEngine(m, params, slots=2, max_len=64)
    reqs = [eng.submit(np.array([1, 2, 3]), max_new_tokens=4) for _ in range(5)]
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_slot_isolation():
    """A request's output must not depend on co-batched requests."""
    m, params = _setup()
    prompt = np.array([5, 6, 7, 8])
    solo = ServeEngine(m, params, slots=2, max_len=64)
    solo.submit(prompt, max_new_tokens=5)
    ref = solo.run_until_drained()[0].out_tokens

    busy = ServeEngine(m, params, slots=2, max_len=64)
    busy.submit(np.array([9, 10]), max_new_tokens=5)
    busy.submit(prompt, max_new_tokens=5)
    busy.submit(np.array([11, 12, 13]), max_new_tokens=5)
    done = busy.run_until_drained()
    got = [r for r in done if r.prompt.tolist() == prompt.tolist()][0].out_tokens
    assert got == ref, (got, ref)
