from repro.core.dynamic import QoSController


def _ladder():
    return [{"ebits": 8}, {"ebits": 7}, {"ebits": 6}, {"ebits": 5}]


def test_increases_approximation_when_quality_headroom():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0)
    for s in range(5):
        kw = c.update(s, -0.1)  # quality signal below low water
    assert c.degree == 3 and kw == {"ebits": 5}


def test_backs_off_on_violation():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0, degree=3)
    c.update(0, 0.9)
    assert c.degree == 2


def test_cooldown_prevents_thrash():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=10, ema_alpha=1.0)
    c.update(0, -1.0)
    d1 = c.degree
    c.update(1, -1.0)
    assert c.degree == d1  # cooling down


def test_degree_pinned_at_most_approximate_end():
    """At the ladder's last rung, sustained headroom must not run off the end."""
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0, degree=3)
    for s in range(10):
        kw = c.update(s, -1.0)
    assert c.degree == 3 and kw == {"ebits": 5}
    assert [d for _, _, d in c.history] == [3] * 10


def test_degree_pinned_at_exact_end():
    """At rung 0, sustained violation must not go negative."""
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0, degree=0)
    for s in range(10):
        kw = c.update(s, 5.0)
    assert c.degree == 0 and kw == {"ebits": 8}


def test_pinned_updates_do_not_consume_cooldown():
    """A no-move update at a ladder end must not arm the cooldown timer: the
    next genuine quality swing reacts immediately."""
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=5, ema_alpha=1.0, degree=0)
    c.update(0, 5.0)          # pinned at 0, no move
    c.update(1, -1.0)         # headroom appears
    assert c.degree == 1      # reacts without waiting out a phantom cooldown


def test_cooldown_blocks_oscillation():
    """Alternating head-room/violation signals inside one cooldown window
    produce exactly one move, not a thrash."""
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=4, ema_alpha=1.0)
    sigs = [-1.0, 2.0, -1.0, 2.0, -1.0]
    for s, q in enumerate(sigs):
        c.update(s, q)
    degrees = [d for _, _, d in c.history]
    assert degrees[0] == 1                 # first headroom moves
    assert degrees == [1, 1, 1, 1, 1]      # cooldown pins every later signal
    assert c.degree == 1


def test_cooldown_expiry_allows_next_move():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=2, ema_alpha=1.0)
    c.update(0, -1.0)          # -> degree 1, cooldown = 2
    c.update(1, -1.0)          # cooldown 2 -> 1
    c.update(2, -1.0)          # cooldown 1 -> 0
    assert c.degree == 1
    c.update(3, -1.0)          # cooldown expired -> move
    assert c.degree == 2


def test_ema_smoothing_gates_single_spike():
    """With a small alpha, one outlier signal cannot trigger a move."""
    c = QoSController(ladder=_ladder(), low_water=-0.5, high_water=0.5,
                      cooldown_steps=0, ema_alpha=0.1)
    c.update(0, 0.0)
    c.update(1, -3.0)          # ema = 0.9*0 + 0.1*(-3) = -0.3 > low_water
    assert c.degree == 0
