from repro.core.dynamic import QoSController


def _ladder():
    return [{"ebits": 8}, {"ebits": 7}, {"ebits": 6}, {"ebits": 5}]


def test_increases_approximation_when_quality_headroom():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0)
    for s in range(5):
        kw = c.update(s, -0.1)  # quality signal below low water
    assert c.degree == 3 and kw == {"ebits": 5}


def test_backs_off_on_violation():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=0, ema_alpha=1.0, degree=3)
    c.update(0, 0.9)
    assert c.degree == 2


def test_cooldown_prevents_thrash():
    c = QoSController(ladder=_ladder(), low_water=0.0, high_water=0.5,
                      cooldown_steps=10, ema_alpha=1.0)
    c.update(0, -1.0)
    d1 = c.degree
    c.update(1, -1.0)
    assert c.degree == d1  # cooling down
