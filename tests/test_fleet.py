"""repro.dist.fleet (ISSUE 9): replica fleets that survive replica loss.

Covers the fleet-level fault kind (parse alias, seeded scheduling, RNG
back-compat), supervisor routing, the hard-kill failure arc (queue
migration + in-flight rewind + rescale), the exactly-once fleet-wide
accounting partition, payload bit-identity against a clean single-engine
reference across arbitrary seeded loss schedules, same-seed recovery-trace
determinism, last-replica protection, graceful decommission, retry
exhaustion through the fleet rewind path, and the injectable rescale
clock.  (The multi-device sharded-serving twin lives in
test_sharded_serve.py; ragged rescale planning in test_elastic.py.)

Everything here runs on one host device: replicas get degenerate (1,1)
meshes sharing device 0 — the supervisor logic is device-count agnostic.
"""
import numpy as np
import pytest

from repro.dist.fleet import FleetSupervisor, fleet_meshes
from repro.resil import (FaultEvent, FaultPlan, FaultSpec, GuardConfig,
                         ServePolicy, VirtualClock)
from repro.serve.stream import StreamAdapter, StreamServeEngine, make_clip


def _clip(frames=4, seed=0):
    cfg = StreamAdapter().cfg
    return make_clip(frames, cfg.frame, q=cfg.q, seed=seed)


def _policy(**kw):
    kw.setdefault("deadline_ms", None)
    kw.setdefault("ttft_deadline_ms", None)
    kw.setdefault("max_queue", None)
    kw.setdefault("max_queue_age_ms", None)
    kw.setdefault("backoff_ms", 0.0)
    return ServePolicy(**kw)


def _fleet(replicas=3, *, slots=2, faults=None, policy=None, clock=None,
           rescale_ms=5.0, seed=0):
    clock = clock if clock is not None else VirtualClock()
    policy = policy if policy is not None else _policy()

    def build(mesh, rid):
        return StreamServeEngine(slots=slots, seed=seed, clock=clock,
                                 policy=policy, guards=GuardConfig())

    return FleetSupervisor(build, replicas, tp=1, clock=clock,
                           faults=faults, policy=policy,
                           rescale_ms=rescale_ms)


def _kill_at(tick, replica):
    return FaultPlan(events=[FaultEvent(tick=tick, kind="replica_loss",
                                        slot=replica, target="replica")])


def _payload_key(req):
    return tuple(np.asarray(f).tobytes() for f in req.out)


def _clean_reference(clips, *, slots=2):
    """Single-replica, no-fault run over the same clips: the payload
    oracle every fleet run must match bit-for-bit on its ok requests."""
    eng = StreamServeEngine(slots=slots)
    reqs = [eng.submit(c) for c in clips]
    eng.run_until_drained()
    assert all(r.status == "ok" for r in reqs)
    return {r.rid: _payload_key(r) for r in reqs}


# ---------------------------------------------------------------------------
# fault-kind plumbing
# ---------------------------------------------------------------------------


def test_replica_loss_spec_parse_aliases():
    sp = FaultSpec.parse("replica=0.25")
    assert sp.replica_loss == 0.25
    assert FaultSpec.parse("replica_loss=0.1").replica_loss == 0.1


def test_replica_loss_needs_fleet_binding():
    plan = FaultPlan(FaultSpec(replica_loss=1.0), seed=3)
    assert all(not evs for evs in
               (plan.events_at(t) for t in range(5)))   # unbound: no victims
    plan.bind_fleet(4)
    evs = [e for t in range(5) for e in plan.events_at(t)]
    assert evs and all(e.kind == "replica_loss" for e in evs)
    assert all(0 <= e.slot < 4 for e in evs)


def test_replica_loss_rate_zero_preserves_rng_streams():
    # adding the new kind must not shift the draw sequence of old plans:
    # a spec with replica_loss=0 yields tick-for-tick identical events
    spec = FaultSpec(seu_state=0.3, nan=0.3, spike=0.2, drop=0.2)
    a = FaultPlan(spec, seed=7)
    b = FaultPlan(spec, seed=7).bind_fleet(8)
    eng = StreamServeEngine(slots=2)
    a.bind(eng.state, eng.params, 2)
    b.bind(eng.state, eng.params, 2)
    for t in range(64):
        assert a.events_at(t) == b.events_at(t)


def test_replica_loss_schedule_is_deterministic():
    mk = lambda: FaultPlan(FaultSpec(replica_loss=0.5), seed=11).bind_fleet(3)
    a, b = mk(), mk()
    for t in range(32):
        assert a.events_at(t) == b.events_at(t)


# ---------------------------------------------------------------------------
# meshes + routing
# ---------------------------------------------------------------------------


def test_fleet_meshes_shapes_and_sharing():
    meshes = fleet_meshes(3, tp=1)
    assert len(meshes) == 3
    for m in meshes:
        assert m.axis_names == ("data", "model")
        assert m.devices.shape == (1, 1)


def test_routing_is_least_loaded_then_lowest_rid():
    sup = _fleet(3)
    # empty fleet: ties break to replica 0, then spread round-robin-ish
    r0 = sup.submit(_clip(seed=0))
    assert r0 in sup.replicas[0].engine.queue
    r1 = sup.submit(_clip(seed=1))
    assert r1 in sup.replicas[1].engine.queue
    r2 = sup.submit(_clip(seed=2))
    assert r2 in sup.replicas[2].engine.queue
    r3 = sup.submit(_clip(seed=3))
    assert r3 in sup.replicas[0].engine.queue


def test_fleet_rids_are_unique_across_replicas():
    sup = _fleet(3)
    reqs = [sup.submit(_clip(seed=i)) for i in range(9)]
    assert sorted(r.rid for r in reqs) == list(range(9))


# ---------------------------------------------------------------------------
# the failure arc
# ---------------------------------------------------------------------------


def test_kill_migrates_rewinds_and_rescales():
    sup = _fleet(3, faults=_kill_at(2, 1))
    reqs = [sup.submit(_clip(6, seed=i)) for i in range(8)]
    done = sup.run_until_drained(max_ticks=800)
    assert len(done) == len(reqs)
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert all(r.status == "ok" for r in done)
    assert not sup.replicas[1].alive
    assert sup.replicas[1].died_at == 2
    names = [n for _, n, _ in sup.resil_log]
    assert "replica_lost" in names and "rescale" in names
    assert "rewind" in names          # slots were mid-decode at tick 2
    # survivor plan: 2 replicas * tp=1 -> data=2, nothing idle
    assert sup.rescales[-1].data == 2
    assert sup.rescales[-1].idle_devices == 0
    assert sup.status_counts() == {"ok": len(reqs)}


def test_fleet_payloads_bit_identical_after_kill():
    clips = [_clip(5, seed=i) for i in range(8)]
    ref = _clean_reference(clips)
    sup = _fleet(3, faults=_kill_at(3, 0))
    reqs = [sup.submit(c) for c in clips]
    sup.run_until_drained(max_ticks=800)
    got = {r.rid: _payload_key(r) for r in sup.done}
    assert got == ref


def test_last_live_replica_is_never_killed():
    # schedule hits every replica; the fleet must refuse the final kill
    events = [FaultEvent(tick=t, kind="replica_loss", slot=t,
                         target="replica") for t in range(3)]
    sup = _fleet(3, faults=FaultPlan(events=events))
    reqs = [sup.submit(_clip(5, seed=i)) for i in range(6)]
    done = sup.run_until_drained(max_ticks=800)
    assert len(sup.live) == 1
    assert len(done) == len(reqs) and all(r.status == "ok" for r in done)
    assert any(n == "replica_loss_skipped" for _, n, _ in sup.resil_log)


def test_rewind_exhaustion_fails_exactly_once():
    # max_retries=0: any in-flight rewind immediately fails the request —
    # the fleet-level terminal path must keep the accounting partition
    sup = _fleet(2, policy=_policy(max_retries=0), faults=_kill_at(2, 0))
    reqs = [sup.submit(_clip(6, seed=i)) for i in range(4)]
    done = sup.run_until_drained(max_ticks=800)
    assert len(done) == len(reqs)
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    counts = sup.status_counts()
    assert counts.get("failed", 0) >= 1    # the mid-decode victims
    assert sum(counts.values()) == len(reqs)
    assert any(n == "request_failed" for _, n, _ in sup.resil_log)


def test_decommission_drains_with_zero_rewinds():
    sup = _fleet(3)
    reqs = [sup.submit(_clip(5, seed=i)) for i in range(6)]
    for _ in range(2):
        sup.tick()
    plan = sup.decommission(1)
    assert plan is not None and not sup.replicas[1].alive
    done = sup.run_until_drained(max_ticks=800)
    assert len(done) == len(reqs) and all(r.status == "ok" for r in done)
    assert all(r.retries == 0 for r in done)          # graceful: no rewinds
    names = [n for _, n, _ in sup.resil_log]
    assert "decommission" in names and "rewind" not in names


def test_rescale_duration_is_injectable_and_observed():
    clock = VirtualClock()
    sup = _fleet(3, clock=clock, faults=_kill_at(1, 2), rescale_ms=40.0)
    [sup.submit(_clip(5, seed=i)) for i in range(6)]
    t0 = clock()
    sup.run_until_drained(max_ticks=800)
    # the virtual clock advanced by exactly the modeled rescale latency
    # (stream ticks themselves don't touch the clock)
    assert clock() - t0 == pytest.approx(0.040)
    hist = sup.registry.histogram("repro_rescale_seconds")
    assert hist.count == 1 and hist.sum == pytest.approx(0.040)


def test_replica_up_gauge_tracks_liveness():
    sup = _fleet(3, faults=_kill_at(1, 1))
    [sup.submit(_clip(5, seed=i)) for i in range(4)]
    g = sup.registry.gauge("repro_replica_up", labels=("replica",))
    assert [g.labels(replica=str(r)).value for r in range(3)] == [1, 1, 1]
    sup.run_until_drained(max_ticks=800)
    assert [g.labels(replica=str(r)).value for r in range(3)] == [1, 0, 1]
    assert sup.registry.counter("repro_replica_loss_total").value == 1


# ---------------------------------------------------------------------------
# exactly-once + determinism across seeded schedules (the property tests)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 5, 9, 13])
def test_property_exactly_once_and_ok_bit_identity(seed):
    """Across arbitrary seeded replica-loss schedules: every submitted
    request terminates exactly once, and every ok payload is bit-identical
    to the clean single-replica run."""
    clips = [_clip(5, seed=100 + i) for i in range(10)]
    ref = _clean_reference(clips)
    plan = FaultPlan(FaultSpec(replica_loss=0.2), seed=seed)
    sup = _fleet(3, faults=plan)
    reqs = [sup.submit(c) for c in clips]
    done = sup.run_until_drained(max_ticks=1200)
    # exactly-once: one terminal record per submission, no dups, no losses
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert sum(sup.status_counts().values()) == len(reqs)
    for r in done:
        if r.status == "ok":
            assert _payload_key(r) == ref[r.rid]


def test_same_seed_recovery_trace_is_deterministic():
    def run():
        plan = FaultPlan(FaultSpec(replica_loss=0.25), seed=17)
        sup = _fleet(3, faults=plan)
        reqs = [sup.submit(_clip(5, seed=i)) for i in range(8)]
        done = sup.run_until_drained(max_ticks=1200)
        return (tuple(sup.resil_log),
                tuple((e.tick, e.kind, e.slot) for e in plan.injected),
                tuple(sorted((r.rid, r.status, _payload_key(r))
                             for r in done)))

    assert run() == run()


def test_replica_loss_mid_chunked_admission_rewinds_and_recovers():
    """Hard-killing a replica while an LM request is mid-chunked-prefill
    must rewind the admission cursor to zero and front-requeue the request
    onto a survivor, where it re-admits from scratch and finishes with the
    same payload as a never-faulted run."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.admission import AdmissionConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    adm = AdmissionConfig(chunk_tokens=8, warmup=False)

    def build(mesh, rid):
        return ServeEngine(m, params, slots=1, max_len=64, seed=7,
                           admission=adm, emitter=False)

    sup = FleetSupervisor(build, 2, tp=1, policy=_policy(), rescale_ms=0.0)
    prompt = np.random.default_rng(5).integers(
        1, cfg.vocab, 40).astype(np.int32)
    req = sup.submit(prompt, 3)
    eng0 = sup.replicas[0].engine           # ties route to replica 0
    eng0.tick()                             # first chunk only
    assert req in eng0.slot_req
    assert 0 < req.cursor < req.payload_units - 1
    assert not eng0.workload.admit_complete(req)

    sup.kill(0)
    assert req.cursor == 0                  # rewound: fresh re-admission
    assert req in sup.replicas[1].engine.queue
    done = sup.run_until_drained()
    assert [r.rid for r in done] == [req.rid] and req.status == "ok"
    names = [n for _, n, _ in sup.resil_log]
    assert "replica_lost" in names and "rewind" in names

    ref_eng = ServeEngine(m, params, slots=1, max_len=64, seed=7,
                          admission=adm, emitter=False)
    ref = ref_eng.submit(prompt, 3)
    ref_eng.run_until_drained()
    assert req.out == ref.out               # recovery == clean run
