"""The unit-gate model must reproduce the dissertation's Table 3.3."""
from repro.core import area_model


def test_table_3_3_dlsb_overheads():
    t = area_model.dlsb_overhead_table()
    paper = {8: (11.8, 1.4), 16: (6.7, 0.8), 32: (3.7, 0.5)}
    for n, (d1, d2) in paper.items():
        assert abs(t[n][0] - d1) < 0.15, (n, t[n])
        assert abs(t[n][1] - d2) < 0.15, (n, t[n])


def test_approximate_families_cheaper_than_exact():
    n = 16
    base = area_model.area_cmb(n)
    assert area_model.area_rad(n, 8) < base
    assert area_model.area_pr(n, 2, 4) < base
    assert area_model.area_roup(n, 8, 1, 4) < area_model.area_rad(n, 8)


def test_deeper_approximation_is_smaller():
    n = 16
    assert area_model.area_pr(n, 2, 0) < area_model.area_pr(n, 1, 0)
    assert area_model.area_rad(n, 10) < area_model.area_rad(n, 6)
    assert area_model.energy_proxy("PR", n, p=2) < area_model.energy_proxy("CMB", n)
