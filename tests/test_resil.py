"""repro.resil (ISSUE 8): fault injection, runtime guards, serving policy.

Covers the primitives (bit flips, fault operand, slot guards, retry helper,
sentinel, virtual clock), the deterministic fault schedule, and the engine
integration on the stream workload: quarantine + requeue, deadlines on all
three edges, backpressure (brownout-before-shed), retry exhaustion, the
terminal-status accounting partition, recovery-trace determinism, and the
zero-recompile contract of the guarded step.  (LM-family quarantine
bit-identity lives in test_serve.py; the stream twin in test_stream.py;
checkpoint digest verification in test_checkpoint.py.)
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dynamic import QoSController
from repro.kernels.dispatch import inject_fault
from repro.models.cache_ops import bit_flip, cache_bit_flip
from repro.resil import (FaultEvent, FaultPlan, FaultSpec, GuardConfig,
                         QualitySentinel, ServePolicy, VirtualClock, retry,
                         slot_ok)
from repro.serve.stream import StreamAdapter, StreamServeEngine, make_clip


def _clip(frames=4, seed=0):
    cfg = StreamAdapter().cfg
    return make_clip(frames, cfg.frame, q=cfg.q, seed=seed)


def _nan_at(tick, slot=0):
    return FaultPlan(events=[FaultEvent(tick=tick, kind="nan", slot=slot,
                                        value=float("nan"))])


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_faultspec_parse_aliases_and_errors():
    sp = FaultSpec.parse("seu=0.1,param=0.05,inf=0.2,latency=0.01,drop=0.02")
    assert sp.seu_state == 0.1 and sp.seu_param == 0.05
    assert sp.nan == 0.2 and sp.spike == 0.01 and sp.drop == 0.02
    sp = FaultSpec.parse("nan=0.5,spike_ms=9,seu_bit=uniform")
    assert sp.spike_ms == 9.0 and sp.seu_bit == "uniform"
    with pytest.raises(ValueError):
        FaultSpec.parse("gamma_ray=0.5")
    with pytest.raises(ValueError):
        FaultSpec.parse("nan")          # k=v required


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32,
                                   jnp.int8])
def test_bit_flip_is_a_single_element_involution(dtype):
    rng = np.random.default_rng(0)
    arr = jnp.asarray(rng.integers(-40, 40, (3, 5)), dtype)
    idx, bit = 7, 2
    once = bit_flip(arr, idx, bit)
    assert np.asarray(once != arr).sum() == 1          # exactly one element
    assert np.asarray(once).reshape(-1)[idx] != np.asarray(arr).reshape(-1)[idx]
    twice = bit_flip(once, idx, bit)
    np.testing.assert_array_equal(np.asarray(twice), np.asarray(arr))


def test_bit_flip_accepts_host_numpy_leaves():
    arr = np.arange(6, dtype=np.float32)
    out = bit_flip(arr, 3, 30)
    assert np.asarray(out != arr).sum() == 1
    np.testing.assert_array_equal(np.asarray(bit_flip(out, 3, 30)), arr)


def test_cache_bit_flip_isolates_the_slot_and_protects_length():
    state = StreamAdapter().init_state(batch=3, max_len=0)
    field = next(n for n in state._fields if n != "length")
    flipped = cache_bit_flip(state, field, 1, 0, 30)
    for name in state._fields:
        a, b = getattr(state, name), getattr(flipped, name)
        if name == field:
            assert np.asarray(a[:, 1] != b[:, 1]).sum() == 1
            np.testing.assert_array_equal(np.asarray(a[:, 0]),
                                          np.asarray(b[:, 0]))
            np.testing.assert_array_equal(np.asarray(a[:, 2]),
                                          np.asarray(b[:, 2]))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        cache_bit_flip(state, "length", 0, 0, 0)


def test_inject_fault_identity_and_marking():
    x = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    assert inject_fault(x, None) is x
    clean = inject_fault(x, jnp.zeros(3, jnp.float32))
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(x))  # exact
    f = jnp.asarray([0.0, np.nan, 0.0], jnp.float32)
    hit = np.asarray(inject_fault(x, f))
    assert np.isnan(hit[1]).all()
    np.testing.assert_array_equal(hit[0], np.asarray(x)[0])
    np.testing.assert_array_equal(hit[2], np.asarray(x)[2])
    xi = jnp.asarray(np.arange(6, dtype=np.int32).reshape(3, 2))
    hit_i = np.asarray(inject_fault(xi, f))
    np.testing.assert_array_equal(hit_i[0], np.asarray(xi)[0])
    assert (np.abs(hit_i[1].astype(np.int64)) >= 2**30 - 1).all()


def test_slot_ok_finite_and_limit():
    x = jnp.asarray([[1.0, 2.0], [np.nan, 0.0], [np.inf, 0.0], [50.0, 0.0]])
    np.testing.assert_array_equal(np.asarray(slot_ok(x)),
                                  [True, False, False, True])
    np.testing.assert_array_equal(np.asarray(slot_ok(x, limit=10.0)),
                                  [True, False, False, False])
    xi = jnp.asarray([[5, 2], [2**30, 0]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(slot_ok(xi)), [True, True])
    np.testing.assert_array_equal(np.asarray(slot_ok(xi, limit=100.0)),
                                  [True, False])


def test_retry_helper_backoff_exhaustion_and_passthrough():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=5, backoff=0.05, cap=0.08,
                 sleep=sleeps.append) == "ok"
    assert calls["n"] == 4
    assert sleeps == [0.05, 0.08, 0.08]          # capped exponential

    with pytest.raises(OSError):
        retry(lambda: (_ for _ in ()).throw(OSError("down")),
              attempts=2, sleep=lambda s: None)
    with pytest.raises(KeyError):                # non-matching: immediate
        retry(lambda: {}["x"], attempts=5, sleep=lambda s: None)
    with pytest.raises(ValueError):
        retry(lambda: 1, attempts=0)


def test_quality_sentinel_window_and_modes():
    s = QualitySentinel(1.0, mode="max", window=2)
    assert not s.observe(5.0)           # 1 consecutive bad
    assert s.observe(5.0)               # 2nd trips, counter resets
    assert not s.observe(5.0)
    assert not s.observe(0.5)           # good sample resets the streak
    assert not s.observe(5.0)
    assert s.trips == 1
    p = QualitySentinel(30.0, mode="min")       # PSNR-style: low is bad
    assert p.observe(10.0) and not p.observe(40.0)
    with pytest.raises(ValueError):
        QualitySentinel(1.0, mode="median")


def test_virtual_clock():
    c = VirtualClock(5.0)
    assert c() == 5.0
    c.advance(0.25)
    assert c() == 5.25


# ---------------------------------------------------------------------------
# fault schedule determinism
# ---------------------------------------------------------------------------


def test_fault_schedule_deterministic_and_order_free():
    spec = FaultSpec(seu_state=0.4, seu_param=0.3, nan=0.4, spike=0.2,
                     drop=0.2)
    adapter = StreamAdapter()
    state = adapter.init_state(batch=2, max_len=0)
    params = adapter.init_params()
    a = FaultPlan(spec, seed=3).bind(state, params, 2)
    b = FaultPlan(spec, seed=3).bind(state, params, 2)
    fwd = [a.events_at(t) for t in range(40)]
    rev = [b.events_at(t) for t in reversed(range(40))][::-1]
    assert fwd == rev                   # stateless per tick
    assert any(fwd)                     # non-vacuous at these rates
    c = FaultPlan(spec, seed=4).bind(state, params, 2)
    assert [c.events_at(t) for t in range(40)] != fwd


def test_fault_plan_scripted_and_ctor_validation():
    ev = FaultEvent(tick=3, kind="drop")
    plan = FaultPlan(events=[ev])
    assert plan.events_at(3) == [ev] and plan.events_at(2) == []
    with pytest.raises(ValueError):
        FaultPlan()


# ---------------------------------------------------------------------------
# engine integration (stream workload — cheap, int32, exact)
# ---------------------------------------------------------------------------


def test_guarded_clean_run_matches_legacy_bitwise():
    adapter = StreamAdapter()
    clip = _clip(frames=4)
    legacy = StreamServeEngine(adapter, slots=2)
    r0 = legacy.submit(clip)
    legacy.run_until_drained()
    guarded = StreamServeEngine(adapter, slots=2, guards=GuardConfig())
    r1 = guarded.submit(clip)
    guarded.run_until_drained()
    assert len(r0.out) == len(r1.out) == 4
    for f0, f1 in zip(r0.out, r1.out):
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
    assert guarded.resil_log == []      # clean run: empty recovery trace


def test_faults_imply_guards_imply_policy():
    eng = StreamServeEngine(StreamAdapter(), slots=2,
                            faults=FaultPlan(FaultSpec(nan=0.1)))
    assert eng.guards is not None and eng.policy is not None
    bare = StreamServeEngine(StreamAdapter(), slots=2)
    assert bare.guards is None and bare.policy is None


def test_quarantine_requeues_and_recovers():
    eng = StreamServeEngine(StreamAdapter(), slots=1, faults=_nan_at(1))
    req = eng.submit(_clip(frames=4))
    eng.run_until_drained()
    assert req.status == "ok" and req.retries == 1
    assert len(req.out) == 4
    events = [name for _, name, _ in eng.resil_log]
    assert events[:3] == ["fault_injected", "guard_tripped", "retry"]
    # recovery output is bit-identical to a never-faulted run
    ref = StreamServeEngine(StreamAdapter(), slots=1)
    rr = ref.submit(_clip(frames=4))
    ref.run_until_drained()
    for f0, f1 in zip(req.out, rr.out):
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))


def test_retry_exhaustion_fails_the_request():
    # a NaN every tick: the request can never complete its 3 frames
    events = [FaultEvent(tick=t, kind="nan", slot=0, value=float("nan"))
              for t in range(200)]
    eng = StreamServeEngine(StreamAdapter(), slots=1,
                            faults=FaultPlan(events=events),
                            policy=ServePolicy(max_retries=2,
                                               backoff_ms=0.01))
    req = eng.submit(_clip(frames=3))
    eng.run_until_drained(max_ticks=500)
    assert req.status == "failed" and req.done
    assert req.retries == 3             # initial + 2 requeues, then fail
    assert int(eng.stats.c_failed.value) == 1
    assert int(eng.stats.c_retries.value) == 2
    assert eng.done == [req]            # terminated exactly once


def test_deadline_edges_queue_and_active():
    clock = VirtualClock()
    eng = StreamServeEngine(StreamAdapter(), slots=1, clock=clock,
                            guards=GuardConfig(), policy=ServePolicy())
    occupant = eng.submit(_clip(frames=8))
    queued = eng.submit(_clip(frames=2), deadline_ms=5.0)
    active = eng.submit(_clip(frames=30), deadline_ms=40.0)
    for _ in range(60):
        eng.tick()
        clock.advance(0.002)            # 2 virtual ms per tick
        if all(r.done for r in (occupant, queued, active)):
            break
    assert occupant.status == "ok"
    assert queued.status == "deadline"  # expired before a slot freed
    assert active.status == "deadline"  # admitted, too slow to finish
    edges = {dict(args).get("edge") for _, name, args in eng.resil_log
             if name == "deadline_miss"}
    assert edges == {"queue", "active"}
    assert len(eng.done) == 3           # nothing lost


def test_deadline_ttft_edge_under_dropped_ticks():
    # dropped ticks starve the first emission (stream otherwise emits on
    # its admission tick), so the TTFT cut is what terminates the request
    clock = VirtualClock()
    drops = [FaultEvent(tick=t, kind="drop") for t in range(8)]
    eng = StreamServeEngine(StreamAdapter(), slots=1, clock=clock,
                            faults=FaultPlan(events=drops),
                            policy=ServePolicy())
    req = eng.submit(_clip(frames=2), ttft_deadline_ms=5.0)
    for _ in range(20):
        eng.tick()
        clock.advance(0.002)
        if req.done:
            break
    assert req.status == "deadline" and req.out == []
    assert int(eng.stats.c_deadline_miss.labels(edge="ttft").value) == 1


def test_backpressure_brownout_before_shed():
    cfg = StreamAdapter().cfg
    ladder = [{"degrees": [e] * 3} for e in (8, 6, 4)]
    clock = VirtualClock()
    qos = QoSController(ladder=ladder, low_water=0.25, high_water=0.75,
                        cooldown_steps=3)
    eng = StreamServeEngine(StreamAdapter(), slots=1, qos=qos, clock=clock,
                            policy=ServePolicy(max_queue=1, brownout=True),
                            guards=GuardConfig())
    reqs = [eng.submit(_clip(frames=2, seed=i)) for i in range(6)]
    for _ in range(40):
        eng.tick()
        clock.advance(0.001)
        if all(r.done for r in reqs):
            break
    # ladder walked before anything shed: 2 brownout rungs (8 -> 6 -> 4),
    # then overflow shedding newest-first
    assert int(eng.stats.c_brownout.value) == 2
    assert qos.degree == 2
    statuses = [r.status for r in reqs]
    assert statuses.count("shed") >= 1
    shed_order = [dict(a)["rid"] for _, n, a in eng.resil_log if n == "shed"]
    assert shed_order == sorted(shed_order, reverse=True)  # newest first
    assert len(eng.done) == len(reqs)
    # shed-only twin at the same traffic sheds MORE (no ladder to spend)
    clock2 = VirtualClock()
    only = StreamServeEngine(StreamAdapter(), slots=1, clock=clock2,
                             policy=ServePolicy(max_queue=1, brownout=False),
                             guards=GuardConfig())
    reqs2 = [only.submit(_clip(frames=2, seed=i)) for i in range(6)]
    for _ in range(40):
        only.tick()
        clock2.advance(0.001)
        if all(r.done for r in reqs2):
            break
    assert ([r.status for r in reqs2].count("shed")
            > statuses.count("shed"))


def test_queue_age_shedding():
    clock = VirtualClock()
    eng = StreamServeEngine(StreamAdapter(), slots=1, clock=clock,
                            guards=GuardConfig(),
                            policy=ServePolicy(max_queue_age_ms=4.0))
    eng.submit(_clip(frames=8))
    stale = eng.submit(_clip(frames=2))
    for _ in range(20):
        eng.tick()
        clock.advance(0.002)
        if stale.done:
            break
    assert stale.status == "shed"
    assert int(eng.stats.c_shed.labels(reason="stale").value) == 1


def test_recovery_trace_determinism_same_seed():
    spec = FaultSpec(seu_state=0.25, seu_param=0.15, nan=0.25, drop=0.1)

    def run(seed):
        eng = StreamServeEngine(StreamAdapter(), slots=2,
                                faults=FaultPlan(spec, seed=seed),
                                policy=ServePolicy(max_retries=8,
                                                   backoff_ms=0.01))
        reqs = [eng.submit(_clip(frames=3, seed=i)) for i in range(4)]
        eng.run_until_drained(max_ticks=2000)
        outs = [tuple(np.asarray(f).tobytes() for f in r.out) for r in reqs]
        return eng.faults.injected, eng.resil_log, outs

    inj_a, log_a, outs_a = run(11)
    inj_b, log_b, outs_b = run(11)
    assert inj_a == inj_b and log_a == log_b and outs_a == outs_b
    assert inj_a                          # the storm actually injected
    inj_c, log_c, _ = run(12)
    assert (inj_c, log_c) != (inj_a, log_a)


def test_accounting_partition_under_storm():
    spec = FaultSpec(seu_state=0.2, nan=0.3, drop=0.1)
    clock = VirtualClock()
    eng = StreamServeEngine(StreamAdapter(), slots=2, clock=clock,
                            faults=FaultPlan(spec, seed=5),
                            policy=ServePolicy(deadline_ms=25.0, max_queue=3,
                                               max_retries=1,
                                               backoff_ms=0.5))
    reqs = [eng.submit(_clip(frames=3, seed=i)) for i in range(10)]
    for _ in range(400):
        eng.tick()
        clock.advance(0.002)
        if all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs)
    assert len(eng.done) == len(reqs)                     # zero lost
    assert len({r.rid for r in eng.done}) == len(reqs)    # zero duplicated
    assert {r.status for r in reqs} <= {"ok", "failed", "shed", "deadline"}
    for r in reqs:                                        # zero over-charged
        assert len(r.out) <= r.budget
        if r.status == "ok":
            assert len(r.out) == 3


def test_sentinel_trips_and_scrubs_param_corruption():
    adapter = StreamAdapter()
    eng = StreamServeEngine(
        adapter, slots=1, degree=[8, 8, 8], quality_every=1,
        guards=GuardConfig(sentinel_threshold=200.0, sentinel_mode="min"))
    golden = eng.params
    # persistent param corruption, as a seu_param storm would leave behind
    leaves, treedef = jax.tree_util.tree_flatten(eng.params)
    leaves[0] = bit_flip(leaves[0], 0, 30)
    eng.params = jax.tree_util.tree_unflatten(treedef, leaves)
    eng.submit(_clip(frames=3))
    eng.run_until_drained()
    trips = [a for _, n, a in eng.resil_log if n == "guard_tripped"]
    assert any(dict(a)["reason"] == "quality" for a in trips)
    assert eng.params is golden          # scrub rebound the golden tree
    assert int(eng.stats.c_scrubs.value) >= 1


def test_sentinel_requires_quality_tap():
    with pytest.raises(ValueError):
        StreamServeEngine(StreamAdapter(), slots=1,
                          guards=GuardConfig(sentinel_threshold=1.0))


def test_guarded_qos_walk_single_compile():
    cfg = StreamAdapter().cfg
    ladder = [{"degrees": [e] * 3} for e in (8, 7, 6, 5)]
    qos = QoSController(ladder=ladder, low_water=0.25, high_water=0.75,
                        cooldown_steps=2)
    eng = StreamServeEngine(StreamAdapter(), slots=2, qos=qos,
                            guards=GuardConfig(),
                            faults=FaultPlan(FaultSpec(nan=0.2), seed=1),
                            policy=ServePolicy(max_retries=5,
                                               backoff_ms=0.01))
    for rung in range(len(ladder)):
        qos.degree = rung
        eng._degree = jnp.asarray(ladder[rung]["degrees"], jnp.int32)
        eng.submit(_clip(frames=3, seed=rung))
        eng.run_until_drained(max_ticks=2000)
    assert eng._step._cache_size() == 1   # rung walk + faults: no retrace


def test_spike_advances_injected_clock():
    clock = VirtualClock()
    ev = FaultEvent(tick=0, kind="spike", value=0.125)
    eng = StreamServeEngine(StreamAdapter(), slots=1, clock=clock,
                            faults=FaultPlan(events=[ev]))
    eng.submit(_clip(frames=2))
    eng.run_until_drained(max_ticks=50)
    assert math.isclose(clock(), 0.125)
    assert int(eng.stats.c_faults.labels(kind="spike").value) == 1


def test_dropped_tick_charges_nothing():
    ev = FaultEvent(tick=1, kind="drop")
    eng = StreamServeEngine(StreamAdapter(), slots=1,
                            faults=FaultPlan(events=[ev]))
    req = eng.submit(_clip(frames=3))
    eng.run_until_drained(max_ticks=50)
    assert req.status == "ok" and len(req.out) == 3
    assert int(eng.stats.c_dropped_ticks.value) == 1
    # the dropped tick ran no step: steps == frames, not frames + 1
    assert int(eng.stats.c_steps.value) == 3


def test_nan_against_decoding_slot_during_chunked_admission():
    """A guard trip on a DECODING slot while a neighbour slot is still
    chunk-admitting a long prompt: the victim retries and recovers, the
    mid-admission slot is untouched, accounting stays exactly-once, and
    both payloads match a fault-free run."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.admission import AdmissionConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    adm = AdmissionConfig(chunk_tokens=8, warmup=False)
    rng = np.random.default_rng(21)
    short = rng.integers(1, cfg.vocab, 3).astype(np.int32)
    long = rng.integers(1, cfg.vocab, 40).astype(np.int32)

    def run(faults):
        eng = ServeEngine(m, params, slots=2, max_len=64, seed=11,
                          admission=adm, emitter=False, faults=faults,
                          policy=ServePolicy(backoff_ms=0.01))
        rs = eng.submit(short, 4)
        rl = eng.submit(long, 4)
        eng.run_until_drained()
        return eng, rs, rl

    # tick 1 admits the short row + first chunk of the long one; the nan at
    # tick 3 lands while the long prompt is still mid-admission
    plan = FaultPlan(events=[FaultEvent(tick=3, kind="nan", slot=0,
                                        value=float("nan"))])
    eng, rs, rl = run(plan)
    assert rs.status == "ok" and rl.status == "ok"
    assert rs.retries == 1 and rl.retries == 0
    assert len(eng.done) == 2
    names = [n for _, n, _ in eng.resil_log]
    assert "guard_tripped" in names and "retry" in names
    _, crs, crl = run(None)
    assert rs.out == crs.out and rl.out == crl.out
