import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import collectives as C


def test_quantize_dequantize_error_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(4096), jnp.float32)
    y = C.quantize_dequantize(x, bits=8)
    assert float(jnp.abs(y - x).max()) <= float(jnp.abs(x).max()) / 127 * 1.01


def test_error_feedback_conserves_signal():
    """sum of transmitted over steps -> sum of true gradients (EF property)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.standard_normal(256), jnp.float32) for _ in range(50)]
    err = jnp.zeros(256)
    sent_sum = jnp.zeros(256)
    for g in g_true:
        sent, err = C.ef_compress(g, err, bits=4)
        sent_sum = sent_sum + sent
    true_sum = sum(g_true)
    # residual bounded by one quantization step, not accumulated
    assert float(jnp.abs(sent_sum + err - true_sum).max()) < 1e-4


def test_dp_allreduce_compressed_single_device_identity():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((4, 8)), jnp.float32)
    y = C.dp_allreduce_compressed(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=np.abs(x).max()/127*1.1)


def test_ring_allreduce_int8_subprocess():
    """8-device shard_map ring: correctness + 4x wire-byte reduction
    (measured from HLO — integer collectives are not float-normalized)."""
    import subprocess
    import sys
    from pathlib import Path

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist import meshctx
mesh = meshctx.make_mesh((1, 8), ("data", "model"))
meshctx.set_mesh(mesh)
from repro.dist.collectives import ring_allreduce_int8_local
from repro.dist.hlo_analysis import analyze_hlo

x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 1024)), jnp.float32)
def body(xs):
    return ring_allreduce_int8_local(xs, "model")
f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("model", None),
                          out_specs=P("model", None)))
y = f(x)
ref = jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape)
rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
assert rel < 0.05, rel
rep = analyze_hlo(f.lower(x).compile().as_text())
b_int8 = rep.collectives.total_bytes
def body32(xs):
    return jax.lax.psum(xs, "model")
f32 = jax.jit(jax.shard_map(body32, mesh=mesh, in_specs=P("model", None),
                            out_specs=P("model", None)))
b_f32_wire = 2 * analyze_hlo(f32.lower(x).compile().as_text()).collectives.total_bytes
assert b_f32_wire / b_int8 > 3.5, (b_f32_wire, b_int8)
print("RING_OK", rel, b_int8, b_f32_wire)
"""
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=root,
                       env={"PYTHONPATH": str(root / "src"),
                            "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert "RING_OK" in r.stdout, r.stderr[-2000:]
