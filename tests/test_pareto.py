from repro.core import pareto


def test_front_extraction():
    pts = pareto.explore(n=12, num_samples=1 << 12)
    front = pareto.front(pts)
    assert len(front) >= 5
    # exact design always on the front (mred 0)
    assert any(p.fam == "CMB" for p in front)
    # fronts are sorted & monotone: lower error => higher energy
    for a, b in zip(front, front[1:]):
        assert a.mred <= b.mred
        assert a.energy >= b.energy


def test_best_under_error_budget():
    pts = pareto.explore(n=12, num_samples=1 << 12)
    sel = pareto.best_under_error(pts, 0.02)
    assert sel is not None and sel.mred <= 0.02
    # paper's rule: picks strictly cheaper than the exact baseline
    base = [p for p in pts if p.fam == "CMB"][0]
    assert sel.energy < base.energy
