import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.core import quantization as q


def test_quant_dequant_roundtrip_error():
    x = np.random.default_rng(0).standard_normal((8, 512)).astype(np.float32)
    qt = q.quantize_block(jnp.asarray(x), block=256)
    back = np.asarray(q.dequantize(qt))
    assert np.abs(back - x).max() <= np.abs(x).max() / 127 * 1.01


@pytest.mark.parametrize("e", [8, 7, 6, 5, 4])
def test_degrade_keeps_int8_range_and_monotone_error(e):
    v = jnp.arange(-127, 128, dtype=jnp.int8)
    d = q.degrade(v, e)
    assert int(jnp.abs(d.astype(jnp.int32)).max()) <= 127
    if e == 8:
        assert (d == v).all()


def test_qmm_ref_error_monotone_in_ebits():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((32, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    exact = x @ w
    errs = []
    for e in (8, 6, 4):
        y = q.qmm_ref(x, w, block=256, ebits=e)
        errs.append(float(jnp.abs(y - exact).mean()))
    assert errs[0] < errs[1] < errs[2]


@given(st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_degrade_multiple_of_step(e):
    v = jnp.arange(-127, 128, dtype=jnp.int8)
    d = np.asarray(q.degrade(v, e), np.int32)
    step = 1 << (8 - e)
    inner = d[np.abs(d) < 127]  # saturated lanes exempt
    assert (inner % step == 0).all()
