"""Sharded serving on the 8-fake-device mesh (ISSUE 9 tentpole).

Subprocess dry-runs (the XLA device-count flag must precede jax import):

  * f32 exact collectives: sharded greedy decode bit-identical to the
    single-device engine; QoS rung walks on the sharded step never
    recompile (one executable per mesh config); int8 ring collectives
    stay within half the exact wire-byte budget and keep decode inside
    the calibrated error envelope.
  * a fleet of sharded replicas on disjoint mesh slices survives a
    scripted replica loss with exactly-once accounting and ok payloads
    bit-identical to the clean single-engine reference.

The single-device fleet logic is covered by test_fleet.py; partition-rule
validation against real trees by test_sharding.py.
"""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str) -> None:
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={"PYTHONPATH": str(ROOT / "src"),
                            "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert "SHARDED_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-4000:])


@pytest.mark.slow
def test_sharded_lm_decode_identity_rungs_and_ring():
    _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import contextlib
import numpy as np
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.dist import meshctx, sharding
from repro.kernels import ops as kops
from repro.models import build_model
from repro.models.degrees import num_sites
from repro.serve.sharded import ShardedServeEngine, lm_decode_collective_bytes
from repro.serve.lm import ServeEngine

assert len(jax.devices()) == 8
cfg = get_config("tinyllama-1.1b-smoke")
model = build_model(cfg)
tp = 4
mesh = meshctx.make_mesh((2, tp), ("data", "model"))
params = model.init(jax.random.PRNGKey(0), tp=tp)
prompts = [list(range(1, 6)), [7, 8, 9]]
n = num_sites(cfg)

# --- f32 exact collectives: bit-identical greedy decode ------------------
eng = ShardedServeEngine(model, params, mesh=mesh, slots=2, max_len=32,
                         degree=[8] * n)
reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
eng.run_until_drained()
ref = ServeEngine(model, params, slots=2, max_len=32, tp=tp, degree=[8] * n)
rrefs = [ref.submit(p, max_new_tokens=8) for p in prompts]
ref.run_until_drained()
assert [r.out for r in reqs] == [r.out for r in rrefs], "f32 not bit-identical"
assert all(r.status == "ok" and len(r.out) == 8 for r in reqs)

# --- rung walk on the sharded step: one executable per mesh config -------
for e in (8, 7, 6, 5):
    eng._degree = jnp.asarray([e] * n, jnp.int32)
    eng.submit(prompts[0], max_new_tokens=4)
    eng.run_until_drained()
assert eng._step._cache_size() == 1, eng._step._cache_size()

# --- int8 ring: compressed wire budget + error envelope ------------------
# budget probe at tp=2: on the tiny smoke model the per-hop f32 requant
# scales dominate once chunks shrink (tp=4), which would understate the
# compression real-size models get; tp=2 keeps the payload/scale ratio
# representative
f32b = lm_decode_collective_bytes(arch=cfg.name, tp=2, ring=False)
ringb = lm_decode_collective_bytes(arch=cfg.name, tp=2, ring=True)
assert f32b["total"] > 0 and ringb["total"] > 0
assert ringb["total"] <= 0.5 * f32b["total"], (ringb, f32b)

def decode_logits(ring):
    m = meshctx.make_mesh((1, tp), ("data", "model"))
    ctx = contextlib.ExitStack()
    ctx.enter_context(meshctx.use_mesh(m))
    if ring:
        ctx.enter_context(kops.ring_tp())
    with ctx:
        cache = model.init_cache(tp=tp, batch=2, max_len=8)
        p = jax.device_put(params, sharding.named(
            sharding.partition_params(params, cfg.family), m))
        c = jax.device_put(cache, sharding.named(
            sharding.partition_cache(cache, cfg.family), m))
        toks = jnp.ones((2, 1), jnp.int32)
        out = jax.jit(lambda p_, c_, t_: model.decode_step(
            p_, c_, t_, tp=tp))(p, c, toks)
    return np.asarray(out[0], np.float32)

exact, approx = decode_logits(False), decode_logits(True)
rel = np.abs(approx - exact).mean() / (np.abs(exact).mean() + 1e-9)
assert rel < 0.05, f"ring decode outside error envelope: rel={rel}"

# --- ring engine end to end ---------------------------------------------
reng = ShardedServeEngine(model, params, mesh=mesh, slots=2, max_len=32,
                          ring=True)
rr = [reng.submit(p, max_new_tokens=8) for p in prompts]
reng.run_until_drained()
assert all(r.status == "ok" and len(r.out) == 8 for r in rr)
assert reng._step._cache_size() == 1
print("SHARDED_OK")
""")


@pytest.mark.slow
def test_sharded_fleet_survives_replica_loss():
    _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.dist.fleet import FleetSupervisor, fleet_meshes
from repro.models import build_model
from repro.resil import FaultEvent, FaultPlan, ServePolicy, VirtualClock
from repro.serve.sharded import ShardedServeEngine
from repro.serve.lm import ServeEngine

cfg = get_config("tinyllama-1.1b-smoke")
model = build_model(cfg)
tp = 2
params = model.init(jax.random.PRNGKey(0), tp=tp)
meshes = fleet_meshes(3, tp=tp)
# disjoint device slices: 3 replicas x tp=2 on 8 devices
used = [tuple(d.id for d in m.devices.flat) for m in meshes]
assert len({i for t in used for i in t}) == 6, used

clock = VirtualClock()
policy = ServePolicy(deadline_ms=None, ttft_deadline_ms=None,
                     max_queue=None, max_queue_age_ms=None, backoff_ms=0.0)

def build(mesh, rid):
    return ShardedServeEngine(model, params, mesh=mesh, slots=2,
                              max_len=32, clock=clock, policy=policy)

plan = FaultPlan(events=[FaultEvent(tick=2, kind="replica_loss", slot=1,
                                    target="replica")])
sup = FleetSupervisor(build, 3, tp=tp, clock=clock, faults=plan,
                      policy=policy)
prompts = [[1 + i, 2 + i, 3 + i] for i in range(8)]
reqs = [sup.submit(p, 6) for p in prompts]
done = sup.run_until_drained(max_ticks=400)
assert sorted(r.rid for r in done) == list(range(8))
assert all(r.status == "ok" for r in done)
assert not sup.replicas[1].alive
assert sup.rescales[-1].model == tp and sup.rescales[-1].data == 2

# ok payloads bit-identical to the clean single-engine reference
ref = ServeEngine(model, params, slots=2, max_len=32, tp=tp)
rrefs = [ref.submit(p, 6) for p in prompts]
ref.run_until_drained()
want = {r.rid: tuple(r.out) for r in rrefs}
got = {r.rid: tuple(r.out) for r in done}
assert got == want, "fleet payloads diverged from clean reference"
names = [n for _, n, _ in sup.resil_log]
assert "replica_lost" in names and "rescale" in names
print("SHARDED_OK")
""")
