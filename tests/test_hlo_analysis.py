import jax
import jax.numpy as jnp

from repro.dist.hlo_analysis import analyze_hlo, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[4,4]") == 64
    assert shape_bytes("bf16[2,3]{1,0}") == 12
    assert shape_bytes("(f32[2], s8[8])") == 16


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=22)
        return y

    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    txt = jax.jit(f).lower(x, w).compile().as_text()
    rep = analyze_hlo(txt)
    assert rep.dot_flops == 2 * 128 * 128 * 128 * 22


def test_collective_detection_synthetic():
    hlo = """
HloModule m
ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16] parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(%a), replica_groups={}, to_apply=%add
}
"""
    rep = analyze_hlo(hlo)
    assert rep.collectives.bytes_by_kind["all-reduce"] == 64
