"""Kernel backend dispatch: resolution (env/override/auto), per-site routing
with jnp fallbacks on CPU, GQA/window equivalence through the model layout,
and the engine running end to end on the Pallas path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.models import attention as attn


@pytest.fixture(autouse=True)
def _reset_backend():
    yield
    dispatch.set_backend(None)


def test_auto_resolves_to_xla_on_cpu(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    dispatch.set_backend(None)
    assert jax.default_backend() != "tpu"
    assert dispatch.backend_setting() == "auto"
    assert dispatch.resolved_backend() == "xla"
    assert dispatch.interpret_mode()


def test_env_and_override_precedence(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    dispatch.set_backend(None)
    assert dispatch.resolved_backend() == "pallas"
    dispatch.set_backend("xla")           # override beats env
    assert dispatch.resolved_backend() == "xla"
    with pytest.raises(ValueError):
        dispatch.set_backend("cuda")
    monkeypatch.setenv("REPRO_KERNELS", "bogus")
    dispatch.set_backend(None)
    with pytest.raises(ValueError):
        dispatch.backend_setting()


def _qkv(B=2, S=64, H=4, KVr=2, D=16, key=0):
    k = jax.random.PRNGKey(key)
    q = jax.random.normal(k, (B, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KVr, D),
                           jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KVr, D),
                          jnp.float32)
    return q, kk, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_prefill_routes_pallas_and_matches_blockwise(causal, window):
    q, k, v = _qkv()
    dispatch.set_backend("pallas")
    y = dispatch.prefill_attention(q, k, v, causal=causal, window=window)
    assert dispatch.last_route["prefill"] == "pallas"
    yr = attn.attn_blockwise(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)


def test_prefill_fallback_selection_on_cpu():
    q, k, v = _qkv()
    dispatch.set_backend(None)          # auto -> xla on CPU
    y = dispatch.prefill_attention(q, k, v, causal=True)
    assert dispatch.last_route["prefill"] == "xla"
    np.testing.assert_array_equal(
        np.asarray(y),
        np.asarray(attn.attn_blockwise(q, k, v, causal=True, window=None)))
    # non-causal windowed has no kernel grid: falls back even under pallas
    dispatch.set_backend("pallas")
    y2 = dispatch.prefill_attention(q, k, v, causal=False, window=16)
    assert dispatch.last_route["prefill"] == "xla"
    assert y2.shape == q.shape


def test_decode_routes_and_matches():
    B, T, KVr, D, H = 2, 16, 2, 8, 4
    cache = attn.init_kv_cache(B, T, KVr, D, dtype=jnp.float32)
    cache = cache._replace(
        k=jax.random.normal(jax.random.PRNGKey(1), cache.k.shape),
        v=jax.random.normal(jax.random.PRNGKey(2), cache.v.shape),
        length=jnp.asarray([3, 9], jnp.int32))
    k = jax.random.PRNGKey(3)
    q1 = jax.random.normal(k, (B, 1, H, D), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(k, 1), (B, 1, KVr, D))
    vn = jax.random.normal(jax.random.fold_in(k, 2), (B, 1, KVr, D))
    dispatch.set_backend("xla")
    o_x, c_x = dispatch.decode_attention(q1, kn, vn, cache)
    assert dispatch.last_route["decode"] == "xla"
    dispatch.set_backend("pallas")
    o_p, c_p = dispatch.decode_attention(q1, kn, vn, cache)
    assert dispatch.last_route["decode"] == "pallas"
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x), atol=1e-5)
    assert (np.asarray(c_p.k) == np.asarray(c_x.k)).all()
    assert (np.asarray(c_p.length) == np.asarray(c_x.length)).all()


def test_model_forward_backend_equivalence_f32():
    """Full model forward (dense GQA + SWA configs) must agree across
    backends to fp tolerance when activations are f32."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model

    for arch in ("tinyllama-1.1b-smoke", "h2o-danube-1.8b-smoke"):
        cfg = dataclasses.replace(get_config(arch), dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                              0, cfg.vocab)}
        outs = {}
        for b in ("xla", "pallas"):
            dispatch.set_backend(b)
            outs[b], _ = model.forward(params, batch)
        np.testing.assert_allclose(np.asarray(outs["pallas"]),
                                   np.asarray(outs["xla"]), atol=1e-4)


def test_engine_drains_on_pallas_backend():
    """End to end: fused prefill + flash_decode serve steps, dense + hybrid
    (RG-LRU local attention) families."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    dispatch.set_backend("pallas")
    for arch in ("tinyllama-1.1b-smoke", "recurrentgemma-2b-smoke"):
        cfg = get_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=2, max_len=64)
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(rng.integers(0, cfg.vocab, 5), 6)
        done = eng.run_until_drained()
        assert len(done) == 4
        assert all(len(r.out_tokens) == 6 for r in done)


def test_engine_int8_cache_on_pallas_backend(monkeypatch):
    """Quant decode in the live engine: int8 KV cache + pallas backend +
    runtime degree (dequant-degrade kernel) drains cleanly."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    monkeypatch.setenv("REPRO_KV_INT8", "1")
    dispatch.set_backend("pallas")
    cfg = get_config("tinyllama-1.1b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=2, max_len=64, degree=6)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, 5), 4)
    done = eng.run_until_drained()
    assert len(done) == 3 and all(len(r.out_tokens) == 4 for r in done)
