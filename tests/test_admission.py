"""Production admission pipeline (ISSUE 10): bucketed AOT prefill, packed
prompts, chunked prefill, async emit, and the policy/routing edges.

Covers the config primitives (ladder/bucket lookup), the zero-post-warmup
compile contract (trace_counts census), the compile-count regression bound
(20 random prompt lengths compile at most len(buckets) executables), the
background emit queue, the queue-TTFT deadline + doomed-shed policy fixes,
admission-backlog-aware fleet routing, and the sharded-engine warmup
ordering.  The hypothesis-style bit-identity properties live in
test_admission_props.py.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.resil import ServePolicy, VirtualClock
from repro.serve.admission import AdmissionConfig, bucket_for, bucket_ladder
from repro.serve.emitq import AsyncEmitter, default_detok
from repro.serve.engine import ServeEngine

_CACHE: dict = {}


def _setup(arch: str = "tinyllama-1.1b-smoke"):
    if arch not in _CACHE:
        cfg = get_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[arch] = (m, params)
    return _CACHE[arch]


def _prompts(n, lens=None, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    if lens is None:
        lens = rng.integers(2, 30, n)
    return [rng.integers(1, vocab, int(ln)).astype(np.int32) for ln in lens]


# ---------------------------------------------------------------------------
# config primitives
# ---------------------------------------------------------------------------


def test_bucket_ladder_powers_of_two():
    assert bucket_ladder(512) == (16, 32, 64, 128, 256, 512)
    assert bucket_ladder(64) == (16, 32, 64)
    assert bucket_ladder(17) == (16,)
    assert bucket_ladder(18) == (16, 18)              # capped at max_len


def test_bucket_ladder_caps_at_cache_capacity():
    # a non-power-of-two max_len must never get a bucket the dense cache
    # cannot hold (Pb > T would raise at warmup trace)
    assert bucket_ladder(48) == (16, 32, 48)
    assert max(bucket_ladder(100)) <= 100
    assert bucket_ladder(8) == (8,)


def test_bucket_for_smallest_cover_and_overflow():
    buckets = (16, 32, 64)
    assert bucket_for(1, buckets) == 16
    assert bucket_for(16, buckets) == 16
    assert bucket_for(17, buckets) == 32
    assert bucket_for(64, buckets) == 64
    with pytest.raises(ValueError):
        bucket_for(65, buckets)


def test_admission_config_validation_and_resolve():
    with pytest.raises(ValueError):
        AdmissionConfig(pack=0)
    with pytest.raises(ValueError):
        AdmissionConfig(chunk_tokens=-1)
    with pytest.raises(ValueError):
        AdmissionConfig(buckets=(32, 16))
    a = AdmissionConfig(pack=3, chunk_tokens=8).resolved(64)
    assert a.buckets == (16, 32, 64) and a.pack == 3 and a.chunk_tokens == 8
    pinned = AdmissionConfig(buckets=(8, 24)).resolved(64)
    assert pinned.buckets == (8, 24)      # explicit buckets win


# ---------------------------------------------------------------------------
# AOT warmup + compile-count regression
# ---------------------------------------------------------------------------


def test_warmup_compiles_everything_no_post_warmup_traces():
    """The warmup pass must trace every bucket + the chunk + the step
    executable; serving 20 mixed-length prompts afterwards compiles
    NOTHING new."""
    m, params = _setup()
    adm = AdmissionConfig(pack=2, chunk_tokens=16)
    eng = ServeEngine(m, params, slots=4, max_len=64, seed=3, admission=adm)
    wl = eng.workload
    assert wl.trace_counts["prefill_batch"] == len(wl.admission.buckets)
    assert wl.trace_counts["prefill_chunk"] == 1
    assert wl.trace_counts["step"] == 1
    assert int(eng.stats.c_warmups.value) == 1
    before = dict(wl.trace_counts)
    for p in _prompts(20, lens=np.random.default_rng(7).integers(2, 60, 20)):
        eng.submit(p, 3)
    eng.run_until_drained()
    assert wl.trace_counts == before, "a request triggered a compile"


def test_compile_count_bounded_by_bucket_ladder():
    """Without warmup, 20 random prompt lengths may compile lazily — but
    never more than one executable per bucket (satellite 3)."""
    m, params = _setup()
    adm = AdmissionConfig(pack=2, warmup=False)
    eng = ServeEngine(m, params, slots=4, max_len=64, seed=3, admission=adm)
    wl = eng.workload
    assert wl.trace_counts["prefill_batch"] == 0      # warmup disabled
    for p in _prompts(20, lens=np.random.default_rng(9).integers(2, 60, 20)):
        eng.submit(p, 2)
    eng.run_until_drained()
    assert 1 <= wl.trace_counts["prefill_batch"] <= len(wl.admission.buckets)
    assert wl.trace_counts["step"] == 1


def test_warmup_leaves_live_state_untouched():
    """Warmup's dummy rows (slot = B, out of bounds) must be dropped by
    scatter: tokens from a warmed engine match a legacy engine exactly."""
    m, params = _setup()
    prompts = _prompts(6, seed=4)
    legacy = ServeEngine(m, params, slots=3, max_len=64, seed=11)
    r0 = [legacy.submit(p, 5) for p in prompts]
    legacy.run_until_drained()
    adm = AdmissionConfig(pack=2, chunk_tokens=16)
    warmed = ServeEngine(m, params, slots=3, max_len=64, seed=11,
                         admission=adm)
    r1 = [warmed.submit(p, 5) for p in prompts]
    warmed.run_until_drained()
    assert [r.out for r in r1] == [r.out for r in r0]


def test_oversize_prompt_falls_back_to_exact_path():
    """A prefix longer than the largest bucket admits through the legacy
    exact-length prefill (same tokens), not a bucket call."""
    m, params = _setup()
    adm = AdmissionConfig(buckets=(8,))
    eng = ServeEngine(m, params, slots=2, max_len=64, seed=5, admission=adm)
    wl = eng.workload
    long_p = _prompts(1, lens=[20], seed=6)[0]
    short_p = _prompts(1, lens=[5], seed=7)[0]
    r_long = eng.submit(long_p, 4)
    r_short = eng.submit(short_p, 4)
    eng.run_until_drained()
    assert wl.trace_counts["prefill"] == 1            # the fallback traced
    ref = ServeEngine(m, params, slots=2, max_len=64, seed=5)
    q_long = ref.submit(long_p, 4)
    q_short = ref.submit(short_p, 4)
    ref.run_until_drained()
    assert r_long.out == q_long.out and r_short.out == q_short.out


def test_moe_family_keeps_exact_admission():
    """MoE capacity routing couples packed rows, so the adapter must
    silently drop the admission config and serve the exact path."""
    m, params = _setup("qwen2-moe-a2.7b-smoke")
    adm = AdmissionConfig(pack=2)
    eng = ServeEngine(m, params, slots=2, max_len=32, seed=0, admission=adm)
    assert eng.workload.admission is None
    assert eng._admission is None
    req = eng.submit(_prompts(1, lens=[6], seed=1)[0], 3)
    eng.run_until_drained()
    assert len(req.out) == 3 and req.status == "ok"


def test_bucket_metrics_exported():
    m, params = _setup()
    adm = AdmissionConfig(pack=2)
    eng = ServeEngine(m, params, slots=4, max_len=64, seed=0, admission=adm)
    for p in _prompts(4, lens=[3, 5, 20, 25], seed=8):
        eng.submit(p, 2)
    eng.run_until_drained()
    assert int(eng.stats.c_packed_rows.value) == 4
    by_bucket = {k: int(c.value)
                 for k, c in eng.stats.c_admit_bucket.children.items()}
    assert sum(by_bucket.values()) == 2              # two packed flushes


# ---------------------------------------------------------------------------
# chunked prefill scheduling
# ---------------------------------------------------------------------------


def test_chunked_prefill_interleaves_with_decode():
    """While a long prompt admits chunk-by-chunk, co-resident short
    requests must keep decoding — the long arrival cannot freeze them."""
    m, params = _setup()
    adm = AdmissionConfig(pack=1, chunk_tokens=8, chunk_calls_per_tick=1)
    eng = ServeEngine(m, params, slots=2, max_len=64, seed=2, admission=adm)
    short = eng.submit(_prompts(1, lens=[3], seed=1)[0], 8)
    long_r = eng.submit(_prompts(1, lens=[50], seed=2)[0], 4)
    # short decodes while long is still admitting (49 prefix / 8 = 7 calls)
    progressed = False
    for _ in range(5):
        eng.tick()
        if short.out and not eng.workload.admit_complete(long_r):
            progressed = True
    assert progressed, "short request starved behind chunked admission"
    eng.run_until_drained()
    assert short.status == "ok" and long_r.status == "ok"
    assert len(long_r.out) == 4
    assert int(eng.stats.c_chunk_calls.value) == 7


def test_chunk_calls_per_tick_budget():
    m, params = _setup()
    adm = AdmissionConfig(chunk_tokens=8, chunk_calls_per_tick=2)
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=2, admission=adm)
    req = eng.submit(_prompts(1, lens=[40], seed=3)[0], 2)
    eng.tick()                     # first chunk rides the admit tick
    assert req.cursor == 8
    eng.tick()                     # then 2 chunk calls per tick
    assert req.cursor == 24
    eng.run_until_drained()
    assert req.status == "ok" and len(req.out) == 2


def test_admission_only_tick_advances_clock_not_step():
    m, params = _setup()
    adm = AdmissionConfig(chunk_tokens=8)
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=2, admission=adm)
    eng.submit(_prompts(1, lens=[30], seed=4)[0], 2)
    steps0 = int(eng.stats.c_steps.value)
    busy = eng.tick()
    assert busy == 1                         # slot held, nothing decodable
    assert int(eng.stats.c_steps.value) == steps0   # no fused step ran


# ---------------------------------------------------------------------------
# background emit queue
# ---------------------------------------------------------------------------


def test_async_emitter_order_and_flush():
    class R:
        pass

    got = []
    em = AsyncEmitter(on_emit=lambda req, piece: got.append(piece))
    r = R()
    for i in range(50):
        em.push(r, i)
    assert em.flush(timeout=5.0)
    assert r.detok == [f"<{i}>" for i in range(50)]   # per-request order
    assert got == r.detok
    assert em.emitted == 50 and em.errors == 0
    em.close()
    with pytest.raises(RuntimeError):
        em.push(r, 0)
    em.close()                                        # idempotent


def test_async_emitter_survives_detok_errors():
    def bad(item):
        if int(item) == 2:
            raise RuntimeError("boom")
        return default_detok(item)

    class R:
        pass

    em = AsyncEmitter(detok=bad)
    r = R()
    for i in range(4):
        em.push(r, i)
    assert em.flush(timeout=5.0)
    assert em.errors == 1 and em.emitted == 3
    assert r.detok == ["<0>", "<1>", "<3>"]
    em.close()


def test_engine_emits_in_background():
    m, params = _setup()
    adm = AdmissionConfig(pack=2)
    eng = ServeEngine(m, params, slots=2, max_len=64, seed=1, admission=adm)
    reqs = [eng.submit(p, 4) for p in _prompts(3, seed=5)]
    eng.run_until_drained()                 # drain flushes the emitter
    for r in reqs:
        assert r.detok == [f"<{t}>" for t in r.out]
    assert eng.emitter.emitted == sum(len(r.out) for r in reqs)


def test_engine_emitter_opt_out():
    m, params = _setup()
    eng = ServeEngine(m, params, slots=2, max_len=64,
                      admission=AdmissionConfig(), emitter=False)
    assert eng.emitter is None
    req = eng.submit(_prompts(1, seed=6)[0], 3)
    eng.run_until_drained()
    assert not hasattr(req, "detok")


# ---------------------------------------------------------------------------
# policy fixes: queue-TTFT deadline + doomed-shed (satellite 4)
# ---------------------------------------------------------------------------


def test_queue_ttft_deadline_measured_from_enqueue():
    """A queued request past its TTFT budget terminates with the
    queue_ttft edge — it cannot emit in time even if admitted now.
    Regression: the old queue check only looked at the e2e deadline."""
    m, params = _setup()
    clock = VirtualClock()
    adm = AdmissionConfig(chunk_tokens=8)
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=0, admission=adm,
                      policy=ServePolicy(), clock=clock)
    occupant = eng.submit(_prompts(1, lens=[50], seed=1)[0], 20)
    starved = eng.submit(_prompts(1, lens=[3], seed=2)[0], 4,
                         ttft_deadline_ms=6.0)
    for _ in range(40):
        eng.tick()
        clock.advance(0.002)               # 2 virtual ms per tick
        if starved.done:
            break
    assert starved.status == "deadline" and starved.out == []
    assert int(eng.stats.c_deadline_miss.labels(edge="queue_ttft").value) == 1
    assert occupant.status != "deadline" or occupant.done


def test_doomed_request_shed_before_admission():
    """A queued request whose remaining TTFT budget cannot cover its
    admission call count (admit_calls x admit_eta_ms) sheds early with
    reason=doomed instead of burning device calls on a guaranteed miss."""
    m, params = _setup()
    clock = VirtualClock()
    adm = AdmissionConfig(chunk_tokens=8)
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=0, admission=adm,
                      policy=ServePolicy(admit_eta_ms=2.0), clock=clock)
    occupant = eng.submit(_prompts(1, lens=[3], seed=1)[0], 6)
    # 50-token prompt -> ceil(49/8) = 7 chunk calls x 2 ms = 14 ms of
    # admission; a 10 ms TTFT budget can never be met
    doomed = eng.submit(_prompts(1, lens=[50], seed=2)[0], 4,
                        ttft_deadline_ms=10.0)
    chunk0 = int(eng.stats.c_chunk_calls.value)
    for _ in range(30):
        eng.tick()
        clock.advance(0.001)
        if doomed.done:
            break
    assert doomed.status == "shed"
    assert int(eng.stats.c_shed.labels(reason="doomed").value) == 1
    assert int(eng.stats.c_chunk_calls.value) == chunk0   # zero device work
    shed_events = [dict(a) for _, n, a in eng.resil_log if n == "shed"]
    assert any(e.get("reason") == "doomed" for e in shed_events)
    eng.run_until_drained()
    assert occupant.status == "ok"
    assert len(eng.done) == 2                             # exactly-once


def test_feasible_request_not_doomed():
    """The doomed check must not fire when the budget covers admission."""
    m, params = _setup()
    clock = VirtualClock()
    adm = AdmissionConfig(chunk_tokens=8)
    eng = ServeEngine(m, params, slots=1, max_len=64, seed=0, admission=adm,
                      policy=ServePolicy(admit_eta_ms=0.1), clock=clock)
    req = eng.submit(_prompts(1, lens=[50], seed=3)[0], 2,
                     ttft_deadline_ms=500.0)
    for _ in range(40):
        eng.tick()
        clock.advance(0.0005)
        if req.done:
            break
    assert req.status == "ok"
    assert int(eng.stats.c_shed.labels(reason="doomed").value) == 0


# ---------------------------------------------------------------------------
# fleet routing + sharded warmup
# ---------------------------------------------------------------------------


def test_fleet_backlog_routing_weighs_admission_work():
    from repro.dist.fleet import FleetSupervisor

    m, params = _setup()
    adm = AdmissionConfig(chunk_tokens=8)

    def build(mesh, rid):
        return ServeEngine(m, params, slots=2, max_len=64, seed=rid,
                           admission=adm, emitter=False)

    sup = FleetSupervisor(build, 2, route_by="backlog")
    grinder = sup.replicas[0].engine
    busy = sup.replicas[1].engine
    # replica 0: ONE long prompt mid-chunked-admission (heavy backlog,
    # light request count); replica 1: two short decoding requests
    grinder.submit(_prompts(1, lens=[60], seed=1)[0], 8)
    grinder.tick()                                  # first chunk only
    busy.submit(_prompts(1, lens=[3], seed=2)[0], 8)
    busy.submit(_prompts(1, lens=[4], seed=3)[0], 8)
    busy.tick()
    assert sup._route().rid == 1                    # backlog: avoid grinder
    sup.route_by = "slots"
    assert sup._route().rid == 0                    # legacy: fewer requests
    sup.route_by = "backlog"
    done = sup.run_until_drained()
    assert len(done) == 3 and all(r.status == "ok" for r in done)


def test_sharded_engine_admission_warms_after_device_put():
    """ShardedServeCore must defer warmup until params/state carry their
    final shardings — the first live call then retraces nothing."""
    from repro.serve.sharded import ShardedServeEngine
    from repro.dist import meshctx

    m, params = _setup()
    mesh = meshctx.make_mesh((1, 1), ("data", "model"))
    adm = AdmissionConfig(pack=2, chunk_tokens=16)
    eng = ShardedServeEngine(m, params, mesh=mesh, slots=2, max_len=64,
                             admission=adm)
    wl = eng.workload
    before = dict(wl.trace_counts)
    assert before["prefill_batch"] == len(wl.admission.buckets)
    reqs = [eng.submit(p, 3) for p in _prompts(4, seed=9)]
    eng.run_until_drained()
    assert wl.trace_counts == before        # zero post-warmup compiles
    assert all(r.status == "ok" and len(r.out) == 3 for r in reqs)
