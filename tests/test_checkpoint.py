import json
import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


def _tree(v):
    return {"a": jnp.full((4, 4), v, jnp.float32),
            "b": {"c": jnp.arange(8, dtype=jnp.int32) + int(v)}}


def test_roundtrip_and_gc():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=2)
        for s in (10, 20, 30):
            ck.save(s, _tree(s), extra={"data_step": s}, blocking=True)
        assert ck.all_steps() == [20, 30]  # keep=2 gc'd step 10
        got = ck.restore_latest(_tree(0))
        assert got is not None
        step, tree, extra = got
        assert step == 30 and extra["data_step"] == 30
        assert float(tree["a"][0, 0]) == 30.0
    finally:
        shutil.rmtree(d)


def test_torn_write_detected():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=3)
        ck.save(1, _tree(1), blocking=True)
        ck.save(2, _tree(2), blocking=True)
        # corrupt newest: delete an array file
        newest = Path(d) / "step_0000000002"
        manifest = json.loads((newest / "manifest.json").read_text())
        victim = next(iter(manifest["arrays"].values()))["file"]
        (newest / victim).unlink()
        assert ck.latest_valid_step() == 1  # falls back
    finally:
        shutil.rmtree(d)


def test_async_save():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d)
        ck.save(5, _tree(5), blocking=False)
        ck.wait()
        assert ck.all_steps() == [5]
    finally:
        shutil.rmtree(d)


def test_same_size_bit_corruption_detected():
    """ISSUE 8: the manifest digest catches same-size byte corruption (a
    bad sector, not just a torn write): latest_valid_step falls back and
    a direct restore of the corrupt step raises."""
    import pytest

    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=3)
        ck.save(1, _tree(1), blocking=True)
        ck.save(2, _tree(2), blocking=True)
        newest = Path(d) / "step_0000000002"
        manifest = json.loads((newest / "manifest.json").read_text())
        victim = newest / next(iter(manifest["arrays"].values()))["file"]
        blob = bytearray(victim.read_bytes())
        blob[-1] ^= 0x40                  # flip one payload bit, same size
        victim.write_bytes(bytes(blob))
        assert ck.latest_valid_step() == 1
        with pytest.raises((ValueError, KeyError)):
            ck.restore(2, _tree(0))
    finally:
        shutil.rmtree(d)
