"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
output shapes + finite values (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model, concrete_batch
from repro.train import step as step_mod

ARCHS = [
    "qwen2-moe-a2.7b", "granite-moe-3b-a800m", "mistral-nemo-12b",
    "h2o-danube-1.8b", "qwen2.5-3b", "tinyllama-1.1b", "recurrentgemma-2b",
    "internvl2-1b", "hubert-xlarge", "mamba2-370m",
]


def test_all_archs_registered():
    assert set(ARCHS) == set(list_configs())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    batch = concrete_batch(cfg, seq=32, batch=2)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b, remat="none"))(params, batch)
    S_out = 32
    assert logits.shape[0] == 2 and logits.shape[1] == S_out
    assert bool(jnp.isfinite(logits).all())
    state = step_mod.init_state(m, jax.random.PRNGKey(1))
    scfg = step_mod.StepConfig(remat="none", total_steps=10, warmup=2)
    state2, metrics = jax.jit(
        lambda s, b: step_mod.train_step(m, scfg, s, b))(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert 3.0 < float(metrics["loss"]) < 10.0
    # params actually changed
    d0 = jax.tree_util.tree_leaves(state.params)[0]
    d1 = jax.tree_util.tree_leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b",
                                  "mamba2-370m"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    batch = concrete_batch(cfg, seq=8, batch=2)
    full, _ = jax.jit(lambda p, b: m.forward(p, b, remat="none"))(params, batch)
    cache = m.init_cache(tp=1, batch=2, max_len=16)
    step = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    outs = []
    for t in range(8):
        lg, cache = step(params, cache, batch["tokens"][:, t:t + 1])
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, 1)
    ref = np.asarray(full)[:, :, :dec.shape[-1]]
    np.testing.assert_allclose(dec, ref, atol=0.35, rtol=0.1)


def test_swa_masks_distant_tokens():
    """Danube's sliding window: logits at position t must not depend on
    tokens further back than the window."""
    cfg = get_config("h2o-danube-1.8b-smoke")  # swa_window=32
    import dataclasses

    cfg = dataclasses.replace(cfg, swa_window=4)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    b1 = concrete_batch(cfg, seq=16, batch=1)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[0, 0].set((b2["tokens"][0, 0] + 7) % cfg.vocab)
    f = jax.jit(lambda p, b: m.forward(p, b, remat="none")[0])
    l1, l2 = f(params, b1), f(params, b2)
    # position 15 is > window away from position 0 -> unchanged
    np.testing.assert_allclose(np.asarray(l1[0, 15]), np.asarray(l2[0, 15]),
                               atol=1e-3)
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]), atol=1e-3)


def test_blockwise_attention_matches_full():
    from repro.models import attention as attn

    k = jax.random.PRNGKey(0)
    B, S, H, KV, D = 2, 256, 4, 2, 16
    q = jax.random.normal(k, (B, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KV, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KV, D), jnp.float32)
    full = attn.attn_full(q, kk, v, causal=True)
    blk = attn.attn_blockwise(q, kk, v, causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blk), atol=2e-5)
    # windowed path
    fullw = attn.attn_full(q, kk, v, causal=True, window=64)
    blkw = attn.attn_blockwise(q, kk, v, causal=True, window=64,
                               q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(fullw), np.asarray(blkw), atol=2e-5)


def test_quantized_kv_cache_decode():
    """§Perf B2 feature: int8 cache decode stays close to bf16-cache decode."""
    cfg = get_config("tinyllama-1.1b-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), tp=1)
    batch = concrete_batch(cfg, seq=8, batch=2)
    step = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    caches = {
        "bf16": m.init_cache(tp=1, batch=2, max_len=16, quant=False),
        "int8": m.init_cache(tp=1, batch=2, max_len=16, quant=True),
    }
    outs = {}
    for name, cache in caches.items():
        o = []
        for t in range(8):
            lg, cache = step(params, cache, batch["tokens"][:, t:t + 1])
            o.append(np.asarray(lg[:, 0]))
        outs[name] = np.stack(o, 1)
    diff = np.abs(outs["bf16"] - outs["int8"]).max()
    assert diff < 0.5, diff


def test_moe_int8_experts_train(monkeypatch):
    """§Perf C1 feature: int8 expert path (STE backward) still learns."""
    import importlib

    import repro.models.moe as moe_mod

    monkeypatch.setenv("REPRO_MOE_INT8", "1")
    importlib.reload(moe_mod)
    try:
        cfg = get_config("qwen2-moe-a2.7b-smoke")
        m = build_model(cfg)
        state = step_mod.init_state(m, jax.random.PRNGKey(0))
        scfg = step_mod.StepConfig(remat="none", total_steps=40, warmup=2)
        batch = concrete_batch(cfg, seq=16, batch=2)
        f = jax.jit(lambda s, b: step_mod.train_step(m, scfg, s, b))
        losses = []
        for _ in range(25):
            state, metrics = f(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])
    finally:
        monkeypatch.delenv("REPRO_MOE_INT8")
        importlib.reload(moe_mod)


def test_bwd_bf16_matmul_grads_close():
    """§Perf A1 feature: bf16-reduction matmul grads ~ exact grads."""
    from repro.kernels.ops import _matmul_bf16_bwd

    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (16, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(k, 1), (64, 32), jnp.float32)

    def f_ref(x, w):
        return jnp.sum(jnp.matmul(x, w.astype(x.dtype),
                                  preferred_element_type=jnp.float32) ** 2)

    def f_ax(x, w):
        return jnp.sum(_matmul_bf16_bwd(x, w).astype(jnp.float32) ** 2)

    g_ref = jax.grad(f_ref, argnums=1)(x, w)
    g_ax = jax.grad(f_ax, argnums=1)(x, w)
    rel = float(jnp.abs(g_ax - g_ref).max() / jnp.abs(g_ref).max())
    assert rel < 0.05, rel
