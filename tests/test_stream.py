"""Streaming DSP/vision workload on the generic serve core (ISSUE 7):
dispatch fir/conv2d route bit-identity, streaming continuity, the stream
engine's slot lifecycle (reuse-after-free bit-identity via the generic
cache_ops helpers over StreamState), the PSNR-calibrated plan walking its
QoS ladder at one compile, and the pluggable-metric quality tap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, dsp
from repro.models.cache_ops import cache_mask_update, cache_reset_slot
from repro.serve.stream import (StreamAdapter, StreamConfig,
                                StreamServeEngine, StreamState, make_clip,
                                psnr_metric)


@pytest.fixture(autouse=True)
def _reset_backend():
    yield
    dispatch.set_backend(None)


def _adapter():
    return StreamAdapter(StreamConfig())


# ---------------------------------------------------------------------------
# dispatch routes
# ---------------------------------------------------------------------------


def test_fir_route_bit_identical_and_recorded():
    rng = np.random.default_rng(3)
    sig = rng.integers(-2**14, 2**14, 512).astype(np.int32)
    taps = rng.integers(-2**13, 2**13, 8).astype(np.int32)
    outs = {}
    for be in ("pallas", "xla"):
        dispatch.set_backend(be)
        outs[be] = dispatch.fir(sig, taps, p=1, r=4)
        assert dispatch.last_route["fir"] == be
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


def test_conv2d_route_bit_identical_and_recorded():
    rng = np.random.default_rng(4)
    img = rng.integers(-2**11, 2**11, (2, 16, 16)).astype(np.int32)
    kern = dsp.quantize_weights(
        np.array([[1.0, 2.0, 1.0], [2.0, 4.0, 2.0], [1.0, 2.0, 1.0]]), 8)
    outs = {}
    for be in ("pallas", "xla"):
        dispatch.set_backend(be)
        outs[be] = np.asarray(
            dispatch.conv2d(jnp.asarray(img), jnp.asarray(kern), p=1, r=2,
                            shift=8, pad="edge"))
        assert dispatch.last_route["conv2d"] == be
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


def test_fir_degree_and_raw_knobs_exclusive():
    sig = np.ones(64, np.int32)
    taps = np.ones(4, np.int32)
    with pytest.raises(ValueError):
        dispatch.fir(sig, taps, degree=6, p=1)


def test_streaming_fir_matches_whole_signal():
    """Frame-by-frame filtering with a carried tail is bit-identical to
    filtering the concatenated signal in one call."""
    cfg = StreamConfig()
    taps = dsp.quantize_weights(np.hanning(cfg.taps + 2)[1:-1], cfg.q)
    clip = make_clip(4, cfg.frame, q=cfg.q, seed=5)      # (4, frame)
    whole = clip.reshape(1, -1)
    tail0 = jnp.zeros((1, cfg.taps - 1), jnp.int32)
    y_whole, _ = dispatch.fir(jnp.asarray(whole), jnp.asarray(taps),
                              tail=tail0, p=1, r=4, shift=cfg.q)
    tail = tail0
    ys = []
    for f in clip:
        y, tail = dispatch.fir(jnp.asarray(f[None]), jnp.asarray(taps),
                               tail=tail, p=1, r=4, shift=cfg.q)
        ys.append(np.asarray(y))
    np.testing.assert_array_equal(np.concatenate(ys, axis=1),
                                  np.asarray(y_whole))


def test_fir_approx_grad_is_exact_correlation():
    """The float entry's backward is the exact-correlation STE: its grads
    equal differentiating the exact einsum, and the forward runs the int
    PR datapath (nonzero deviation at an approximate degree)."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.uniform(-0.9, 0.9, (2, 64)), jnp.float32)
    taps = jnp.asarray(np.hanning(6) / np.hanning(6).sum(), jnp.float32)

    def exact(x, t):
        ext = jnp.concatenate(
            [jnp.zeros((x.shape[0], t.shape[0] - 1), x.dtype), x], axis=1)
        win = jnp.stack([ext[:, i:i + x.shape[1]] for i in range(t.shape[0])])
        return jnp.einsum("i,ibl->bl", t, win)

    def loss(fn):
        return lambda x, t: jnp.sum(jnp.sin(fn(x, t)))

    gx, gt = jax.grad(loss(
        lambda x, t: dispatch.fir_approx(x, t, degree=4)), argnums=(0, 1))(
            x, taps)
    ex, et = jax.grad(loss(exact), argnums=(0, 1))(x, taps)
    # STE: cotangents flow through the exact path; forward quantization
    # perturbs only the point the loss gradient is evaluated at
    assert np.allclose(np.asarray(gx), np.asarray(ex), atol=0.05)
    assert np.allclose(np.asarray(gt), np.asarray(et), atol=0.5)
    y = dispatch.fir_approx(x, taps, degree=4)
    assert float(jnp.max(jnp.abs(y - exact(x, taps)))) > 0


# ---------------------------------------------------------------------------
# cache_ops generics over StreamState (satellite: slot reset / masking)
# ---------------------------------------------------------------------------


def _filled_state(B=3, T=8):
    return StreamState(
        length=jnp.arange(1, B + 1, dtype=jnp.int32),
        tail=jnp.arange(B * (T - 1), dtype=jnp.int32).reshape(1, B, T - 1))


def test_cache_reset_slot_zeros_only_that_slot():
    st = _filled_state()
    out = cache_reset_slot(st, 1)
    assert int(out.length[1]) == 0
    np.testing.assert_array_equal(np.asarray(out.tail[0, 1]), 0)
    for s in (0, 2):                      # neighbors bit-untouched
        assert int(out.length[s]) == int(st.length[s])
        np.testing.assert_array_equal(np.asarray(out.tail[0, s]),
                                      np.asarray(st.tail[0, s]))


def test_cache_mask_update_freezes_inactive_slots():
    st = _filled_state()
    new = StreamState(length=st.length + 5, tail=st.tail + 100)
    active = jnp.asarray([True, False, True])
    out = cache_mask_update(st, new, active)
    # the length counter is the masked field: inactive slots keep theirs
    assert int(out.length[1]) == int(st.length[1])
    assert int(out.length[0]) == int(new.length[0])
    assert int(out.length[2]) == int(new.length[2])


def test_reuse_after_free_bit_identity():
    """A slot that served an earlier clip produces bit-identical output for
    a new clip vs a fresh engine — admission's cache_reset_slot rewind over
    the StreamState NamedTuple leaves no residue (FIR tail zeroed)."""
    ad = _adapter()
    params = ad.init_params()
    clip = make_clip(3, ad.cfg.frame, q=ad.cfg.q, seed=11)

    fresh = StreamServeEngine(ad, params, slots=1)
    r0 = fresh.submit(clip)
    fresh.run_until_drained()

    used = StreamServeEngine(ad, params, slots=1)
    used.submit(make_clip(2, ad.cfg.frame, q=ad.cfg.q, seed=12, kind="noise"))
    used.run_until_drained()              # dirty the only slot, then reuse it
    r1 = used.submit(clip)
    used.run_until_drained()

    assert len(r0.out) == len(r1.out) == 3
    for a, b in zip(r0.out, r1.out):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# engine end to end
# ---------------------------------------------------------------------------


def test_stream_engine_end_to_end_matches_manual_steps():
    ad = _adapter()
    params = ad.init_params()
    eng = StreamServeEngine(ad, params, slots=2)
    clip = make_clip(4, ad.cfg.frame, q=ad.cfg.q, seed=7)
    req = eng.submit(clip)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [req.rid]
    assert len(req.out) == 4 and req.done

    # replay the pipeline by hand: same step math, one slot, no engine
    state = ad.init_state(batch=1)
    active = jnp.asarray([True])
    tail = state
    for i, (frame, got) in enumerate(zip(clip, req.out)):
        out, tail = ad.step(params, tail, jnp.asarray(frame[None]), active,
                            None, None)
        np.testing.assert_array_equal(np.asarray(out)[0], got)


def test_stream_validate_rejects_bad_payloads():
    ad = _adapter()
    with pytest.raises(ValueError):
        ad.validate(np.zeros((2, ad.cfg.frame + 1), np.int32))
    with pytest.raises(ValueError):
        ad.validate(np.zeros((0, ad.cfg.frame), np.int32))
    with pytest.raises(ValueError):
        ad.validate(np.full((1, ad.cfg.frame), 2**15, np.int32))


def test_engine_interleaves_more_clips_than_slots():
    ad = _adapter()
    eng = StreamServeEngine(ad, slots=2)
    clips = [make_clip(3, ad.cfg.frame, q=ad.cfg.q, seed=i) for i in range(5)]
    reqs = [eng.submit(c) for c in clips]
    done = eng.run_until_drained()
    assert len(done) == 5
    solo = StreamServeEngine(ad, slots=1)
    for r, c in zip(reqs, clips):
        s = solo.submit(c)
        solo.run_until_drained()
        for a, b in zip(r.out, s.out):    # batching never changes the bits
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# PSNR plan + QoS ladder at one compile
# ---------------------------------------------------------------------------


def test_psnr_plan_walks_ladder_at_one_compile():
    from repro.tune import build_plan
    from repro.tune.autotune import _Prober

    ad = _adapter()
    params = ad.init_params()
    calib = {"frames": np.stack(
        [make_clip(3, ad.cfg.frame, q=ad.cfg.q, seed=i) for i in range(2)])}
    prober = _Prober(ad, params, calib, metric=psnr_metric)
    plan = build_plan(ad, params, calib, grid=(8, 6, 4), prober=prober,
                      metric=psnr_metric)
    assert plan.sites == ["fir", "conv2d", "gain"]
    assert plan.meta["metric"] == "neg_psnr_db"
    assert len(plan.ladder) >= 2
    # errors are neg-PSNR: monotone non-decreasing down the ladder
    errs = [pt.error for pt in plan.ladder]
    assert errs == sorted(errs)

    eng = StreamServeEngine(ad, params, slots=2, plan=plan)
    for rung in range(len(plan.ladder)):
        eng._degree = jnp.asarray(plan.degrees(rung), jnp.int32)
        eng.submit(make_clip(2, ad.cfg.frame, q=ad.cfg.q, seed=rung))
        eng.run_until_drained()
    assert len(eng.done) == len(plan.ladder)
    assert eng._step._cache_size() == 1   # rung moves never retrace


def test_quality_tap_records_psnr_histogram():
    ad = _adapter()
    eng = StreamServeEngine(ad, slots=2, degree=[8, 6, 8], quality_every=1)
    eng.submit(make_clip(3, ad.cfg.frame, q=ad.cfg.q, seed=1))
    eng.run_until_drained()
    assert eng._tap is not None and eng._tap.samples > 0
    fam = eng.stats.registry.get("repro_quality_psnr_db")
    assert fam is not None
    (labels, hist), = fam.children.items()
    assert hist.count == eng._tap.samples
    assert hist.sum > 0                   # PSNR in dB, not a tiny rel-err


def test_stream_quarantine_reset_bit_identical_to_fresh_admission():
    """ISSUE 8 twin of the LM-family quarantine test: the stream slot's
    reset after a guard trip must reproduce the never-faulted frames
    bit-for-bit (StreamState tail/hist regions rewound exactly)."""
    from repro.resil import FaultEvent, FaultPlan, GuardConfig

    ad = _adapter()
    clip = make_clip(5, ad.cfg.frame, q=ad.cfg.q, seed=3)
    plan = FaultPlan(events=[FaultEvent(tick=2, kind="nan", slot=0,
                                        value=float("nan"))])
    eng = StreamServeEngine(ad, slots=2, faults=plan)
    hit = eng.submit(clip)
    # a clean neighbor shares the batch: its frames must be untouched by
    # the other slot's quarantine
    neighbor = eng.submit(make_clip(5, ad.cfg.frame, q=ad.cfg.q, seed=4))
    eng.run_until_drained()
    assert hit.status == "ok" and hit.retries == 1

    ref_eng = StreamServeEngine(ad, slots=2, guards=GuardConfig())
    ref = ref_eng.submit(clip)
    ref_n = ref_eng.submit(make_clip(5, ad.cfg.frame, q=ad.cfg.q, seed=4))
    ref_eng.run_until_drained()
    assert len(hit.out) == len(ref.out) == 5
    for got, want in zip(hit.out, ref.out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(neighbor.out, ref_n.out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
