"""The model-layer dispatch: every mode runs and degrades gracefully."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx import ApproxMode, ApproxSpec
from repro.kernels.ops import approx_matmul

K = jax.random.PRNGKey(0)
X = jax.random.normal(K, (32, 256), jnp.float32)
W = jax.random.normal(jax.random.fold_in(K, 1), (256, 64), jnp.float32)
EXACT = np.asarray(X @ W)


def rel(y):
    return float(np.abs(np.asarray(y) - EXACT).mean() / np.abs(EXACT).mean())


def test_exact_mode():
    y = approx_matmul(X, W, ApproxSpec(mode=ApproxMode.EXACT))
    assert rel(y) < 1e-6


@pytest.mark.parametrize("mode,kw,band", [
    (ApproxMode.AXQ, dict(ebits=8, block=256), 0.03),
    (ApproxMode.AXQ, dict(ebits=5, block=256), 0.25),
    (ApproxMode.PR_EMUL, dict(p=1, r=2, lane_bits=8), 0.2),
    (ApproxMode.RAD_EMUL, dict(k=4, lane_bits=8), 0.2),
    (ApproxMode.ROUP_EMUL, dict(k=4, p=0, r=1, lane_bits=8), 0.3),
    (ApproxMode.POW2_W, dict(), 0.35),
])
def test_modes_bounded_error(mode, kw, band):
    y = approx_matmul(X, W, ApproxSpec(mode=mode, **kw))
    r = rel(y)
    assert 0 < r < band, (mode, r)


def test_policy_path_dispatch():
    from repro.core.approx import ApproxPolicy

    pol = ApproxPolicy(rules=[(r".*mlp.*", ApproxSpec(mode=ApproxMode.AXQ, ebits=6))])
    assert pol.spec_for("layer/mlp/up").mode == ApproxMode.AXQ
    assert pol.spec_for("layer/wq").mode == ApproxMode.EXACT
    pol2 = pol.with_degree(ebits=4)
    assert pol2.spec_for("layer/mlp/up").ebits == 4


def test_dynamic_degree_is_runtime():
    spec = ApproxSpec(mode=ApproxMode.AXQ, dynamic=True, block=256)
    f = jax.jit(lambda x, w, d: approx_matmul(x, w, spec, degree=d))
    y8 = f(X, W, jnp.int32(8))
    y4 = f(X, W, jnp.int32(4))
    assert rel(y8) < rel(y4)
