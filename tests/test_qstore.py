"""Quantize-once weight residency + fused GEMM epilogues (DESIGN.md §9).

The load-bearing claim: prepacking moves quantization from per-call to
load-time without changing a single bit of the computation — asserted as
op-level (eager) bit-identity across all four model families and both serve
cache dtypes.  Fused epilogues (gated first half, bias/residual writeback)
are checked against their multi-call oracles, and the axqmm custom-VJP
(kernel fwd, qmm_ref-oracle bwd) is grad-checked against the pure-jnp path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.core.quantization import qmm_gated_ref, qmm_ref
from repro.kernels import dispatch as kdispatch
from repro.kernels import qstore
from repro.kernels.axqmm import axqmm, axqmm_gated, axqmm_gated_packed, axqmm_packed
from repro.kernels.ops import approx_matmul
from repro.models import build_model, concrete_batch

AXQ_POLICY = ApproxPolicy(default=ApproxSpec(mode=ApproxMode.AXQ, ebits=8,
                                             block=64))
FAMILY_ARCHS = ["tinyllama-1.1b-smoke", "qwen2-moe-a2.7b-smoke",
                "mamba2-370m-smoke", "recurrentgemma-2b-smoke"]

_CACHE: dict = {}


def _setup(arch):
    if arch not in _CACHE:
        cfg = get_config(arch)
        m = build_model(cfg, AXQ_POLICY)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[arch] = (m, params, m.prepack(params))
    return _CACHE[arch]


# ---------------------------------------------------------------------------
# block resolution (satellite: loud failure + caching)
# ---------------------------------------------------------------------------


def test_resolve_block_shrinks_and_caches():
    assert qstore.resolve_block(512, 512) == 512
    assert qstore.resolve_block(192, 512) == 192     # min(requested, K)
    assert qstore.resolve_block(192, 128) == 64      # 128 -> 64 divides 192
    assert qstore.resolve_block(96, 64) == 32        # 64 -> 32 divides 96
    assert qstore.resolve_block(255, 64) == 1        # odd K walks down to 1
    before = qstore.resolve_block.cache_info().hits
    qstore.resolve_block(255, 64)
    assert qstore.resolve_block.cache_info().hits == before + 1


def test_resolve_block_fails_loudly():
    with pytest.raises(ValueError):
        qstore.resolve_block(256, 0)
    with pytest.raises(ValueError):
        qstore.resolve_block(256, -64)
    with pytest.raises(ValueError):
        qstore.resolve_block(0, 256)


# ---------------------------------------------------------------------------
# kernel-level: packed vs on-the-fly, fused vs oracle
# ---------------------------------------------------------------------------


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("shape", [(32, 256, 96), (3, 512, 130)])
def test_axqmm_packed_bit_identical_to_onthefly(shape):
    M, K, N = shape
    x, w = _rand((M, K), 0), _rand((K, N), 1)
    pw = qstore.prepack_weight(w, qstore.resolve_block(K, 256))
    y_fly = axqmm(x, w, block=256)
    y_pack = axqmm_packed(x, pw)
    assert (np.asarray(y_fly) == np.asarray(y_pack)).all()
    # and the jnp (xla-route) oracle pair agrees with itself the same way
    yr_fly = qmm_ref(x, w, block=qstore.resolve_block(K, 256))
    from repro.core.quantization import qmm_packed_ref

    yr_pack = qmm_packed_ref(x, pw.qw, pw.scales)
    assert (np.asarray(yr_fly) == np.asarray(yr_pack)).all()


def test_axqmm_gated_matches_three_call_oracle():
    M, K, N = 40, 256, 130
    x, wu, wg = _rand((M, K), 0), _rand((K, N), 1), _rand((K, N), 2)
    for act, actf in (("silu", jax.nn.silu), ("gelu", jax.nn.gelu)):
        fused = axqmm_gated(x, wu, wg, block=256, act=act)
        # three-call path: two independent GEMMs + elementwise gate
        up = axqmm(x, wu, block=256)
        gate = axqmm(x, wg, block=256)
        three = actf(gate) * up
        np.testing.assert_allclose(np.asarray(fused), np.asarray(three),
                                   rtol=1e-5, atol=1e-4)
        oracle = qmm_gated_ref(x, wu, wg, actf, block=256)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-4)


def test_axqmm_gated_packed_bit_identical_and_degradable():
    M, K, N = 16, 128, 64
    x, wu, wg = _rand((M, K), 3), _rand((K, N), 4), _rand((K, N), 5)
    pu = qstore.prepack_weight(wu, 128)
    pg = qstore.prepack_weight(wg, 128)
    y_fly = axqmm_gated(x, wu, wg, block=128)
    y_pack = axqmm_gated_packed(x, pu, pg)
    assert (np.asarray(y_fly) == np.asarray(y_pack)).all()
    # runtime degree stays a traced scalar on the packed path
    f = jax.jit(lambda x, e: axqmm_gated_packed(x, pu, pg, e))
    exact = jax.nn.silu(x @ wg) * (x @ wu)
    e8 = float(jnp.abs(f(x, jnp.int32(8)) - exact).mean())
    e4 = float(jnp.abs(f(x, jnp.int32(4)) - exact).mean())
    assert e8 < e4


def test_axqmm_bias_residual_epilogue():
    M, K, N = 24, 256, 72
    x, w = _rand((M, K), 6), _rand((K, N), 7)
    b, r = _rand((N,), 8), _rand((M, N), 9)
    pw = qstore.prepack_weight(w, 256)
    y = axqmm_packed(x, pw, bias=b, residual=r)
    base = axqmm_packed(x, pw)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(base + b[None, :] + r))


def test_dispatch_axq_matmul_routes_and_agrees():
    x, w = _rand((8, 256), 0), _rand((256, 64), 1)
    pw = qstore.prepack_weight(w, 256)
    kdispatch.set_backend("xla")
    try:
        y_x = kdispatch.axq_matmul(x, pw, block=256)
        assert kdispatch.last_route["gemm"] == "xla"
        kdispatch.set_backend("pallas")
        y_p = kdispatch.axq_matmul(x, pw, block=256)
        assert kdispatch.last_route["gemm"] == "pallas"
    finally:
        kdispatch.set_backend(None)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p), rtol=1e-6,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# custom-VJP: pallas-routed AXQ grads == jnp-path grads (satellite)
# ---------------------------------------------------------------------------


def test_axq_vjp_grads_match_jnp_path():
    x, w = _rand((16, 256), 0), _rand((256, 32), 1)

    def loss(backend):
        kdispatch.set_backend(backend)
        try:
            return jax.grad(
                lambda x, w: jnp.sum(
                    kdispatch.axq_matmul(x, w, block=64, ebits=8) ** 2),
                argnums=(0, 1))(x, w)
        finally:
            kdispatch.set_backend(None)

    gp = loss("pallas")
    gx = loss("xla")
    gref = jax.grad(
        lambda x, w: jnp.sum(qmm_ref(x, w, block=64, ebits=8) ** 2),
        argnums=(0, 1))(x, w)
    for a, b, c in zip(gp, gx, gref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c), rtol=1e-5,
                                   atol=1e-5)


def test_axq_vjp_trains_under_pallas_backend():
    """`--kernels pallas` training must route AXQ through the kernel without
    raising (the seed silently required the jnp path)."""
    spec = ApproxSpec(mode=ApproxMode.AXQ, ebits=8, block=64)
    x, w = _rand((8, 128), 2), _rand((128, 16), 3)
    kdispatch.set_backend("pallas")
    try:
        g = jax.grad(lambda w: jnp.sum(
            approx_matmul(x, w, spec) ** 2))(w)
    finally:
        kdispatch.set_backend(None)
    assert g.shape == w.shape and bool(jnp.isfinite(g).all())


def test_axq_gated_vjp_ste_is_finite_and_descends():
    x, wu, wg = _rand((8, 64), 4), _rand((64, 32), 5), _rand((64, 32), 6)

    def loss(x, wu, wg):
        return jnp.sum(kdispatch.axq_gated(x, wu, wg, block=64, ste=True) ** 2)

    g = jax.grad(loss, argnums=(1, 2))(x, wu, wg)
    scale = max(float(jnp.abs(g[0]).max()), float(jnp.abs(g[1]).max()))
    lr = 1e-4 / scale                    # small normalized descent step
    l0 = float(loss(x, wu, wg))
    l1 = float(loss(x, wu - lr * g[0], wg - lr * g[1]))
    assert np.isfinite(l1) and l1 < l0


# ---------------------------------------------------------------------------
# emul-mode weight residency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", [
    (ApproxMode.PR_EMUL, dict(p=1, r=2)),
    (ApproxMode.RAD_EMUL, dict(k=4)),
    (ApproxMode.ROUP_EMUL, dict(k=4, p=1, r=1)),
])
def test_emul_prepack_bit_identical(mode, kw):
    spec = ApproxSpec(mode=mode, lane_bits=8, **kw)
    x, w = _rand((16, 128), 0), _rand((128, 48), 1)
    pw = qstore.prepack_emul_weight(w, spec)
    y_fly = approx_matmul(x, w, spec)
    y_pack = approx_matmul(x, pw, spec)
    assert (np.asarray(y_fly) == np.asarray(y_pack)).all()


def test_packed_weight_under_exact_spec_fails_loudly():
    x, w = _rand((4, 64), 0), _rand((64, 8), 1)
    pw = qstore.prepack_weight(w, 64)
    with pytest.raises(ValueError):
        approx_matmul(x, pw, ApproxSpec(mode=ApproxMode.EXACT), path="layer/wq")


# ---------------------------------------------------------------------------
# model-level: prepack bit-identity across the zoo (tentpole claim)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prepack_decode_bit_identical(arch):
    """Eager (op-semantics) decode: prepacked params produce bit-identical
    logits and cache states — quantize-once changes *when* the weight is
    encoded, never *what* is computed."""
    m, params, pp = _setup(arch)
    batch = concrete_batch(m.cfg, seq=8, batch=2)
    ca = m.init_cache(tp=1, batch=2, max_len=16)
    cb = m.init_cache(tp=1, batch=2, max_len=16)
    for t in range(3):
        la, ca = m.decode_step(params, ca, batch["tokens"][:, t:t + 1])
        lb, cb = m.decode_step(pp, cb, batch["tokens"][:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for a, b in zip(jax.tree_util.tree_leaves(ca), jax.tree_util.tree_leaves(cb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prepack_prefill_bit_identical(arch):
    m, params, pp = _setup(arch)
    batch = concrete_batch(m.cfg, seq=8, batch=2)
    prompt = jnp.asarray(batch["tokens"][0, :5])
    la, ca = m.prefill(params, m.init_cache(tp=1, batch=2, max_len=16),
                       prompt, jnp.int32(1))
    lb, cb = m.prefill(pp, m.init_cache(tp=1, batch=2, max_len=16),
                       prompt, jnp.int32(1))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for a, b in zip(jax.tree_util.tree_leaves(ca), jax.tree_util.tree_leaves(cb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("quant", [False, True])
def test_prepack_bit_identical_both_serve_cache_dtypes(quant):
    """The residency layer composes with both serve cache dtypes (bf16 and
    int8 KV): decode through either cache is bit-identical prepacked vs
    on-the-fly."""
    m, params, pp = _setup("tinyllama-1.1b-smoke")
    batch = concrete_batch(m.cfg, seq=8, batch=2)
    ca = m.init_cache(tp=1, batch=2, max_len=16, quant=quant)
    cb = m.init_cache(tp=1, batch=2, max_len=16, quant=quant)
    for t in range(3):
        la, ca = m.decode_step(params, ca, batch["tokens"][:, t:t + 1])
        lb, cb = m.decode_step(pp, cb, batch["tokens"][:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_serve_engine_prepacks_and_drains():
    """The engine packs at admission (quantize-once at model load): packed
    params in the live engine, same greedy tokens as a no-prepack engine."""
    m, params, _ = _setup("tinyllama-1.1b-smoke")
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(m, params, slots=2, max_len=64)
    leaves = jax.tree_util.tree_leaves(
        eng.params, is_leaf=qstore.is_packed)
    assert any(qstore.is_packed(l) for l in leaves)
    r1 = eng.submit(np.array([5, 6, 7, 8]), max_new_tokens=6)
    eng.run_until_drained()
    raw = ServeEngine(m, params, slots=2, max_len=64, prepack=False)
    r2 = raw.submit(np.array([5, 6, 7, 8]), max_new_tokens=6)
    raw.run_until_drained()
    assert r1.out_tokens == r2.out_tokens


def test_prepack_idempotent_and_exact_policy_noop():
    m, params, pp = _setup("tinyllama-1.1b-smoke")
    pp2 = m.prepack(pp)
    for a, b in zip(jax.tree_util.tree_leaves(pp, is_leaf=qstore.is_packed),
                    jax.tree_util.tree_leaves(pp2, is_leaf=qstore.is_packed)):
        if qstore.is_packed(a):
            assert a.qw is b.qw
    exact = build_model(m.cfg)          # default EXACT policy
    same = exact.prepack(params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(same)):
        assert a is b
