"""Deterministic stand-in for the tiny slice of hypothesis this suite uses.

The container image has no ``hypothesis`` wheel and nothing may be pip
installed, so property tests fall back to this shim: every
``st.integers(lo, hi)`` strategy contributes its two bounds first, then
seeded pseudorandom interior points, for ``max_examples`` total draws.
Coverage is deterministic instead of adversarial, but the bit-exactness
properties still get exercised across their ranges.  With the real library
installed the test files never import this module.
"""

from __future__ import annotations

import numpy as np


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo = int(lo)
        self.hi = int(hi)

    def draw(self, i: int, rng: np.random.Generator) -> int:
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntStrategy:
        return _IntStrategy(min_value, max_value)


st = _Strategies()


def settings(max_examples: int = 12, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # strategy parameters (it would hunt for fixtures named after them)
        def wrapper():
            # read max_examples at call time: @settings may sit either above
            # @given (sets it on this wrapper) or below it (sets it on fn)
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 12))
            rng = np.random.default_rng(0)
            for i in range(n):
                fn(*(s.draw(i, rng) for s in strats))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
