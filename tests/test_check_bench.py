"""Bench-record regression gate (repro.obs.regress / tools/check_bench.py).

The committed ``benchmarks/BENCH_*.json`` records must pass their own
declared invariants, and the gate must demonstrably FAIL when a record is
perturbed — a gate that can't fail is not a gate.  Fresh-diff logic is
exercised on fabricated records (actual bench re-runs live in the CI
``bench-regress`` job, not the unit suite).
"""
import copy
import json
import subprocess
import sys

import pytest

from repro.obs import regress


@pytest.fixture(scope="module")
def committed():
    return {b: regress.load_record(b) for b in regress.BENCH_RECORDS}


def test_committed_records_pass(committed):
    errs = regress.check_committed()
    assert errs == [], "\n".join(errs)


def test_meta_stamp_complete(committed):
    for bench, rec in committed.items():
        assert rec["schema_version"] == regress.SCHEMA_VERSION
        assert rec["git_sha"] not in ("", "unknown")
        assert rec["kernels_backend"] in ("pallas", "xla")
        assert rec["tiny_shapes"] is False  # committed = full shapes


def test_missing_meta_fails():
    rec = {"bench": "bench_kernels", "rows": [["a", "1", "x"]]}
    errs = regress.check_meta(rec)
    assert any("schema_version" in e for e in errs)
    assert any("git_sha" in e for e in errs)


def test_unknown_sha_fails(committed):
    rec = copy.deepcopy(committed["bench_kernels"])
    rec["git_sha"] = "unknown"
    assert any("git_sha" in e for e in regress.check_meta(rec))


@pytest.mark.parametrize("row,value,needle", [
    ("kern.axqmm_e8_relerr", "0.5", "relerr"),           # error envelope
    ("kern.axqmm_e8_vs_ref_maxdiff", "0.1", "maxdiff"),  # kernel drift
])
def test_perturbed_kernels_record_fails(committed, row, value, needle):
    rec = copy.deepcopy(committed["bench_kernels"])
    rec["rows"] = [[r[0], r[1], value] if r[0] == row else r
                   for r in rec["rows"]]
    errs = regress.check_invariants(rec)
    assert errs and any(needle in e for e in errs), errs


def test_perturbed_skip_ratio_fails(committed):
    rec = copy.deepcopy(committed["bench_kernels"])
    for r in rec["rows"]:
        if r[0] == "kern.flash_causal_skip_us":
            r[2] = "steps 99/100 (skip/dense)"
    errs = regress.check_invariants(rec)
    assert any("ratio" in e for e in errs), errs


def test_perturbed_gemm_speedup_fails(committed):
    rec = copy.deepcopy(committed["bench_gemm"])
    base = fused = None
    for r in rec["rows"]:
        if r[0] == "gemm.mlp_fly_unfused_us":
            base = float(r[1])
    for r in rec["rows"]:
        if r[0] == "gemm.mlp_packed_fused_us":
            # regress the fused path to slower-than-baseline
            r[1] = str(base * 2)
            fused = float(r[1])
            r[2] = f"{base / fused:.2f}x vs fly_unfused"
    errs = regress.check_invariants(rec)
    assert any("speedup" in e for e in errs), errs


def test_dropped_row_fails(committed):
    rec = copy.deepcopy(committed["bench_serving"])
    rec["rows"] = [r for r in rec["rows"] if "gen_tok_per_s" not in r[0]]
    errs = regress.check_invariants(rec)
    assert any("missing row" in e for e in errs), errs


def test_tune_ladder_order_fails_when_scrambled(committed):
    rec = copy.deepcopy(committed["bench_tune"])
    # make rung_1 MORE costly than rung_0 (breaks Pareto descent)
    rungs = {r[0]: r for r in rec["rows"] if r[0].startswith("tune.rung_")}
    if len(rungs) < 2:
        pytest.skip("committed plan ladder has < 2 rungs")
    r0 = rungs["tune.rung_0"]
    import re

    ec = re.search(r"err=([0-9.e+-]+),cost=([0-9.e+-]+)", r0[2])
    c0 = float(ec.group(2))
    r1 = rungs["tune.rung_1"]
    r1[2] = re.sub(r"cost=[0-9.e+-]+", f"cost={c0 * 10}", r1[2])
    errs = regress.check_invariants(rec)
    assert any("Pareto" in e for e in errs), errs


# ---------------------------------------------------------------------------
# fresh-diff logic (fabricated records; real re-runs live in CI)
# ---------------------------------------------------------------------------


def test_compare_fresh_subset_ok(committed):
    com = committed["bench_serving"]
    fresh = copy.deepcopy(com)
    fresh["tiny_shapes"] = True
    # tiny runs emit a subset of the full-shape rows: keep one slots group
    groups = sorted({r[0].split("_")[0] for r in fresh["rows"]})
    keep = [r for r in fresh["rows"] if "slots2" in r[0]] or fresh["rows"][:4]
    fresh["rows"] = keep
    errs = regress.compare_fresh(com, fresh)
    # subset coverage passes; invariants may or may not apply to the subset
    assert not any("missing from the committed" in e for e in errs), (groups,
                                                                      errs)


def test_compare_fresh_new_row_fails(committed):
    com = committed["bench_serving"]
    fresh = copy.deepcopy(com)
    fresh["rows"] = fresh["rows"] + [["serve.slots64_gen_tok_per_s",
                                     "1.0", "42.0"]]
    errs = regress.compare_fresh(com, fresh)
    assert any("missing from the committed" in e for e in errs), errs


def test_compare_fresh_bench_mismatch(committed):
    errs = regress.compare_fresh(committed["bench_serving"],
                                 committed["bench_gemm"])
    assert any("mismatch" in e for e in errs)


def test_duplicate_row_names_rejected(committed):
    rec = copy.deepcopy(committed["bench_gemm"])
    rec["rows"].append(list(rec["rows"][0]))
    with pytest.raises(ValueError):
        regress.rows_by_name(rec)
    # check_record surfaces it as a violation instead of raising
    errs = regress.check_invariants(rec)
    assert errs


def test_cli_passes_on_committed():
    out = subprocess.run(
        [sys.executable, str(regress.bench_dir().parent / "tools" /
                             "check_bench.py")],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_cli_fails_on_perturbed(tmp_path, committed):
    # a perturbed copy of all four records in a scratch dir must fail
    for bench, fname in regress.BENCH_RECORDS.items():
        rec = copy.deepcopy(committed[bench])
        (tmp_path / fname).write_text(json.dumps(rec))
    bad = copy.deepcopy(committed["bench_kernels"])
    bad["rows"] = [[r[0], r[1], "0.5"]
                   if r[0] == "kern.axqmm_e8_relerr" else r
                   for r in bad["rows"]]
    (tmp_path / regress.BENCH_RECORDS["bench_kernels"]).write_text(
        json.dumps(bad))
    errs = regress.check_committed(directory=tmp_path)
    assert errs and any("relerr" in e for e in errs)
