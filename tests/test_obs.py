"""Observability subsystem (repro.obs): tracer, metric registry, engine
instrumentation, quality tap, trainer spans.

The trace-validation tests pin the DESIGN.md §11 contract: every admitted
request shows enqueue -> prefill -> first_token with matching rids, QoS
rung transitions carry the full per-site degree vector, and the per-tick
kernel-route counters sum exactly to the executed decode steps.
"""
import json
import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.core.dynamic import QoSController
from repro.models import build_model
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, parse_text
from repro.obs.quality import QualityTap, rung_label
from repro.obs.trace import Tracer
from repro.serve.engine import ServeEngine
from repro.serve.metrics import EngineStats, _pct, summarize

_CACHE: dict = {}


def _setup(arch: str = "tinyllama-1.1b-smoke", policy=None):
    key = (arch, id(policy) if policy is not None else None)
    if key not in _CACHE:
        cfg = get_config(arch)
        m = build_model(cfg, policy) if policy is not None else build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), tp=1)
        _CACHE[key] = (m, params)
    return _CACHE[key]


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_nest_and_export():
    tr = Tracer(enabled=True)
    with tr.span("outer", track="t", a=1):
        with tr.span("inner", track="t"):
            time.sleep(0.001)
        tr.event("mark", track="t", x=2)
    evs = tr.events
    names = [e["name"] for e in evs]
    # inner exits before outer -> emitted first
    assert names == ["inner", "mark", "outer"]
    inner = evs[0]
    outer = evs[2]
    assert inner["ph"] == "X" and outer["ph"] == "X"
    assert inner["dur"] > 0
    # nesting: inner fully contained in outer's [ts, ts+dur]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert evs[1]["ph"] == "i" and evs[1]["args"] == {"x": 2}

    chrome = tr.to_chrome()
    # loadable chrome://tracing object: thread_name metadata + serializable
    assert any(e["ph"] == "M" and e["args"]["name"] == "t"
               for e in chrome["traceEvents"])
    json.dumps(chrome)                    # must be JSON-serializable
    assert chrome["displayTimeUnit"] == "ms"


def test_tracer_ring_buffer_bounded():
    tr = Tracer(capacity=8, enabled=True)
    for i in range(20):
        tr.event("e", n=i)
    assert len(tr.events) == 8
    assert tr.dropped == 12
    # oldest evicted: the survivors are the 8 most recent
    assert [e["args"]["n"] for e in tr.events] == list(range(12, 20))
    assert tr.to_chrome()["otherData"]["dropped"] == 12


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    with tr.span("s", a=1) as sp:
        pass
    tr.event("e")
    tr.counter("c", v=1)
    assert tr.events == []
    # the disabled path hands out one shared null span (no allocation)
    with tr.span("s2") as sp2:
        pass
    assert sp is sp2


def test_tracer_write_and_global_swap(tmp_path):
    old = obs_trace.get_tracer()
    try:
        tr = obs_trace.set_tracer(Tracer(enabled=True))
        obs_trace.span("x")  # context manager unused: no event
        obs_trace.event("y", track="g")
        p = tmp_path / "trace.json"
        tr.write(p)
        loaded = json.loads(p.read_text())
        assert any(e["name"] == "y" for e in loaded["traceEvents"])
    finally:
        obs_trace.set_tracer(old)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_prometheus_roundtrip():
    r = Registry()
    c = r.counter("repro_x_total", "things")
    c.inc()
    c.inc(2)
    g = r.gauge("repro_g", "a gauge")
    g.set(1.5)
    lab = r.counter("repro_lab_total", "by site", labels=("site", "backend"))
    lab.labels(site="decode", backend="xla").inc(4)
    h = r.histogram("repro_h_seconds", "lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)

    text = r.to_prometheus()
    d = parse_text(text)
    assert d[("repro_x_total", ())] == 3
    assert d[("repro_g", ())] == 1.5
    assert d[("repro_lab_total",
              (("backend", "xla"), ("site", "decode")))] == 4
    # cumulative buckets + +Inf == count
    assert d[("repro_h_seconds_bucket", (("le", "0.1"),))] == 1
    assert d[("repro_h_seconds_bucket", (("le", "1"),))] == 2
    assert d[("repro_h_seconds_bucket", (("le", "+Inf"),))] == 3
    assert d[("repro_h_seconds_count", ())] == 3
    assert d[("repro_h_seconds_sum", ())] == pytest.approx(5.55)
    # snapshot is JSON-able and agrees
    snap = r.snapshot()
    json.dumps(snap)
    assert snap["repro_x_total"]["values"][""] == 3


def test_registry_idempotent_and_conflicts():
    r = Registry()
    a = r.counter("repro_dup_total", "x")
    b = r.counter("repro_dup_total", "x")
    assert a is b
    with pytest.raises(ValueError):
        r.gauge("repro_dup_total", "now a gauge")
    with pytest.raises(ValueError):
        r.counter("repro_dup_total", "x", labels=("site",))
    with pytest.raises(ValueError):
        r.counter("0bad name")
    c = r.counter("repro_neg_total")
    with pytest.raises(ValueError):
        c.inc(-1)


def test_labelled_family_interning():
    r = Registry()
    f = r.counter("repro_l_total", "x", labels=("site",))
    f.labels(site="a").inc()
    f.labels(site="a").inc()
    f.labels(site="b").inc()
    assert f.labels(site="a").value == 2
    assert f.labels(site="b").value == 1
    with pytest.raises(ValueError):
        f.labels(wrong="a")
    with pytest.raises(ValueError):
        f.inc()                           # labelled family has no solo child


# ---------------------------------------------------------------------------
# serve metrics: percentiles + summarize edge cases
# ---------------------------------------------------------------------------


def test_pct_linear_interpolation():
    xs = [0.0, 1.0, 2.0, 3.0]
    assert _pct(xs, 0.0) == 0.0
    assert _pct(xs, 1.0) == 3.0
    assert _pct(xs, 0.5) == pytest.approx(1.5)     # nearest-rank gave 1.0
    assert _pct(xs, 0.95) == pytest.approx(2.85)
    assert _pct([], 0.5) == 0.0
    assert _pct([7.0], 0.99) == 7.0


@settings(max_examples=24, deadline=None)
@given(st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_pct_monotone_in_q(n, seed):
    """Interpolated percentiles are monotone non-decreasing in q and stay
    inside [min, max] of the sample."""
    rng = np.random.default_rng(seed)
    xs = rng.uniform(-100, 100, size=n).tolist()
    qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0]
    vals = [_pct(xs, q) for q in qs]
    assert all(a <= b + 1e-9 for a, b in zip(vals, vals[1:])), (qs, vals)
    assert min(xs) - 1e-9 <= vals[0] and vals[-1] <= max(xs) + 1e-9


class _FakeReq:
    def __init__(self, t_enqueue=0.0, t_admitted=0.0, t_first_token=0.0,
                 t_done=0.0, out_tokens=(), prompt_len=3, degree=None):
        self.t_enqueue = t_enqueue
        self.t_admitted = t_admitted
        self.t_first_token = t_first_token
        self.t_done = t_done
        self.out_tokens = list(out_tokens)
        self.prompt = np.zeros(prompt_len, np.int32)
        self.degree_at_first_token = degree

    @property
    def queue_time(self):
        return self.t_admitted - self.t_enqueue

    @property
    def ttft(self):
        return self.t_first_token - self.t_enqueue

    @property
    def tpot(self):
        return (self.t_done - self.t_first_token) / max(len(self.out_tokens) - 1, 1)

    @property
    def e2e(self):
        return self.t_done - self.t_enqueue


def test_summarize_empty_done():
    s = summarize([])
    assert s["requests"] == 0
    assert s["generated_tokens"] == 0
    assert s["ttft_p50_ms"] == 0.0 and s["e2e_p95_ms"] == 0.0
    assert "degree_at_first_token" not in s
    assert "gen_tok_per_s" not in s


def test_summarize_zero_tokens_and_single_token():
    # EOS-before-first-token: no TTFT sample; single token: no TPOT sample
    r0 = _FakeReq(t_admitted=0.1, t_done=0.2, out_tokens=[])
    r1 = _FakeReq(t_admitted=0.1, t_first_token=0.3, t_done=0.3,
                  out_tokens=[5])
    s = summarize([r0, r1], wall_s=1.0)
    assert s["requests"] == 2
    assert s["generated_tokens"] == 1
    assert s["ttft_p50_ms"] == pytest.approx(300.0)  # only r1 contributes
    assert s["tpot_p50_ms"] == 0.0                   # no multi-token request
    assert s["e2e_p50_ms"] == pytest.approx(250.0)
    assert s["gen_tok_per_s"] == 1.0


def test_summarize_no_wall_clock_and_first_token_degrees():
    r0 = _FakeReq(t_first_token=0.1, t_done=0.5, out_tokens=[1, 2],
                  degree=(8,))
    r1 = _FakeReq(t_first_token=0.2, t_done=0.6, out_tokens=[3, 4],
                  degree=(8, 7, 6))
    s = summarize([r0, r1])
    assert "gen_tok_per_s" not in s
    assert s["degree_at_first_token"] == {"8": 1, "8.7.6": 1}
    assert s["ttft_p99_ms"] >= s["ttft_p95_ms"] >= s["ttft_p50_ms"]


def test_engine_stats_registry_view():
    st_ = EngineStats()
    st_.c_decode_steps.inc(3)
    st_.c_prefill_tokens.inc(7)
    assert st_.decode_steps == 3 and st_.prefill_tokens == 7
    rec = st_.record_degree(0, 6)
    assert rec == (6,)
    assert st_.degree_history[-1] == (0, (6,))
    d = parse_text(st_.registry.to_prometheus())
    assert d[("repro_decode_steps_total", ())] == 3
    assert d[("repro_degree_ebits", (("site", "global"),))] == 6


# ---------------------------------------------------------------------------
# engine trace validation (the §11 contract)
# ---------------------------------------------------------------------------


def _events(tracer, name):
    return [e for e in tracer.events if e["name"] == name]


def test_engine_trace_lifecycle_and_route_counters():
    m, params = _setup()
    tr = Tracer(enabled=True)
    reg = Registry()
    eng = ServeEngine(m, params, slots=2, max_len=64, registry=reg, tracer=tr)
    for _ in range(4):
        eng.submit(np.array([1, 2, 3]), max_new_tokens=4)
    done = eng.run_until_drained()
    assert len(done) == 4

    enq = _events(tr, "enqueue")
    pre = _events(tr, "prefill")
    ft = _events(tr, "first_token")
    fin = _events(tr, "request_done")
    rids = {r.rid for r in done}
    # every admitted request has enqueue -> prefill -> first_token ->
    # request_done, with matching rids across the event kinds
    assert {e["args"]["rid"] for e in enq} == rids
    assert {e["args"]["rid"] for e in pre} == rids
    assert {e["args"]["rid"] for e in ft} == rids
    assert {e["args"]["rid"] for e in fin} == rids
    # prefill spans carry the slot and token payload and measured time
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in pre)
    assert all(e["args"]["prompt_tokens"] == 3 for e in pre)
    # per-rid ordering: enqueue < prefill end < first_token
    t_enq = {e["args"]["rid"]: e["ts"] for e in enq}
    t_ft = {e["args"]["rid"]: e["ts"] for e in ft}
    for e in pre:
        rid = e["args"]["rid"]
        assert t_enq[rid] <= e["ts"] + e["dur"] <= t_ft[rid]
    # one decode_tick span per engine tick
    ticks = _events(tr, "decode_tick")
    assert len(ticks) == eng.stats.decode_steps

    # kernel-route counters: decode-site counts sum EXACTLY to decode steps
    fam = eng.stats.c_route_steps
    by_site: dict = {}
    for (site, backend), child in fam.children.items():
        by_site[site] = by_site.get(site, 0) + child.value
    assert by_site["decode"] == eng.stats.decode_steps
    assert by_site["prefill"] == eng.stats.prefill_calls
    # the route event names a real backend
    routes = _events(tr, "kernel_route")
    assert {e["args"]["backend"] for e in routes} <= {"pallas", "xla"}


def test_engine_qos_rung_events_carry_degrees():
    m, params = _setup()
    tr = Tracer(enabled=True)
    qos = QoSController(ladder=[{"ebits": 8}, {"ebits": 6}],
                        low_water=0.5, high_water=0.9, cooldown_steps=0)
    eng = ServeEngine(m, params, slots=2, max_len=64, qos=qos, tracer=tr)
    for _ in range(6):
        eng.submit(np.array([1, 2, 3]), 8)
    done = eng.run_until_drained()
    rungs = _events(tr, "qos_rung")
    assert rungs, "overload never moved the QoS rung"
    for e in rungs:
        assert isinstance(e["args"]["degrees"], list) and e["args"]["degrees"]
        assert 0.0 <= e["args"]["headroom"] <= 1.0
    # the ladder visited ebits 6 somewhere; history is tuple-normalized
    assert any(e["args"]["degrees"] == [6] for e in rungs)
    # each request records the degree serving its first token
    assert all(r.degree_at_first_token in {(8,), (6,)} for r in done)
    s = summarize(done, eng.stats)
    assert sum(s["degree_at_first_token"].values()) == len(done)


def test_engine_disabled_tracer_records_nothing():
    m, params = _setup()
    tr = Tracer(enabled=False)
    eng = ServeEngine(m, params, slots=2, max_len=64, tracer=tr)
    eng.submit(np.array([1, 2, 3]), 4)
    eng.run_until_drained()
    assert tr.events == []
    # counters still work without tracing
    assert eng.stats.decode_steps > 0


def test_quality_tap_records_per_rung():
    from repro.core.approx import policy_from_flag

    policy = policy_from_flag("axq8", dynamic=True)
    m, params = _setup(policy=policy)
    tr = Tracer(enabled=True)
    eng = ServeEngine(m, params, slots=2, max_len=64, degree=6,
                      quality_every=2, prepack=False, tracer=tr)
    eng.submit(np.array([1, 2, 3]), 8)
    eng.run_until_drained()
    assert eng._tap is not None and eng._tap.samples > 0
    hist = eng.stats.registry.get("repro_quality_logit_rms")
    child = hist.labels(rung="6")
    assert child.count == eng._tap.samples
    assert child.sum > 0                  # approx rung 6 deviates from exact
    probes = [e for e in tr.events if e["name"] == "quality_probe"]
    assert len(probes) == eng._tap.samples
    assert all(e["args"]["rung"] == "6" for e in probes)


def test_quality_tap_requires_traced_degree():
    m, params = _setup()
    with pytest.raises(ValueError):
        ServeEngine(m, params, slots=2, max_len=64, quality_every=4)


def test_rung_label():
    assert rung_label(np.int32(8)) == "8"
    assert rung_label(np.array([8, 7, 6])) == "8.7.6"


def test_trainer_spans_and_metrics(tmp_path):
    from repro.data.pipeline import make_pipeline
    from repro.train import step as step_mod
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("tinyllama-1.1b-smoke")
    model = build_model(cfg)
    tr = Tracer(enabled=True)
    reg = Registry()
    trainer = Trainer(
        model, step_mod.StepConfig(remat="none", total_steps=4, warmup=1),
        TrainerConfig(total_steps=4, ckpt_every=2, log_every=10,
                      ckpt_dir=str(tmp_path), async_ckpt=False),
        make_pipeline(cfg, seq_len=16, global_batch=2),
        registry=reg, tracer=tr)
    out = trainer.run()
    assert out["final_step"] == 4
    steps = [e for e in tr.events if e["name"] == "train_step"]
    assert len(steps) == 4
    assert all(e["ph"] == "X" for e in steps)
    ckpts = [e for e in tr.events if e["name"] == "checkpoint"]
    assert len(ckpts) >= 2
    d = parse_text(reg.to_prometheus())
    assert d[("repro_train_steps_total", ())] == 4
    assert d[("repro_train_checkpoints_total", ())] >= 2
    assert d[("repro_train_step_seconds_count", ())] == 4
    assert d[("repro_degree_ebits", (("site", "global"),))] == 8
