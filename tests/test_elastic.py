from repro.dist.elastic import plan_rescale


def test_full_pod():
    p = plan_rescale(256, target_global_batch=256, tp=16)
    assert p.mesh_shape == (16, 16) and p.grad_accum == 1
    assert p.effective_batch == 256


def test_lost_nodes_grow_accum():
    p = plan_rescale(128, target_global_batch=256, tp=16)
    assert p.model == 16 and p.data == 8
    assert p.per_step_batch * p.grad_accum >= 256


def test_multi_pod():
    p = plan_rescale(512, target_global_batch=256, tp=16, devices_per_pod=256)
    assert p.pods == 2 and p.mesh_axes == ("pod", "data", "model")


def test_tiny_survivor_degrades_tp():
    p = plan_rescale(8, target_global_batch=64, tp=16)
    assert p.model == 8 and p.n_devices == 8
