import pytest

from repro.dist.elastic import plan_rescale


def test_full_pod():
    p = plan_rescale(256, target_global_batch=256, tp=16)
    assert p.mesh_shape == (16, 16) and p.grad_accum == 1
    assert p.effective_batch == 256


def test_lost_nodes_grow_accum():
    p = plan_rescale(128, target_global_batch=256, tp=16)
    assert p.model == 16 and p.data == 8
    assert p.per_step_batch * p.grad_accum >= 256


def test_multi_pod():
    p = plan_rescale(512, target_global_batch=256, tp=16, devices_per_pod=256)
    assert p.pods == 2 and p.mesh_axes == ("pod", "data", "model")


def test_tiny_survivor_degrades_tp():
    p = plan_rescale(8, target_global_batch=64, tp=16)
    assert p.model == 8 and p.n_devices == 8


# -- ragged survivor counts (ISSUE 9): degrade, never crash ------------------


def test_ragged_seven_of_eight():
    # the motivating case: one device of eight dies under a tp=4 mesh —
    # this used to raise out of the recovery path
    p = plan_rescale(7, target_global_batch=64, tp=4)
    assert p.model == 4 and p.data == 1 and p.idle_devices == 3
    assert p.data * p.model * p.pods + p.idle_devices == 7
    # tp=1 has no raggedness: seven one-device replicas all serve
    p1 = plan_rescale(7, target_global_batch=64, tp=1)
    assert p1.data == 7 and p1.idle_devices == 0


def test_ragged_keeps_requested_tp():
    # tp must survive raggedness: every replica group still needs exactly
    # tp devices, so the data axis absorbs the degradation
    p = plan_rescale(7, target_global_batch=64, tp=2)
    assert p.model == 2 and p.data == 2 and p.idle_devices == 3


@pytest.mark.parametrize("devices", list(range(1, 33)))
@pytest.mark.parametrize("tp", [1, 2, 4, 8])
def test_every_survivor_count_plans(devices, tp):
    # the recovery path never raises and accounts for every device; exact
    # factorizations use all survivors, ragged ones degrade to a
    # power-of-two data axis and park the surplus
    p = plan_rescale(devices, target_global_batch=64, tp=tp)
    assert p.pods * p.data * p.model + p.idle_devices == devices
    if p.idle_devices:
        assert p.data & (p.data - 1) == 0
    assert p.idle_devices >= 0
    assert p.effective_batch >= 64


def test_exact_counts_have_no_idle():
    for devices, tp in [(8, 2), (16, 4), (4, 1), (256, 16)]:
        assert plan_rescale(devices, target_global_batch=64,
                            tp=tp).idle_devices == 0
