"""Trainer loop: checkpoint/restart, preemption handling, straggler watchdog,
and runtime approximation (QoS) control — the fault-tolerance layer the
multi-pod deployment contract requires (DESIGN.md §3).

Single-process here; the multi-host contract is documented per hook:
  * checkpoint saves are mesh-agnostic -> elastic restart (dist/elastic.py);
  * SIGTERM/SIGINT -> synchronous checkpoint then clean exit (preemption);
  * the step-time watchdog flags stragglers (per-host EMA vs median across
    hosts arrives via the launcher's heartbeat file in multi-host runs);
  * the QoS controller moves the DyFXU degree (a traced scalar, or a traced
    per-layer vector when the ladder holds ApproxPlan rungs — no recompile
    either way) to hold quality within budget while harvesting
    approximation gains.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.dynamic import QoSController, degree_operand, degree_record
from repro.data.pipeline import SyntheticPipeline
from repro.models.registry import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.train import step as step_mod


@dataclass
class StragglerWatchdog:
    """Flags steps slower than k x the trailing median (on a real cluster the
    launcher compares per-host EMAs; here we monitor the local step time and
    expose the same interface)."""

    factor: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        slow = len(self.times) >= 10 and dt > self.factor * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    async_ckpt: bool = True
    # QoS-driven dynamic approximation (None = static degree)
    qos: Optional[QoSController] = None
    qos_every: int = 20
    # static degree used when qos is None: an ApproxPlan rung's per-site
    # degree list, or None for the global default (ebits 8) — lets
    # `launch.train --plan` (no --qos) train a fixed tuned configuration,
    # mirroring the serve engine's plan-without-controller behavior
    static_degrees: Optional[list] = None


class Trainer:
    """``registry`` / ``tracer`` (DESIGN.md §11): step/checkpoint spans and
    QoS ladder events go to the process-global tracer by default (free when
    disabled); counters/gauges land in a fresh per-trainer registry unless
    a shared one is passed (``launch.train --metrics-out`` exports it)."""

    def __init__(self, model: Model, scfg: step_mod.StepConfig,
                 tcfg: TrainerConfig, pipeline: SyntheticPipeline,
                 tp: int = 1, registry=None, tracer=None):
        self.model = model
        self.scfg = scfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.tp = tp
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.watchdog = StragglerWatchdog()
        self._preempted = False
        self._step_fn = jax.jit(
            lambda state, batch, degree: step_mod.train_step(
                model, scfg, state, batch, tp=tp, degree=degree),
            donate_argnums=(0,))
        self.history: list[dict] = []
        self.registry = (registry if registry is not None
                         else obs_metrics.Registry())
        self._tracer = tracer if tracer is not None else obs_trace.get_tracer()
        r = self.registry
        self._c_steps = r.counter("repro_train_steps_total",
                                  "optimizer steps executed")
        self._c_ckpts = r.counter("repro_train_checkpoints_total",
                                  "checkpoints written")
        self._c_stragglers = r.counter("repro_train_straggler_steps_total",
                                       "steps flagged by the watchdog")
        self._g_loss = r.gauge("repro_train_loss", "last step's loss")
        self._g_degree = r.gauge(
            "repro_degree_ebits", "live approximation degree by plan site",
            labels=("site",))
        self._h_step = r.histogram("repro_train_step_seconds",
                                   "wall time per optimizer step")

    # ------------------------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _record_degree(self, degree) -> tuple:
        """Refresh the ``repro_degree_ebits{site=..}`` gauge family from the
        current degree operand (scalar -> ``site="global"``)."""
        from repro.tune.plan import site_names

        rec = degree_record(degree, as_tuple=True)
        names = site_names(self.model.cfg)
        if len(rec) == len(names):
            for name, e in zip(names, rec):
                self._g_degree.labels(site=name).set(e)
        else:
            self._g_degree.labels(site="global").set(rec[0])
        return rec

    def init_or_restore(self, key) -> tuple[step_mod.TrainState, int]:
        state = step_mod.init_state(self.model, key, tp=self.tp)
        got = None
        try:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            got = self.ckpt.restore_latest(like)
        except Exception:
            got = None
        if got is None:
            return state, 0
        step, tree, extra = got
        tree = jax.tree.map(jnp.asarray, tree)
        print(f"[trainer] restored checkpoint at step {step}")
        return step_mod.TrainState(*tree), step

    def run(self, key=None) -> dict:
        self._install_signal_handlers()
        key = key if key is not None else jax.random.PRNGKey(0)
        state, start = self.init_or_restore(key)
        if self.tcfg.qos:
            degree_kwargs = self.tcfg.qos.ladder[self.tcfg.qos.degree]
        elif self.tcfg.static_degrees is not None:
            degree_kwargs = {"degrees": self.tcfg.static_degrees}
        else:
            degree_kwargs = {"ebits": 8}
        degree = degree_operand(degree_kwargs)
        self._record_degree(degree)
        t_last_loss = None
        step = start
        while step < self.tcfg.total_steps:
            with self._tracer.span("data_batch", track="train", step=step):
                batch = {k: jnp.asarray(v)
                         for k, v in self.pipeline.batch_at(step).items()}
            t0 = time.time()
            with self._tracer.span("train_step", track="train", step=step):
                state, metrics = self._step_fn(state, batch, degree)
                loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = self.watchdog.observe(step, dt)
            self._c_steps.inc()
            self._g_loss.set(loss)
            self._h_step.observe(dt)
            if slow:
                self._c_stragglers.inc()
                self._tracer.event("straggler", track="train", step=step,
                                   dt_s=round(dt, 4))
            rec = {"step": step, "loss": loss, "time_s": dt,
                   "grad_norm": float(metrics["grad_norm"]),
                   "degree": degree_record(degree), "straggler": slow}
            self.history.append(rec)
            if step % self.tcfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms){' STRAGGLER' if slow else ''}")
            # QoS: quality signal = loss improvement rate (negative delta)
            if self.tcfg.qos and step % self.tcfg.qos_every == 0 and step > start:
                signal_q = (t_last_loss - loss) if t_last_loss is not None else 0.0
                kw = self.tcfg.qos.update(step, signal_q)
                old = degree_record(degree, as_tuple=True)
                degree = degree_operand(kw)
                new = self._record_degree(degree)
                if new != old:
                    # ladder move: the event carries the full degree vector,
                    # mirroring the serve engine's qos_rung transitions
                    self._tracer.event("qos_rung", track="train", step=step,
                                       rung=self.tcfg.qos.degree,
                                       degrees=list(new))
                t_last_loss = loss
            elif t_last_loss is None:
                t_last_loss = loss
            step += 1
            if step % self.tcfg.ckpt_every == 0 or self._preempted:
                with self._tracer.span("checkpoint", track="train", step=step):
                    self.ckpt.save(
                        step, state,
                        extra={"data_step": step,
                               "degree": degree_record(degree)},
                        blocking=self._preempted or not self.tcfg.async_ckpt)
                self._c_ckpts.inc()
                if self._preempted:
                    print(f"[trainer] preempted: checkpointed at {step}, exiting")
                    break
        self.ckpt.wait()
        if not self._preempted and (step % self.tcfg.ckpt_every):
            self.ckpt.save(step, state,
                           extra={"data_step": step,
                                  "degree": degree_record(degree)},
                           blocking=True)
            self._c_ckpts.inc()
        return {"final_step": step, "history": self.history,
                "preempted": self._preempted,
                "stragglers": self.watchdog.flagged}
