"""pjit-able train / eval / serve step functions.

``train_step`` is the unit the multi-pod dry-run lowers: forward + backward
(remat policy configurable) + gradient clipping + AdamW update, with optional
microbatch gradient accumulation and compressed gradient all-reduce.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim import adamw

Array = jnp.ndarray


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: Array  # () int32 — global step (mirrors opt.step; kept for restore)


@dataclass(frozen=True)
class StepConfig:
    optimizer: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    remat: str = "dots"          # none | dots | full
    grad_accum: int = 1          # microbatches per step
    warmup: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False  # int8+error-feedback all-reduce (beyond-paper)


def init_state(model: Model, key, tp: int = 1) -> TrainState:
    params = model.init(key, tp)
    return TrainState(params, adamw.init(params), jnp.zeros((), jnp.int32))


def _split_microbatches(batch: dict, n: int) -> dict:
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                        batch)


def train_step(model: Model, cfg: StepConfig, state: TrainState, batch: dict,
               tp: int = 1, degree: Optional[Array] = None):
    """Returns (new_state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, tp=tp, degree=degree,
                                   remat=cfg.remat)
        return loss, metrics

    if cfg.grad_accum > 1:
        mbs = _split_microbatches(batch, cfg.grad_accum)

        def acc_body(carry, mb):
            gsum, lsum = carry
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, mb)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (grads, loss_sum), metrics = jax.lax.scan(
            acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / cfg.grad_accum, grads)
        loss = loss_sum / cfg.grad_accum
        metrics = jax.tree.map(lambda m: m[-1], metrics)
    else:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)

    if cfg.compress_grads:
        from repro.dist.collectives import compress_tree_for_allreduce

        grads = compress_tree_for_allreduce(grads)

    lr_scale = adamw.cosine_warmup(state.step, warmup=cfg.warmup,
                                   total=cfg.total_steps)
    new_params, new_opt, opt_metrics = adamw.update(
        cfg.optimizer, state.opt, state.params, grads, lr_scale)
    metrics = {**metrics, **opt_metrics, "loss": loss,
               "lr_scale": lr_scale}
    return TrainState(new_params, new_opt, state.step + 1), metrics


def eval_step(model: Model, state: TrainState, batch: dict, tp: int = 1,
              degree: Optional[Array] = None):
    loss, metrics = model.loss(state.params, batch, tp=tp, degree=degree,
                               remat="none")
    return {**metrics, "loss": loss}


def serve_step(model: Model, params, cache, tokens: Array, tp: int = 1,
               degree: Optional[Array] = None):
    """One-token decode (the unit lowered for decode_* dry-run cells)."""
    return model.decode_step(params, cache, tokens, tp=tp, degree=degree)
