"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4,
4 shared experts (assignment spec)."""
from .base import ArchConfig, MoECfg, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    moe=MoECfg(n_experts=60, top_k=4, d_expert=1408, n_shared=4, d_shared=1408),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
