"""Architecture & shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape a
``ShapeCfg``.  ``padded(tp)`` derives the mesh-divisible physical dimensions
(heads / kv / experts / vocab padded to the tensor-parallel degree) while the
logical dimensions stay authoritative for parameter export & FLOP accounting.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    d_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class PaddedDims:
    n_heads: int
    n_kv_rep: int      # kv heads after repeat-to-TP (cache/attention layout)
    q_group: int       # padded q heads per kv_rep head
    vocab: int
    n_experts: int
    d_ff: int


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    swa_window: Optional[int] = None        # sliding-window attention (danube)
    local_window: Optional[int] = None      # local attention (recurrentgemma)
    block_pattern: Optional[tuple[str, ...]] = None  # hybrid stacking unit
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    frontend: Optional[str] = None          # "vision" | "audio" (stub)
    frontend_dim: int = 0
    frontend_tokens: int = 0                # img patches / audio frames in seq
    norm_eps: float = 1e-6
    causal: bool = True
    tie_embeddings: bool = False
    act: str = "silu"
    dtype: str = "bfloat16"
    source: str = ""

    # ---- derived -----------------------------------------------------------

    def padded(self, tp: int) -> PaddedDims:
        """Physical dims for a given tensor-parallel degree (DESIGN.md §3)."""
        n_heads = pad_to(self.n_heads, tp) if self.n_heads else 0
        if self.n_kv_heads:
            kv_rep = tp if self.n_kv_heads <= tp else pad_to(self.n_kv_heads, tp)
            kv_rep = min(kv_rep, n_heads) if n_heads else kv_rep
            kv_rep = max(kv_rep, 1)
            # q_group must be a positive integer
            while n_heads % kv_rep:
                kv_rep //= 2
            q_group = n_heads // kv_rep
        else:
            kv_rep, q_group = 0, 0
        n_exp = pad_to(self.moe.n_experts, tp) if self.moe else 0
        return PaddedDims(
            n_heads=n_heads,
            n_kv_rep=kv_rep,
            q_group=q_group,
            vocab=pad_to(self.vocab, tp),
            n_experts=n_exp,
            d_ff=pad_to(self.d_ff, tp) if self.d_ff else 0,
        )

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k (decode w/ bounded state)?"""
        return (
            self.family in ("ssm", "hybrid")
            or (self.swa_window is not None)
        )

    def valid_shapes(self) -> dict[str, ShapeCfg | None]:
        """shape name -> ShapeCfg if runnable else None (skip + reason table
        is produced by launch.dryrun)."""
        out: dict[str, ShapeCfg | None] = {}
        for name, s in SHAPES.items():
            if s.kind == "decode" and self.encoder_only:
                out[name] = None
            elif name == "long_500k" and not self.sub_quadratic:
                out[name] = None
            else:
                out[name] = s
        return out

    def skip_reason(self, shape_name: str) -> str | None:
        if self.valid_shapes()[shape_name] is not None:
            return None
        if self.encoder_only:
            return "encoder-only arch has no decode step"
        return "pure full-attention arch: no sub-quadratic path for 500k decode"

    # ---- parameter count (logical, for MODEL_FLOPS) ------------------------

    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params) — active differs for MoE."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = 0
        if self.n_heads:
            qd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            per_layer_attn = d * qd + 2 * d * kvd + qd * d

        def ffn_dense(dff):
            return 3 * d * dff  # gated (up, gate, down)

        total = emb
        active = emb
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per = (
                d * (2 * d_in + 2 * s.d_state + d_in // s.headdim)  # in_proj
                + d_in * d                                          # out_proj
                + s.conv_width * (d_in + 2 * s.d_state)
            )
            total += L * per
            active = total
            return total, active

        if self.family == "hybrid":
            # recurrent blocks: wx, wg, wa, wi, wo (5 d^2) + conv + gates;
            # attn blocks: standard attention.  Both carry the gated MLP.
            pat = self.block_pattern or ("attn",)
            n_attn = sum(1 for i in range(L) if pat[i % len(pat)] == "attn")
            n_rec = L - n_attn
            rec_per = 5 * d * d + 5 * d  # projections + conv(4d) + lambda
            total += n_attn * (per_layer_attn + ffn_dense(self.d_ff) + 2 * d)
            total += n_rec * (rec_per + ffn_dense(self.d_ff) + 2 * d)
            return total, total

        per_layer = per_layer_attn + 2 * d  # + norms
        if self.moe:
            m = self.moe
            router = d * m.n_experts
            experts = m.n_experts * 3 * d * m.d_expert
            shared = m.n_shared * 3 * d * m.d_shared
            total += L * (per_layer + router + experts + shared)
            active += L * (
                per_layer + router + m.top_k * 3 * d * m.d_expert + shared
            )
        else:
            total += L * (per_layer + ffn_dense(self.d_ff))
            active = total
        return total, active


# ---------------------------------------------------------------------------
# Reduced smoke variants (per-arch family, tiny dims, CPU-runnable)
# ---------------------------------------------------------------------------


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Same family/topology, tiny dims — used by per-arch smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.block_pattern) if cfg.block_pattern else 2,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        frontend_dim=32 if cfg.frontend else 0,
        frontend_tokens=8 if cfg.frontend else 0,
    )
    if cfg.moe:
        kw["moe"] = MoECfg(
            n_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            n_shared=min(cfg.moe.n_shared, 1),
            d_shared=64 if cfg.moe.n_shared else 0,
        )
    if cfg.ssm:
        kw["ssm"] = SSMCfg(d_state=16, headdim=16, expand=2, chunk=16, conv_width=4)
    if cfg.swa_window:
        kw["swa_window"] = 32
    if cfg.local_window:
        kw["local_window"] = 32
    return replace(cfg, **kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import the per-arch modules lazily so `register` runs
    from . import all_archs  # noqa: F401

    if name.endswith("-smoke"):
        return smoke_variant(get_config(name[: -len("-smoke")]))
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from . import all_archs  # noqa: F401

    return sorted(_REGISTRY)
