"""InternVL2-1B  [arXiv:2404.16821; hf]
LM backbone (Qwen2-0.5B): 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  Vision frontend (InternViT) is a STUB per the assignment:
input_specs() provides precomputed patch embeddings (frontend_dim=1024),
projected by a 2-layer MLP and prepended to the text sequence."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=1024,
    source="arXiv:2404.16821",
))
