"""Mamba2-370M (SSD)  [arXiv:2405.21060; unverified]
48L d_model=1024 attn-free, vocab=50280, ssm_state=128, headdim 64,
expand 2 (d_inner 2048, 32 ssd heads), chunked state-space-duality form.
SSM => long_500k RUNS (O(1) recurrent state)."""
from .base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMCfg(d_state=128, headdim=64, expand=2, chunk=256, conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.21060",
))
