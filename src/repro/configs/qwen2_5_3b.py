"""Qwen2.5-3B  [hf:Qwen/Qwen2.5-0.5B family; hf]
36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab=151936,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    source="hf:Qwen/Qwen2.5-3B",
))
