"""Import side-effect module: registers every assigned architecture."""
from . import (  # noqa: F401
    granite_moe_3b_a800m,
    h2o_danube_1_8b,
    hubert_xlarge,
    internvl2_1b,
    mamba2_370m,
    mistral_nemo_12b,
    qwen2_5_3b,
    qwen2_moe_a2_7b,
    recurrentgemma_2b,
    tinyllama_1_1b,
)
