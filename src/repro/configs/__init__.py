from .base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    MoECfg,
    ShapeCfg,
    SSMCfg,
    get_config,
    list_configs,
    smoke_variant,
)
