"""HuBERT X-Large  [arXiv:2106.07447; unverified]
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means units),
encoder-only (bidirectional); audio conv frontend is a STUB: input_specs()
provides precomputed 512-d frame features.  No decode shapes (encoder)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio",
    frontend_dim=512,
    act="gelu",
    source="arXiv:2106.07447",
))
