"""RecurrentGemma-2B (Griffin)  [arXiv:2402.19427; hf]
26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
RG-LRU + local attention, pattern (rec, rec, attn), window 2048.
Hybrid => long_500k RUNS (O(1) recurrent state + bounded local window)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    rope_theta=10_000.0,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    act="gelu",
    source="arXiv:2402.19427",
))
