"""H2O-Danube-1.8B  [arXiv:2401.16818; hf]
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, llama+mistral mix,
sliding-window attention (window 4096) => sub-quadratic decode; long_500k RUNS."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    rope_theta=10_000.0,
    swa_window=4096,
    source="arXiv:2401.16818",
))
