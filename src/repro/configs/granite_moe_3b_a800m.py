"""IBM Granite 3.0 MoE  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8."""
from .base import ArchConfig, MoECfg, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    rope_theta=10_000.0,
    moe=MoECfg(n_experts=40, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
