"""Admission pipeline configuration (DESIGN.md §15).

The production admission shape (MaxText ``offline_inference.py``): prompts
are prefills at one of a fixed ladder of power-of-two *bucket* lengths, so
the set of prefill executables is closed and can be traced ahead of time by
a warmup pass — no request ever triggers a compile after startup.  Short
prompts *pack* — up to ``pack`` rows ride one bucketed prefill call, each
row scattering into its own slot (dummy rows use an out-of-bounds slot and
are dropped by JAX scatter semantics).  Long prompts *chunk* — split into
``chunk_tokens``-sized pieces admitted across ticks, interleaved with
decode, so a long arrival cannot stall short-request TTFT.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


def bucket_ladder(max_len: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Power-of-two prefill lengths from ``min_bucket`` up to the smallest
    power of two covering ``max_len - 1`` (the prefix of a full-length
    prompt; the final token rides the decode feed).  The last rung is
    capped at ``max_len`` so a non-power-of-two cache capacity never gets
    a bucket its dense cache cannot hold."""
    if max_len < 2:
        return (min(min_bucket, max(max_len, 1)),)
    buckets = []
    b = min_bucket
    while b < max_len - 1:
        buckets.append(b)
        b *= 2
    buckets.append(min(b, max_len))
    return tuple(buckets)


def bucket_for(n: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= n; raises when the ladder cannot hold n."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt prefix ({n}) exceeds largest bucket "
                     f"({buckets[-1]})")


@dataclass(frozen=True)
class AdmissionConfig:
    """Knobs for the bucketed/packed/chunked admission pipeline.

    buckets: ascending prefill lengths; () derives a power-of-two ladder
        from the engine's ``max_len`` at construction.
    pack: rows per bucketed prefill call (1 = no packing).  Calls are always
        padded to exactly ``pack`` rows so each bucket has ONE executable.
    chunk_tokens: split prompts longer than this into chunks admitted across
        ticks (0 = disabled).  Only dense full-attention transformer caches
        chunk; other families fall back to whole-prompt bucketed prefill.
    chunk_calls_per_tick: admission-vs-decode interleave ratio — chunk calls
        issued per engine tick for a mid-admission slot.
    warmup: trace every bucket/chunk/step executable at construction.
    """

    buckets: Tuple[int, ...] = ()
    pack: int = 1
    chunk_tokens: int = 0
    chunk_calls_per_tick: int = 1
    warmup: bool = True

    def __post_init__(self):
        if self.pack < 1:
            raise ValueError("pack must be >= 1")
        if self.chunk_tokens < 0:
            raise ValueError("chunk_tokens must be >= 0")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("buckets must be strictly ascending")

    def resolved(self, max_len: int) -> "AdmissionConfig":
        """Fill the default bucket ladder from the engine's max_len."""
        if self.buckets:
            return self
        return AdmissionConfig(buckets=bucket_ladder(max_len),
                               pack=self.pack,
                               chunk_tokens=self.chunk_tokens,
                               chunk_calls_per_tick=self.chunk_calls_per_tick,
                               warmup=self.warmup)
