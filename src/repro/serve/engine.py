"""Workload-generic continuous-batching serve core: slot lifecycle + QoS.

Requests enter a FIFO queue; free slots are (re)filled on admission by the
workload's fused ingest call, which rewinds the slot's state region and
writes the payload prefix into it; every engine tick runs ONE fused,
jit-compiled step for all slots.  Free slots are masked out of the step —
their state never advances — so a freed slot can be handed to the next
request with no stale-state pollution: admission into a reused slot is
bit-identical to a solo run on a fresh engine.

The engine is generic over a :class:`~repro.serve.servable.ServableModel`
(DESIGN.md §12): everything workload-specific — what a unit of work is, how
a payload is ingested, what the fused step computes, when a request
finishes, even the vocabulary the trace events speak — lives behind that
protocol.  ``serve/lm.py`` adapts the language models (the historical
``ServeEngine`` surface, re-exported below unchanged); ``serve/stream.py``
serves the Ch. 7 approximate DSP/vision pipeline frame-by-frame through the
same slot lifecycle.

The fused step is a single compiled executable across the whole engine
lifetime: workload sampling/config is baked at construction, while the PRNG
key and the DyFXU approximation ``degree`` (Ch. 5 §5.2.3) are traced
operands — a global scalar or, under an
:class:`~repro.tune.plan.ApproxPlan`, a per-site degree *vector*
(models/degrees.py).  An optional :class:`~repro.core.dynamic.QoSController`
moves the degree with serving load — the dissertation's
runtime-configuration contract at system level: heavy load -> cheaper
arithmetic, idle -> exact.  With a plan the controller steps along the
plan's calibrated ladder (whole mixed per-site configurations, Pareto
points from ``repro.tune``) instead of rescaling one global knob; either
way the compiled executable never changes.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic import QoSController, degree_operand
from repro.kernels import dispatch as kdispatch
from repro.obs import trace as obs_trace
from repro.serve.metrics import EngineStats
from repro.serve.servable import ServableModel

_DEFAULT_EBITS = 8


@dataclass
class Request:
    """One unit of serving work, workload-agnostic.  ``payload`` is what the
    workload ingests (LM prompt ids, stream frames), ``out`` what its steps
    emit; the LM adapter subclasses this with the historical field names as
    read-only views (``serve/lm.py``)."""

    rid: int
    payload: object
    budget: int = 32              # emission budget (units)
    payload_units: int = 0        # payload size in workload units
    out: list = field(default_factory=list)
    done: bool = False
    admitted_units: int = 0       # units ingested by the fused admit call
    cursor: int = 0               # workload read head into the payload
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_emit: float = 0.0
    t_done: float = 0.0
    # degree tuple that served the first emission (None until then, or
    # engine running without a traced degree): makes mid-run QoS rung moves
    # visible per request, not just the engine-final degree
    degree_at_first_emit: Optional[tuple] = None

    # -- latency breakdown (valid once done) --
    @property
    def queue_time(self) -> float:
        return self.t_admitted - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_emit - self.t_enqueue

    @property
    def tpot(self) -> float:
        return (self.t_done - self.t_first_emit) / max(len(self.out) - 1, 1)

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_enqueue


class ServeCore:
    """Continuous-batching engine over a fixed batch of ``slots``, generic
    over a :class:`~repro.serve.servable.ServableModel` workload.

    Construction compiles the workload's fused step once; afterwards
    ``submit`` enqueues requests and ``tick`` / ``run_until_drained``
    advance the batch.  ``qos`` drives the runtime approximation degree
    from load; ``plan`` replaces the controller's global-ebits ladder with
    the plan's calibrated per-site ladder (and supplies the initial degree
    vector), so QoS moves between whole tuned configurations.  ``degree``
    pins a static initial degree (scalar or per-site vector) without a
    controller.  ``prepack`` applies the workload's quantize-once weight
    residency at construction (DESIGN.md §9).

    Observability (DESIGN.md §11): every lifecycle edge — enqueue,
    admission/ingest, per-tick step, first emission, completion, QoS rung
    transitions (with the per-site degree vector attached) — is traced
    through ``tracer`` (the process-global :mod:`repro.obs.trace` tracer
    by default; free when disabled) under the *workload's* vocabulary, and
    every counter lives in ``stats.registry`` (a fresh
    :class:`repro.obs.metrics.Registry`, or pass ``registry=`` to co-export
    with the dispatch counters).  ``quality_every=N`` samples the
    live-vs-exact output error every N ticks into a per-rung histogram
    (``obs/quality.py``) through the workload's quality tap.
    """

    def __init__(self, workload: ServableModel, params, *, slots: int = 8,
                 max_len: int = 512, seed: int = 0,
                 qos: Optional[QoSController] = None,
                 degree=None, prepack: bool = True, plan=None,
                 registry=None, tracer=None, quality_every: int = 0):
        self.workload = workload
        self.params = workload.prepack(params) if prepack else params
        self.slots = slots
        self.max_len = max_len
        self.qos = qos
        self.state = workload.init_state(batch=slots, max_len=max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self.stats = EngineStats(registry, unit=workload.unit,
                                 admit_name=workload.admit_span,
                                 step_name=workload.step_span)
        self._tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self._feed = workload.init_feed(slots)
        self._rid = itertools.count()
        self._ticks = 0
        self._key = jax.random.PRNGKey(seed)
        # approximation plan: validate against the arch, and point the QoS
        # controller's ladder at the plan's calibrated per-site rungs
        cfg = workload.cfg
        self.plan = plan
        if plan is not None:
            plan.validate_for(cfg)
            if qos is not None:
                qos.ladder = plan.qos_ladder()
                qos.degree = min(qos.degree, len(qos.ladder) - 1)
        # degree is traced only when someone will drive it; None keeps the
        # static policy spec (and a leaner step signature).  With a plan (or
        # any ladder of per-site rungs) the traced operand is the degree
        # vector (models/degrees.py) — its shape is fixed by the arch, so
        # ladder moves never retrace.  The initial degree comes from the
        # controller's current rung so the first QoS update cannot change
        # the operand's shape (scalar -> vector would recompile).
        self._use_degree = (qos is not None or degree is not None
                            or plan is not None)
        if degree is not None:
            self._degree = jnp.asarray(degree, jnp.int32)
        elif qos is not None and qos.ladder:
            self._degree = degree_operand(qos.ladder[qos.degree])
        elif plan is not None:
            self._degree = jnp.asarray(plan.degrees(0), jnp.int32)
        else:
            self._degree = (jnp.asarray(_DEFAULT_EBITS, jnp.int32)
                            if self._use_degree else None)
        # plan site names label the repro_degree_ebits{site=..} gauge family
        # (and trace events); scalar degrees export as site="global"
        from repro.tune.plan import site_names as _site_names

        self._site_names = _site_names(cfg)
        self._degree_rec: Optional[tuple] = None
        if self._degree is not None:
            # the construction-time degree is served until the first QoS
            # update: record it so the history covers every degree used
            self._degree_rec = self.stats.record_degree(
                -1, self._degree, self._site_names)
        # per-rung online quality telemetry (obs/quality.py): compare the
        # live degree's outputs against the exact rung every N ticks
        self._tap = None
        if quality_every > 0:
            if self._degree is None:
                raise ValueError(
                    "quality_every needs a traced degree (pass degree=, "
                    "qos=, or plan=)")
            self._tap = workload.quality_tap(every=quality_every,
                                             registry=self.stats.registry,
                                             tracer=self._tracer)
        # resolved kernel backend for the per-tick route counters: captured
        # from dispatch.last_route after the first traced step/ingest
        self._route: dict = {}
        self._step = jax.jit(workload.step)

    # ------------------------------------------------------------------

    def submit(self, payload, budget: Optional[int] = None) -> Request:
        """Enqueue one request (FIFO).  Returns the live Request object —
        emissions appear in ``request.out`` as ticks produce them, and
        latency fields populate when it finishes.  The workload validates
        the payload here (raising at submit time — rejecting mid-tick
        would lose the request)."""
        wl = self.workload
        payload = wl.validate(payload)
        if budget is None:
            budget = wl.default_budget(payload)
        req = (wl.request_cls or Request)(
            rid=next(self._rid), payload=payload, budget=int(budget),
            payload_units=wl.payload_units(payload), t_enqueue=time.time())
        self.queue.append(req)
        self._tracer.event(
            "enqueue", track="engine", rid=req.rid,
            queue_depth=len(self.queue),
            **{wl.payload_arg: req.payload_units, wl.budget_arg: int(budget)})
        return req

    def _admit(self, slot: int, req: Request):
        """Reset the slot's state region and ingest the payload via the
        workload's fused admit; the first step input lands in the feed."""
        req.t_admitted = time.time()
        wl = self.workload
        with self._tracer.span(wl.admit_span, track="engine", rid=req.rid,
                               slot=slot,
                               **{wl.payload_arg: req.payload_units}):
            self.state, ingested = wl.admit(self.params, self.state,
                                            self._feed, slot, req,
                                            self._degree)
        req.admitted_units = int(ingested)
        if req.admitted_units > 0:
            self.stats.c_admit_units.inc(req.admitted_units)
            self.stats.c_admit_calls.inc()
            if wl.admit_site:
                self._count_route(wl.admit_site)
        self.slot_req[slot] = req
        self.slot_budget[slot] = req.budget
        self.stats.c_admitted.inc()

    def _update_degree(self, n_active: int):
        """Feed the QoS controller a load-headroom signal: overload drives
        the approximation degree down the ladder (cheaper arithmetic), idle
        capacity drives it back to exact — at fixed compiled executable.
        Plan ladders step whole per-site degree vectors; the legacy global
        ladder steps one ebits scalar."""
        occupancy = (n_active + len(self.queue)) / self.slots
        headroom = max(0.0, 1.0 - occupancy)
        kw = self.qos.update(self._ticks, headroom)
        self._degree = degree_operand(kw)
        rec = self.stats.record_degree(self._ticks, self._degree,
                                       self._site_names)
        if rec != self._degree_rec:
            # QoS rung transition: the event carries the full per-site
            # degree vector so the trace shows WHICH arithmetic served
            # every span that follows
            self._tracer.event("qos_rung", track="engine", tick=self._ticks,
                               rung=self.qos.degree, degrees=list(rec),
                               headroom=round(headroom, 4))
            self._degree_rec = rec

    def _count_route(self, site: str) -> None:
        """Per-call kernel-route counter: the backend is read from
        ``dispatch.last_route`` (written at trace time of this engine's
        jitted step/admit) and cached — so the counters reflect what
        actually compiled, and `sum(route counters) == call count`."""
        backend = self._route.get(site)
        if backend is None:
            backend = kdispatch.last_route.get(site,
                                               kdispatch.resolved_backend())
            self._route[site] = backend
            self._tracer.event("kernel_route", track="engine", site=site,
                               backend=backend)
        self.stats.c_route_steps.labels(site=site, backend=backend).inc()

    def tick(self) -> int:
        """One engine iteration: admit queued requests into free slots
        (fused ingest per admission), update the QoS degree, run ONE fused
        step over all slots, and harvest emissions / finished requests.
        Returns the number of active slots (0 = idle)."""
        wl = self.workload
        # FIFO admission into free slots
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                self._admit(s, self.queue.popleft())
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        if self.qos is not None:
            self._update_degree(len(active))
        mask = np.zeros(self.slots, bool)
        mask[active] = True
        if self._tap is not None and self._tap.due(self._ticks):
            # probe BEFORE the step: same inputs the fused step is about to
            # consume, state untouched (the tap discards its state updates)
            self._tap.sample(self._ticks, self.params, self.state,
                             self._feed, mask, self._degree)
        self._key, sub = jax.random.split(self._key)
        with self._tracer.span(f"{wl.step_span}_tick", track="engine",
                               tick=self._ticks, active=len(active),
                               queued=len(self.queue)):
            nxt, self.state = self._step(self.params, self.state,
                                         jnp.asarray(self._feed),
                                         jnp.asarray(mask), sub,
                                         self._degree)
            nxt = np.asarray(nxt)
        self._ticks += 1
        self.stats.c_steps.inc()
        self.stats.c_step_units.inc(len(active))
        for site in wl.step_sites:
            self._count_route(site)
        self._tracer.counter("slots", track="engine", active=len(active),
                             queued=len(self.queue))
        now = time.time()
        for s in active:
            req = self.slot_req[s]
            emitted, finished, info = wl.harvest(req, self._feed, s, nxt[s])
            if emitted:
                # a suppressed emission (e.g. an LM stop id) is neither
                # banked nor charged against the budget; a request that
                # finishes before emitting anything keeps t_first_emit == 0
                # (excluded from TTFT stats)
                if req.t_first_emit == 0.0:
                    req.t_first_emit = now
                    req.degree_at_first_emit = self._degree_rec
                    self._tracer.event(wl.first_event, track="engine",
                                       rid=req.rid, slot=s,
                                       ttft_ms=round(req.ttft * 1e3, 3))
                self.slot_budget[s] -= 1
            if finished or self.slot_budget[s] <= 0:
                req.done = True
                req.t_done = now
                self.done.append(req)
                self.slot_req[s] = None
                self.stats.record_completion(req)
                self._tracer.event("request_done", track="engine",
                                   rid=req.rid, slot=s,
                                   e2e_ms=round(req.e2e * 1e3, 3),
                                   **wl.done_args(req, info))
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until the queue and every slot are empty (or ``max_ticks``);
        returns all finished requests, completion order."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.done


# The historical LM engine surface lives in serve/lm.py on top of ServeCore;
# re-exported here so every existing import path keeps working.  (Safe: by
# this line ServeCore/Request exist, which is all serve/lm.py needs.)
from repro.serve.lm import ServeEngine  # noqa: E402,F401
