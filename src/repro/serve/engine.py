"""Continuous-batching serve engine: fused prefill + slot lifecycle.

Requests enter a FIFO queue; free slots are (re)filled on admission by ONE
fused ``model.prefill`` call that rewinds the slot's cache region (length,
KV, recurrent/conv state) and writes the whole prompt prefix into it; every
engine tick runs one fused, jit-compiled serve step for all slots.  Free
slots are masked out of the step — their cache never advances — so a freed
slot can be handed to the next request with no stale-KV pollution: admission
into a reused slot is bit-identical to a solo run on a fresh engine.

The serve step is a single compiled executable across the whole engine
lifetime: sampling mode (greedy / top-k) is baked at construction, while the
PRNG key, temperature, and the DyFXU approximation ``degree`` (Ch. 5 §5.2.3)
are traced operands — a global scalar or, under an
:class:`~repro.tune.plan.ApproxPlan`, a per-layer degree *vector*
(models/degrees.py).  An optional :class:`~repro.core.dynamic.QoSController`
moves the degree with serving load — the dissertation's runtime-configuration
contract at system level: heavy load -> cheaper arithmetic, idle -> exact.
With a plan the controller steps along the plan's calibrated degree ladder
(whole mixed per-layer configurations, Pareto points from ``repro.tune``)
instead of rescaling one global knob; either way the compiled executable
never changes.

  eos_id semantics: ``-1`` (the default) disables EOS stopping — no vocab id
  compares equal.  When set, sampling ``eos_id`` finishes the request; the
  EOS token itself is neither emitted into ``out_tokens`` nor charged
  against ``max_new_tokens``.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic import QoSController, degree_operand
from repro.kernels import dispatch as kdispatch
from repro.models.cache_ops import cache_mask_update
from repro.models.registry import Model
from repro.obs import trace as obs_trace
from repro.serve.metrics import EngineStats
from repro.serve.sampling import sample_tokens

_DEFAULT_EBITS = 8


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    prefill_tokens: int = 0       # prompt tokens ingested by the fused call
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    # degree tuple that served the first generated token (None until then,
    # or engine running without a traced degree): makes mid-run QoS rung
    # moves visible per request, not just the engine-final degree
    degree_at_first_token: Optional[tuple] = None

    # -- latency breakdown (valid once done) --
    @property
    def queue_time(self) -> float:
        return self.t_admitted - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_enqueue

    @property
    def tpot(self) -> float:
        return (self.t_done - self.t_first_token) / max(len(self.out_tokens) - 1, 1)

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_enqueue


class ServeEngine:
    """Continuous-batching engine over a fixed decode batch of ``slots``.

    Construction compiles the fused serve step once; afterwards ``submit``
    enqueues requests and ``tick`` / ``run_until_drained`` advance the batch.
    ``qos`` drives the runtime approximation degree from load; ``plan``
    replaces the controller's global-ebits ladder with the plan's calibrated
    per-layer degree ladder (and supplies the initial degree vector), so QoS
    moves between whole tuned configurations.  ``degree`` pins a static
    initial degree (scalar or per-site vector) without a controller.
    ``prepack`` packs AXQ/emul weights into int8 residency at admission
    (DESIGN.md §9).

    Observability (DESIGN.md §11): every lifecycle edge — enqueue,
    admission/prefill, per-tick decode, first token, completion, QoS rung
    transitions (with the per-site degree vector attached) — is traced
    through ``tracer`` (the process-global :mod:`repro.obs.trace` tracer
    by default; free when disabled), and every counter lives in
    ``stats.registry`` (a fresh :class:`repro.obs.metrics.Registry`, or
    pass ``registry=`` to co-export with the dispatch counters).
    ``quality_every=N`` samples the live-vs-exact logit error every N
    ticks into a per-rung histogram (``obs/quality.py``).
    """

    def __init__(self, model: Model, params, *, slots: int = 8,
                 max_len: int = 512, eos_id: int = -1, tp: int = 1,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0,
                 qos: Optional[QoSController] = None,
                 degree=None, prepack: bool = True, plan=None,
                 registry=None, tracer=None, quality_every: int = 0):
        self.model = model
        # quantize-once weight residency (DESIGN.md §9): AXQ/emul weights are
        # packed at admission into the engine, so every prefill/decode step
        # touches int8 weights only — the per-call quantize+transpose and the
        # live f32 weight copy are gone.  No-op under an EXACT-only policy.
        self.params = model.prepack(params) if prepack else params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.tp = tp
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self.qos = qos
        self.cache = model.init_cache(tp=tp, batch=slots, max_len=max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self.stats = EngineStats(registry)
        self._tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self._tokens = np.zeros((slots, 1), np.int32)
        self._rid = itertools.count()
        self._ticks = 0
        self._key = jax.random.PRNGKey(seed)
        # prompt-length bound: stateful families ingest unbounded prompts;
        # window caches ring-wrap only while window <= max_len (decode
        # saturates otherwise — attention.py); dense attention is bounded
        # by the cache capacity outright
        cfg = model.cfg
        window = cfg.local_window if cfg.family == "hybrid" else cfg.swa_window
        if cfg.family == "ssm" or (window is not None and window <= max_len):
            self._max_prompt = None
        else:
            self._max_prompt = max_len
        # approximation plan: validate against the arch, and point the QoS
        # controller's ladder at the plan's calibrated per-layer rungs
        self.plan = plan
        if plan is not None:
            plan.validate_for(cfg)
            if qos is not None:
                qos.ladder = plan.qos_ladder()
                qos.degree = min(qos.degree, len(qos.ladder) - 1)
        # degree is traced only when someone will drive it; None keeps the
        # static policy spec (and a leaner step signature).  With a plan (or
        # any ladder of per-layer rungs) the traced operand is the degree
        # vector (models/degrees.py) — its shape is fixed by the arch, so
        # ladder moves never retrace.  The initial degree comes from the
        # controller's current rung so the first QoS update cannot change
        # the operand's shape (scalar -> vector would recompile).
        self._use_degree = (qos is not None or degree is not None
                            or plan is not None)
        if degree is not None:
            self._degree = jnp.asarray(degree, jnp.int32)
        elif qos is not None and qos.ladder:
            self._degree = degree_operand(qos.ladder[qos.degree])
        elif plan is not None:
            self._degree = jnp.asarray(plan.degrees(0), jnp.int32)
        else:
            self._degree = (jnp.asarray(_DEFAULT_EBITS, jnp.int32)
                            if self._use_degree else None)
        # plan site names label the repro_degree_ebits{site=..} gauge family
        # (and trace events); scalar degrees export as site="global"
        from repro.tune.plan import site_names as _site_names

        self._site_names = _site_names(cfg)
        self._degree_rec: Optional[tuple] = None
        if self._degree is not None:
            # the construction-time degree is served until the first QoS
            # update: record it so the history covers every degree used
            self._degree_rec = self.stats.record_degree(
                -1, self._degree, self._site_names)
        # per-rung online quality telemetry (obs/quality.py): compare the
        # live degree's logits against the exact rung every N ticks
        self._tap = None
        if quality_every > 0:
            if self._degree is None:
                raise ValueError(
                    "quality_every needs a traced degree (pass degree=, "
                    "qos=, or plan=)")
            from repro.obs.quality import QualityTap

            self._tap = QualityTap(model, tp=tp, every=quality_every,
                                   registry=self.stats.registry,
                                   tracer=self._tracer)
        # resolved kernel backend for the per-tick route counters: captured
        # from dispatch.last_route after the first traced step/prefill
        self._route: dict = {}
        vocab = model.cfg.vocab

        def serve_step(p, cache, tokens, active, key, temp, deg):
            logits, new_cache = model.decode_step(p, cache, tokens, tp=tp,
                                                  degree=deg, active=active)
            # free slots are masked out: length frozen, region unwritten
            new_cache = cache_mask_update(cache, new_cache, active)
            nxt = sample_tokens(logits[:, 0, :vocab], key, greedy=greedy,
                                temperature=temp, top_k=top_k)
            return nxt, new_cache

        self._step = jax.jit(serve_step)
        self._prefill = jax.jit(
            lambda p, c, t, s, deg: model.prefill(p, c, t, s, tp=tp, degree=deg))
        self._reset = jax.jit(model.reset_slot)

    # ------------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        """Enqueue one request (FIFO).  Returns the live Request object —
        tokens appear in ``request.out_tokens`` as ticks generate them, and
        latency fields populate when it finishes.  Raises at submit time for
        empty prompts or prompts exceeding the cache capacity (rejecting
        mid-tick would lose the request)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if self._max_prompt is not None and prompt.size > self._max_prompt:
            # reject at submit time: raising mid-tick would lose the request
            raise ValueError(
                f"prompt length {prompt.size} exceeds cache capacity "
                f"{self._max_prompt} (max_len)")
        req = Request(rid=next(self._rid),
                      prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      t_enqueue=time.time())
        self.queue.append(req)
        self._tracer.event("enqueue", track="engine", rid=req.rid,
                           prompt_tokens=int(prompt.size),
                           max_new_tokens=max_new_tokens,
                           queue_depth=len(self.queue))
        return req

    def _admit(self, slot: int, req: Request):
        """Reset the slot's cache region and ingest the prompt prefix with
        one fused prefill call; the final prompt token rides the next fused
        decode step (it produces the first generated token)."""
        req.t_admitted = time.time()
        prompt = req.prompt
        sl = jnp.asarray(slot, jnp.int32)
        with self._tracer.span("prefill", track="engine", rid=req.rid,
                               slot=slot, prompt_tokens=int(prompt.size)):
            if prompt.size > 1:
                _, self.cache = self._prefill(self.params, self.cache,
                                              jnp.asarray(prompt[:-1]), sl,
                                              self._degree)
                req.prefill_tokens = int(prompt.size) - 1
                self.stats.c_prefill_tokens.inc(int(prompt.size) - 1)
                self.stats.c_prefill_calls.inc()
                self._count_route("prefill")
            else:
                self.cache = self._reset(self.cache, sl)
        self._tokens[slot, 0] = int(prompt[-1])
        self.slot_req[slot] = req
        self.slot_budget[slot] = req.max_new_tokens
        self.stats.c_admitted.inc()

    def _update_degree(self, n_active: int):
        """Feed the QoS controller a load-headroom signal: overload drives
        the approximation degree down the ladder (cheaper arithmetic), idle
        capacity drives it back to exact — at fixed compiled executable.
        Plan ladders step whole per-layer degree vectors; the legacy global
        ladder steps one ebits scalar."""
        occupancy = (n_active + len(self.queue)) / self.slots
        headroom = max(0.0, 1.0 - occupancy)
        kw = self.qos.update(self._ticks, headroom)
        self._degree = degree_operand(kw)
        rec = self.stats.record_degree(self._ticks, self._degree,
                                       self._site_names)
        if rec != self._degree_rec:
            # QoS rung transition: the event carries the full per-site
            # degree vector so the trace shows WHICH arithmetic served
            # every span that follows
            self._tracer.event("qos_rung", track="engine", tick=self._ticks,
                               rung=self.qos.degree, degrees=list(rec),
                               headroom=round(headroom, 4))
            self._degree_rec = rec

    def _count_route(self, site: str) -> None:
        """Per-call kernel-route counter: the backend is read from
        ``dispatch.last_route`` (written at trace time of this engine's
        jitted step/prefill) and cached — so the counters reflect what
        actually compiled, and `sum(route counters) == call count`."""
        backend = self._route.get(site)
        if backend is None:
            backend = kdispatch.last_route.get(site,
                                               kdispatch.resolved_backend())
            self._route[site] = backend
            self._tracer.event("kernel_route", track="engine", site=site,
                               backend=backend)
        self.stats.c_route_steps.labels(site=site, backend=backend).inc()

    def tick(self) -> int:
        """One engine iteration: admit queued requests into free slots
        (fused prefill per admission), update the QoS degree, run ONE fused
        decode step over all slots, and harvest sampled tokens / finished
        requests.  Returns the number of active slots (0 = idle)."""
        # FIFO admission into free slots
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                self._admit(s, self.queue.popleft())
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        if self.qos is not None:
            self._update_degree(len(active))
        mask = np.zeros(self.slots, bool)
        mask[active] = True
        if self._tap is not None and self._tap.due(self._ticks):
            # probe BEFORE the step: same inputs the fused step is about to
            # consume, cache untouched (the tap discards its cache updates)
            self._tap.sample(self._ticks, self.params, self.cache,
                             self._tokens, mask, self._degree)
        self._key, sub = jax.random.split(self._key)
        with self._tracer.span("decode_tick", track="engine",
                               tick=self._ticks, active=len(active),
                               queued=len(self.queue)):
            nxt, self.cache = self._step(self.params, self.cache,
                                         jnp.asarray(self._tokens),
                                         jnp.asarray(mask), sub,
                                         self.temperature, self._degree)
            nxt = np.asarray(nxt)
        self._ticks += 1
        self.stats.c_decode_steps.inc()
        self.stats.c_decode_tokens.inc(len(active))
        self._count_route("decode")
        self._tracer.counter("slots", track="engine", active=len(active),
                             queued=len(self.queue))
        now = time.time()
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            hit_eos = self.eos_id >= 0 and tok == self.eos_id
            if not hit_eos:
                # EOS is never emitted nor charged against the budget; a
                # request that EOSes before emitting anything keeps
                # t_first_token == 0 (excluded from TTFT stats)
                if req.t_first_token == 0.0:
                    req.t_first_token = now
                    req.degree_at_first_token = self._degree_rec
                    self._tracer.event("first_token", track="engine",
                                       rid=req.rid, slot=s,
                                       ttft_ms=round(req.ttft * 1e3, 3))
                req.out_tokens.append(tok)
                self._tokens[s, 0] = tok
                self.slot_budget[s] -= 1
            if hit_eos or self.slot_budget[s] <= 0:
                req.done = True
                req.t_done = now
                self.done.append(req)
                self.slot_req[s] = None
                self.stats.record_completion(req)
                self._tracer.event("request_done", track="engine",
                                   rid=req.rid, slot=s, eos=hit_eos,
                                   tokens=len(req.out_tokens),
                                   e2e_ms=round(req.e2e * 1e3, 3))
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until the queue and every slot are empty (or ``max_ticks``);
        returns all finished requests, completion order."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.done
