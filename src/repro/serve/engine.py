"""Workload-generic continuous-batching serve core: slot lifecycle + QoS.

Requests enter a FIFO queue; free slots are (re)filled on admission by the
workload's fused ingest call, which rewinds the slot's state region and
writes the payload prefix into it; every engine tick runs ONE fused,
jit-compiled step for all slots.  Free slots are masked out of the step —
their state never advances — so a freed slot can be handed to the next
request with no stale-state pollution: admission into a reused slot is
bit-identical to a solo run on a fresh engine.

The engine is generic over a :class:`~repro.serve.servable.ServableModel`
(DESIGN.md §12): everything workload-specific — what a unit of work is, how
a payload is ingested, what the fused step computes, when a request
finishes, even the vocabulary the trace events speak — lives behind that
protocol.  ``serve/lm.py`` adapts the language models (the historical
``ServeEngine`` surface, re-exported below unchanged); ``serve/stream.py``
serves the Ch. 7 approximate DSP/vision pipeline frame-by-frame through the
same slot lifecycle.

The fused step is a single compiled executable across the whole engine
lifetime: workload sampling/config is baked at construction, while the PRNG
key and the DyFXU approximation ``degree`` (Ch. 5 §5.2.3) are traced
operands — a global scalar or, under an
:class:`~repro.tune.plan.ApproxPlan`, a per-site degree *vector*
(models/degrees.py).  An optional :class:`~repro.core.dynamic.QoSController`
moves the degree with serving load — the dissertation's
runtime-configuration contract at system level: heavy load -> cheaper
arithmetic, idle -> exact.  With a plan the controller steps along the
plan's calibrated ladder (whole mixed per-site configurations, Pareto
points from ``repro.tune``) instead of rescaling one global knob; either
way the compiled executable never changes.

Resilience (``repro.resil``, DESIGN.md §13): ``faults=`` injects a seeded
:class:`~repro.resil.faults.FaultPlan` (SEU bit flips, NaN/Inf activations,
latency spikes, dropped ticks); ``guards=`` switches the engine onto the
workload's ``guarded_step`` — per-slot ok bits, quarantine through the
bit-identical slot reset, golden-param scrubbing, quality-tap sentinel;
``policy=`` adds deadlines, capped-backoff retry, backpressure, and
brownout-by-approximation (the QoS ladder degrades before anything sheds).
``clock=`` injects the engine's time source (``resil.policy.VirtualClock``
makes deadline/goodput behavior deterministic).  With all four at their
defaults the engine compiles and runs the exact legacy path.  Every request
terminates exactly once in ``done`` with a status in {ok, failed, shed,
deadline} — nothing is lost or double-charged — and ``resil_log`` records
the (tick, event, args) recovery trace the determinism tests assert on.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic import QoSController, degree_operand
from repro.kernels import dispatch as kdispatch
from repro.obs import trace as obs_trace
from repro.serve.metrics import EngineStats
from repro.serve.servable import ServableModel

_DEFAULT_EBITS = 8


@dataclass
class Request:
    """One unit of serving work, workload-agnostic.  ``payload`` is what the
    workload ingests (LM prompt ids, stream frames), ``out`` what its steps
    emit; the LM adapter subclasses this with the historical field names as
    read-only views (``serve/lm.py``)."""

    rid: int
    payload: object
    budget: int = 32              # emission budget (units)
    payload_units: int = 0        # payload size in workload units
    out: list = field(default_factory=list)
    done: bool = False
    admitted_units: int = 0       # units ingested by the fused admit call
    cursor: int = 0               # workload read head into the payload
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_emit: float = 0.0
    t_done: float = 0.0
    # degree tuple that served the first emission (None until then, or
    # engine running without a traced degree): makes mid-run QoS rung moves
    # visible per request, not just the engine-final degree
    degree_at_first_emit: Optional[tuple] = None
    # -- resilience lifecycle (repro.resil; defaults = legacy behavior) --
    #: terminal disposition: ok | failed (retries spent) | shed | deadline
    status: str = "ok"
    #: guard-trip requeues so far
    retries: int = 0
    #: e2e / TTFT deadlines (seconds from t_enqueue; None = none)
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None
    #: earliest admission time (retry backoff gate)
    eligible_at: float = 0.0

    # -- latency breakdown (valid once done) --
    @property
    def queue_time(self) -> float:
        return self.t_admitted - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_emit - self.t_enqueue

    @property
    def tpot(self) -> float:
        return (self.t_done - self.t_first_emit) / max(len(self.out) - 1, 1)

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_enqueue


class ServeCore:
    """Continuous-batching engine over a fixed batch of ``slots``, generic
    over a :class:`~repro.serve.servable.ServableModel` workload.

    Construction compiles the workload's fused step once; afterwards
    ``submit`` enqueues requests and ``tick`` / ``run_until_drained``
    advance the batch.  ``qos`` drives the runtime approximation degree
    from load; ``plan`` replaces the controller's global-ebits ladder with
    the plan's calibrated per-site ladder (and supplies the initial degree
    vector), so QoS moves between whole tuned configurations.  ``degree``
    pins a static initial degree (scalar or per-site vector) without a
    controller.  ``prepack`` applies the workload's quantize-once weight
    residency at construction (DESIGN.md §9).

    Observability (DESIGN.md §11): every lifecycle edge — enqueue,
    admission/ingest, per-tick step, first emission, completion, QoS rung
    transitions (with the per-site degree vector attached) — is traced
    through ``tracer`` (the process-global :mod:`repro.obs.trace` tracer
    by default; free when disabled) under the *workload's* vocabulary, and
    every counter lives in ``stats.registry`` (a fresh
    :class:`repro.obs.metrics.Registry`, or pass ``registry=`` to co-export
    with the dispatch counters).  ``quality_every=N`` samples the
    live-vs-exact output error every N ticks into a per-rung histogram
    (``obs/quality.py``) through the workload's quality tap.
    """

    def __init__(self, workload: ServableModel, params, *, slots: int = 8,
                 max_len: int = 512, seed: int = 0,
                 qos: Optional[QoSController] = None,
                 degree=None, prepack: bool = True, plan=None,
                 registry=None, tracer=None, quality_every: int = 0,
                 faults=None, guards=None, policy=None, clock=None,
                 emitter=None):
        self.workload = workload
        self.params = workload.prepack(params) if prepack else params
        self.slots = slots
        self.max_len = max_len
        self.qos = qos
        self._clock = clock if clock is not None else time.time
        self.state = workload.init_state(batch=slots, max_len=max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self.stats = EngineStats(registry, unit=workload.unit,
                                 admit_name=workload.admit_span,
                                 step_name=workload.step_span)
        self._tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self._feed = workload.init_feed(slots)
        self._rid = itertools.count()
        self._ticks = 0
        self._key = jax.random.PRNGKey(seed)
        # approximation plan: validate against the arch, and point the QoS
        # controller's ladder at the plan's calibrated per-site rungs
        cfg = workload.cfg
        self.plan = plan
        if plan is not None:
            plan.validate_for(cfg)
            if qos is not None:
                qos.ladder = plan.qos_ladder()
                qos.degree = min(qos.degree, len(qos.ladder) - 1)
        # degree is traced only when someone will drive it; None keeps the
        # static policy spec (and a leaner step signature).  With a plan (or
        # any ladder of per-site rungs) the traced operand is the degree
        # vector (models/degrees.py) — its shape is fixed by the arch, so
        # ladder moves never retrace.  The initial degree comes from the
        # controller's current rung so the first QoS update cannot change
        # the operand's shape (scalar -> vector would recompile).
        self._use_degree = (qos is not None or degree is not None
                            or plan is not None)
        if degree is not None:
            self._degree = jnp.asarray(degree, jnp.int32)
        elif qos is not None and qos.ladder:
            self._degree = degree_operand(qos.ladder[qos.degree])
        elif plan is not None:
            self._degree = jnp.asarray(plan.degrees(0), jnp.int32)
        else:
            self._degree = (jnp.asarray(_DEFAULT_EBITS, jnp.int32)
                            if self._use_degree else None)
        # plan site names label the repro_degree_ebits{site=..} gauge family
        # (and trace events); scalar degrees export as site="global"
        from repro.tune.plan import site_names as _site_names

        self._site_names = _site_names(cfg)
        self._degree_rec: Optional[tuple] = None
        if self._degree is not None:
            # the construction-time degree is served until the first QoS
            # update: record it so the history covers every degree used
            self._degree_rec = self.stats.record_degree(
                -1, self._degree, self._site_names)
        # per-rung online quality telemetry (obs/quality.py): compare the
        # live degree's outputs against the exact rung every N ticks
        self._tap = None
        if quality_every > 0:
            if self._degree is None:
                raise ValueError(
                    "quality_every needs a traced degree (pass degree=, "
                    "qos=, or plan=)")
            self._tap = workload.quality_tap(every=quality_every,
                                             registry=self.stats.registry,
                                             tracer=self._tracer)
        # resolved kernel backend for the per-tick route counters: captured
        # from dispatch.last_route after the first traced step/ingest
        self._route: dict = {}
        # -- resilience wiring (repro.resil, DESIGN.md §13) ---------------
        # faults imply guards (injected corruption must be catchable) and
        # guards imply a policy (something must own retry semantics); with
        # all three absent the compiled step is the exact legacy jaxpr.
        if faults is not None and guards is None:
            from repro.resil import GuardConfig
            guards = GuardConfig()
        if guards is not None and policy is None:
            from repro.resil import ServePolicy
            policy = ServePolicy()
        self.faults = faults
        self.guards = guards
        self.policy = policy
        #: (tick, event, sorted-args) recovery trace — the determinism
        #: contract: same fault seed + same traffic -> identical log
        self.resil_log: list = []
        self._golden = None
        self._sentinel = None
        self._fault_vec = np.zeros(slots, np.float32)
        if guards is not None:
            if guards.limit is not None:
                workload.guard_limit = guards.limit
            # golden copy for scrubbing: JAX immutability makes this a free
            # reference — prepacked weights are repaired by the same rebind
            self._golden = self.params
            self._slot_reset = jax.jit(workload.reset_slot)
            if guards.sentinel_threshold is not None:
                if self._tap is None:
                    raise ValueError(
                        "sentinel_threshold needs quality_every > 0 (the "
                        "sentinel watches the quality tap's samples)")
                self._sentinel = guards.sentinel()
            self._step = jax.jit(workload.guarded_step)
        else:
            self._step = jax.jit(workload.step)
        if faults is not None:
            faults.bind(self.state, self.params, slots)
        # -- admission pipeline (DESIGN.md §15): bucketed AOT prefill, ----
        # packed prompts, chunked prefill, async emit.  None = the legacy
        # exact-length admission path, bit-identical to prior engines.
        self._admission = getattr(workload, "admission", None)
        self.emitter = None
        if self._admission is not None and emitter is not False:
            from repro.serve.emitq import AsyncEmitter
            self.emitter = emitter if emitter is not None else AsyncEmitter()
        # warmup traces every admission executable + the fused step so no
        # request compiles after startup; ShardedServeCore defers it until
        # params/state carry their final shardings (a resharded arg would
        # otherwise retrace at first live call)
        if not getattr(self, "_defer_warmup", False):
            self._maybe_warmup()

    def _maybe_warmup(self) -> None:
        a = self._admission
        if a is None or not a.warmup:
            return
        with self._tracer.span("admission_warmup", track="engine",
                               buckets=list(a.buckets), pack=a.pack,
                               chunk=a.chunk_tokens):
            self.workload.warmup_admission(self.params, self.state,
                                           self._feed, self._degree)
            # the fused decode-step executable, with a throwaway key and an
            # all-free mask (state updates are masked out and discarded)
            mask = jnp.zeros(self.slots, bool)
            key = jax.random.PRNGKey(0)
            feed = jnp.asarray(self._feed)
            if self.guards is not None:
                out = self._step(self.params, self.state, feed, mask, key,
                                 self._degree, jnp.asarray(self._fault_vec))
            else:
                out = self._step(self.params, self.state, feed, mask, key,
                                 self._degree)
            jax.block_until_ready(out)
        self.stats.c_warmups.inc()

    # ------------------------------------------------------------------

    def submit(self, payload, budget: Optional[int] = None, *,
               deadline_ms: Optional[float] = None,
               ttft_deadline_ms: Optional[float] = None) -> Request:
        """Enqueue one request (FIFO).  Returns the live Request object —
        emissions appear in ``request.out`` as ticks produce them, and
        latency fields populate when it finishes.  The workload validates
        the payload here (raising at submit time — rejecting mid-tick
        would lose the request).  ``deadline_ms``/``ttft_deadline_ms``
        override the policy defaults per request (ignored without a
        policy — nothing would enforce them)."""
        wl = self.workload
        payload = wl.validate(payload)
        if budget is None:
            budget = wl.default_budget(payload)
        p = self.policy
        if p is not None:
            if deadline_ms is None:
                deadline_ms = p.deadline_ms
            if ttft_deadline_ms is None:
                ttft_deadline_ms = p.ttft_deadline_ms
        req = (wl.request_cls or Request)(
            rid=next(self._rid), payload=payload, budget=int(budget),
            payload_units=wl.payload_units(payload),
            t_enqueue=self._clock(),
            deadline_s=None if deadline_ms is None else deadline_ms / 1e3,
            ttft_deadline_s=(None if ttft_deadline_ms is None
                             else ttft_deadline_ms / 1e3))
        self.queue.append(req)
        self._tracer.event(
            "enqueue", track="engine", rid=req.rid,
            queue_depth=len(self.queue),
            **{wl.payload_arg: req.payload_units, wl.budget_arg: int(budget)})
        return req

    def _admit(self, slot: int, req: Request):
        """Reset the slot's state region and ingest the payload via the
        workload's fused admit; the first step input lands in the feed."""
        req.t_admitted = self._clock()
        wl = self.workload
        with self._tracer.span(wl.admit_span, track="engine", rid=req.rid,
                               slot=slot,
                               **{wl.payload_arg: req.payload_units}):
            self.state, ingested = wl.admit(self.params, self.state,
                                            self._feed, slot, req,
                                            self._degree)
        req.admitted_units = int(ingested)
        if req.admitted_units > 0:
            self.stats.c_admit_units.inc(req.admitted_units)
            self.stats.c_admit_calls.inc()
            if wl.admit_site:
                self._count_route(wl.admit_site)
        self.slot_req[slot] = req
        self.slot_budget[slot] = req.budget
        self.stats.c_admitted.inc()

    # ---- admission pipeline (DESIGN.md §15) ---------------------------

    def _chunk_call(self, slot: int, req: Request) -> None:
        """One chunked-prefill device call advancing ``req``'s admission."""
        wl = self.workload
        with self._tracer.span(wl.admit_span, track="engine", rid=req.rid,
                               slot=slot, chunk=True, cursor=req.cursor):
            self.state, n = wl.admit_chunk(self.params, self.state,
                                           self._feed, slot, req,
                                           self._degree)
        req.admitted_units += int(n)
        if n > 0:
            self.stats.c_admit_units.inc(int(n))
        self.stats.c_admit_calls.inc()
        self.stats.c_chunk_calls.inc()
        if wl.admit_site:
            self._count_route(wl.admit_site)

    def _flush_batch(self, pairs: list) -> None:
        """Admit up to ``pack`` requests in one bucketed prefill call."""
        if not pairs:
            return
        wl = self.workload
        with self._tracer.span(wl.admit_span, track="engine",
                               rid=pairs[0][1].rid, slot=pairs[0][0],
                               packed=len(pairs)):
            self.state, ingested = wl.admit_batch(self.params, self.state,
                                                  self._feed, pairs,
                                                  self._degree)
        total = 0
        for (_, req), n in zip(pairs, ingested):
            req.admitted_units = int(n)
            total += int(n)
        if total > 0:
            self.stats.c_admit_units.inc(total)
        self.stats.c_admit_calls.inc()
        if len(pairs) > 1:
            self.stats.c_packed_rows.inc(len(pairs))
        bucket = getattr(wl, "last_admit_bucket", None)
        if bucket is not None:
            self.stats.c_admit_bucket.labels(bucket=str(bucket)).inc()
        if wl.admit_site:
            self._count_route(wl.admit_site)

    def _admit_pipeline(self, now: float) -> None:
        """Bucketed/packed/chunked admission: first advance mid-admission
        chunked slots (bounded calls per tick, so long-prompt ingestion
        interleaves with decode instead of stalling short-request TTFT),
        then fill free slots — chunked requests take their slot alone,
        short ones pack into one bucketed prefill call."""
        wl = self.workload
        a = self._admission
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None or wl.admit_complete(req):
                continue
            for _ in range(a.chunk_calls_per_tick):
                self._chunk_call(s, req)
                if wl.admit_complete(req):
                    break
        batch: list = []
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            if self.policy is None:
                req = self.queue.popleft() if self.queue else None
            else:
                req = self._next_admittable(now)
            if req is None:
                break
            req.t_admitted = now
            self.slot_req[s] = req
            self.slot_budget[s] = req.budget
            self.stats.c_admitted.inc()
            if wl.wants_chunked(req):
                self._chunk_call(s, req)
            else:
                batch.append((s, req))
                if len(batch) >= a.pack:
                    self._flush_batch(batch)
                    batch = []
        self._flush_batch(batch)

    def _update_degree(self, n_active: int):
        """Feed the QoS controller a load-headroom signal: overload drives
        the approximation degree down the ladder (cheaper arithmetic), idle
        capacity drives it back to exact — at fixed compiled executable.
        Plan ladders step whole per-site degree vectors; the legacy global
        ladder steps one ebits scalar."""
        occupancy = (n_active + len(self.queue)) / self.slots
        headroom = max(0.0, 1.0 - occupancy)
        kw = self.qos.update(self._ticks, headroom)
        self._degree = degree_operand(kw)
        rec = self.stats.record_degree(self._ticks, self._degree,
                                       self._site_names)
        if rec != self._degree_rec:
            # QoS rung transition: the event carries the full per-site
            # degree vector so the trace shows WHICH arithmetic served
            # every span that follows
            self._tracer.event("qos_rung", track="engine", tick=self._ticks,
                               rung=self.qos.degree, degrees=list(rec),
                               headroom=round(headroom, 4))
            self._degree_rec = rec

    def _count_route(self, site: str) -> None:
        """Per-call kernel-route counter: the backend is read from
        ``dispatch.last_route`` (written at trace time of this engine's
        jitted step/admit) and cached — so the counters reflect what
        actually compiled, and `sum(route counters) == call count`."""
        backend = self._route.get(site)
        if backend is None:
            backend = kdispatch.last_route.get(site,
                                               kdispatch.resolved_backend())
            self._route[site] = backend
            self._tracer.event("kernel_route", track="engine", site=site,
                               backend=backend)
        self.stats.c_route_steps.labels(site=site, backend=backend).inc()

    # ---- resilience machinery (repro.resil, DESIGN.md §13) -------------

    def _resil_event(self, name: str, **args) -> None:
        """Record one recovery-trace entry + the matching obs trace event.
        The log entry is a plain (tick, name, sorted-args) tuple so two
        runs compare with ``==`` — the determinism contract's artifact."""
        self.resil_log.append((self._ticks, name, tuple(sorted(args.items()))))
        self._tracer.event(name, track="resil", tick=self._ticks, **args)

    def _finish(self, req: Request, status: str, now: float,
                slot: Optional[int] = None) -> None:
        """Terminate one request non-ok (failed/shed/deadline): exactly one
        ``done`` entry per submitted request, whatever its fate."""
        req.status = status
        req.done = True
        req.t_done = now
        self.done.append(req)
        if slot is not None:
            self.slot_req[slot] = None

    def _scrub(self, reason: str) -> None:
        """Restore the golden parameter tree (memory scrubbing): repairs
        any persistent seu_param corruption.  Free when already golden."""
        if self._golden is not None and self.params is not self._golden:
            self.params = self._golden
            self.stats.c_scrubs.inc()
            self._resil_event("param_scrub", reason=reason)

    def _quarantine(self, slot: int, now: float) -> None:
        """Per-slot guard trip: reset the slot through the bit-identical
        cache_ops reset, scrub, and requeue (rewound to a fresh admission,
        behind capped backoff) or fail the request per policy."""
        req = self.slot_req[slot]
        self.stats.c_guard_trips.labels(reason="slot").inc()
        self._resil_event("guard_tripped", reason="slot", rid=req.rid,
                          slot=slot)
        self.state = self._slot_reset(self.state, jnp.asarray(slot, jnp.int32))
        self.slot_req[slot] = None
        if self.guards.scrub_on_trip:
            self._scrub("guard_trip")
        req.retries += 1
        if req.retries > self.policy.max_retries:
            self._finish(req, "failed", now)
            self.stats.c_failed.inc()
            self._resil_event("request_failed", rid=req.rid,
                              retries=req.retries)
            return
        # full rewind: the retry must be indistinguishable from a fresh
        # admission (asserted bit-identical by the quarantine tests)
        req.out.clear()
        req.cursor = 0
        req.admitted_units = 0
        req.t_first_emit = 0.0
        req.degree_at_first_emit = None
        backoff = self.policy.backoff_s(req.retries)
        req.eligible_at = now + backoff
        self.queue.appendleft(req)
        self.stats.c_retries.inc()
        self._resil_event("retry", rid=req.rid, retries=req.retries,
                          backoff_ms=round(backoff * 1e3, 3))

    def _next_admittable(self, now: float) -> Optional[Request]:
        """Oldest queued request whose retry backoff has elapsed."""
        for req in self.queue:
            if req.eligible_at <= now:
                self.queue.remove(req)
                return req
        return None

    def _enforce_queue_policy(self, now: float) -> None:
        """Deadline-cull the queue, apply queue-age shedding, and resolve
        queue-length overload: brownout first (force the QoS controller one
        rung down the calibrated ladder), shed — newest first — only once
        the ladder is exhausted."""
        p = self.policy
        keep: deque[Request] = deque()
        for req in self.queue:
            age = now - req.t_enqueue
            if req.deadline_s is not None and age > req.deadline_s:
                self._finish(req, "deadline", now)
                self.stats.c_deadline_miss.labels(edge="queue").inc()
                self._resil_event("deadline_miss", edge="queue", rid=req.rid)
                continue
            if req.ttft_deadline_s is not None and req.t_first_emit == 0.0:
                # TTFT measures from ENQUEUE, so a queued request spends
                # its budget while waiting: past the deadline it can no
                # longer emit in time, and one whose remaining budget
                # cannot cover its admission call count (chunked prompts
                # need several device calls) is doomed — shed it now
                # instead of burning device time on a guaranteed miss
                if age > req.ttft_deadline_s:
                    self._finish(req, "deadline", now)
                    self.stats.c_deadline_miss.labels(edge="queue_ttft").inc()
                    self._resil_event("deadline_miss", edge="queue_ttft",
                                      rid=req.rid)
                    continue
                if p.admit_eta_ms is not None:
                    eta = (self.workload.admit_calls(req)
                           * p.admit_eta_ms / 1e3)
                    if age + eta > req.ttft_deadline_s:
                        self._finish(req, "shed", now)
                        self.stats.c_shed.labels(reason="doomed").inc()
                        self._resil_event("shed", reason="doomed",
                                          rid=req.rid)
                        continue
            if (p.max_queue_age_ms is not None
                    and age * 1e3 > p.max_queue_age_ms):
                self._finish(req, "shed", now)
                self.stats.c_shed.labels(reason="stale").inc()
                self._resil_event("shed", reason="stale", rid=req.rid)
                continue
            keep.append(req)
        self.queue = keep
        if p.max_queue is None or len(self.queue) <= p.max_queue:
            return
        qos = self.qos
        if (p.brownout and qos is not None and qos.ladder
                and qos.degree < len(qos.ladder) - 1):
            # graceful degradation: one rung per tick, with the controller's
            # own cooldown armed so it can't immediately climb back
            qos.degree += 1
            qos._cooldown = qos.cooldown_steps
            self.stats.c_brownout.inc()
            self._resil_event("brownout_rung", rung=qos.degree,
                              queued=len(self.queue))
            return
        while len(self.queue) > p.max_queue:
            victim = self.queue.pop()
            self._finish(victim, "shed", now)
            self.stats.c_shed.labels(reason="overload").inc()
            self._resil_event("shed", reason="overload", rid=victim.rid)

    def _enforce_active_deadlines(self, now: float) -> None:
        """Terminate in-slot requests past their e2e or TTFT deadline (the
        freed slot region is rewound by the next admission's reset)."""
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            age = now - req.t_enqueue
            if req.deadline_s is not None and age > req.deadline_s:
                edge = "active"
            elif (req.ttft_deadline_s is not None and req.t_first_emit == 0.0
                    and age > req.ttft_deadline_s):
                edge = "ttft"
            else:
                continue
            self._finish(req, "deadline", now, slot=s)
            self.stats.c_deadline_miss.labels(edge=edge).inc()
            self._resil_event("deadline_miss", edge=edge, rid=req.rid, slot=s)

    def _stall(self, seconds: float) -> None:
        """Latency spike: advance an injectable clock, sleep a real one."""
        advance = getattr(self._clock, "advance", None)
        if advance is not None:
            advance(seconds)
        else:
            time.sleep(seconds)

    def _apply_faults(self) -> bool:
        """Apply this tick's scheduled faults; True = the step is dropped.
        State/param flips mutate the live trees (the golden copy is safe by
        immutability); activation faults arm the traced fault operand."""
        drop = False
        for ev in self.faults.events_at(self._ticks):
            self.faults.record(ev)
            self.stats.c_faults.labels(kind=ev.kind).inc()
            self._resil_event("fault_injected", **ev.args())
            if ev.kind == "seu_state":
                self.state = self.faults.apply_state(self.state, ev)
            elif ev.kind == "seu_param":
                self.params = self.faults.apply_params(self.params, ev)
            elif ev.kind == "nan":
                self._fault_vec[ev.slot] = ev.value
            elif ev.kind == "spike":
                self._stall(ev.value)
            elif ev.kind == "drop":
                drop = True
        return drop

    # ---------------------------------------------------------------

    def tick(self) -> int:
        """One engine iteration: admit queued requests into free slots
        (fused ingest per admission), update the QoS degree, run ONE fused
        step over all slots, and harvest emissions / finished requests.
        Returns the number of active slots (0 = idle)."""
        wl = self.workload
        now = self._clock()
        if self.policy is not None:
            self._enforce_queue_policy(now)
            self._enforce_active_deadlines(now)
        # FIFO admission into free slots
        if self._admission is None:
            for s in range(self.slots):
                if self.slot_req[s] is None and self.queue:
                    if self.policy is None:
                        self._admit(s, self.queue.popleft())
                    else:
                        req = self._next_admittable(now)
                        if req is None:
                            break
                        self._admit(s, req)
        else:
            self._admit_pipeline(now)
        if self.guards is not None and self.guards.scrub_every > 0 \
                and self._ticks and self._ticks % self.guards.scrub_every == 0:
            self._scrub("periodic")
        busy = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not busy:
            return 0
        # a slot mid-way through chunked admission holds a request but has
        # no decodable state yet: it stays out of the fused step's mask
        # until its payload is fully ingested
        active = [s for s in busy if wl.admit_complete(self.slot_req[s])]
        if not active:
            # admission-only tick: chunk calls progressed, nothing decodes
            self._ticks += 1
            return len(busy)
        if self.qos is not None:
            self._update_degree(len(active))
        # scheduled faults land before the step: state/param flips are what
        # the step consumes, the armed fault operand poisons its activations
        drop = self.faults is not None and self._apply_faults()
        mask = np.zeros(self.slots, bool)
        mask[active] = True
        if self._tap is not None and self._tap.due(self._ticks):
            # probe BEFORE the step: same inputs the fused step is about to
            # consume, state untouched (the tap discards its state updates)
            val = self._tap.sample(self._ticks, self.params, self.state,
                                   self._feed, mask, self._degree)
            if self._sentinel is not None and self._sentinel.observe(val):
                self.stats.c_guard_trips.labels(reason="quality").inc()
                self._resil_event("guard_tripped", reason="quality",
                                  sample=round(float(val), 6))
                if self.guards.scrub_on_trip:
                    self._scrub("sentinel")
        if drop:
            # dropped tick: the fused step never runs — no state advance,
            # no emission, no budget charge; an armed activation fault
            # evaporates with the skipped cycle
            self._fault_vec[:] = 0.0
            self._ticks += 1
            self.stats.c_dropped_ticks.inc()
            return len(active)
        self._key, sub = jax.random.split(self._key)
        with self._tracer.span(f"{wl.step_span}_tick", track="engine",
                               tick=self._ticks, active=len(active),
                               queued=len(self.queue)):
            if self.guards is not None:
                nxt, self.state, ok = self._step(
                    self.params, self.state, jnp.asarray(self._feed),
                    jnp.asarray(mask), sub, self._degree,
                    jnp.asarray(self._fault_vec))
                ok = np.asarray(ok)
                self._fault_vec[:] = 0.0
            else:
                nxt, self.state = self._step(self.params, self.state,
                                             jnp.asarray(self._feed),
                                             jnp.asarray(mask), sub,
                                             self._degree)
                ok = None
            nxt = np.asarray(nxt)
        self._ticks += 1
        self.stats.c_steps.inc()
        self.stats.c_step_units.inc(len(active))
        for site in wl.step_sites:
            self._count_route(site)
        self._tracer.counter("slots", track="engine", active=len(active),
                             queued=len(self.queue))
        now = self._clock()
        for s in active:
            req = self.slot_req[s]
            if ok is not None and not ok[s]:
                # corrupted emission: never banked — quarantine the slot
                self._quarantine(s, now)
                continue
            emitted, finished, info = wl.harvest(req, self._feed, s, nxt[s])
            if emitted:
                # a suppressed emission (e.g. an LM stop id) is neither
                # banked nor charged against the budget; a request that
                # finishes before emitting anything keeps t_first_emit == 0
                # (excluded from TTFT stats)
                if req.t_first_emit == 0.0:
                    req.t_first_emit = now
                    req.degree_at_first_emit = self._degree_rec
                    self._tracer.event(wl.first_event, track="engine",
                                       rid=req.rid, slot=s,
                                       ttft_ms=round(req.ttft * 1e3, 3))
                if self.emitter is not None:
                    # detokenize/deliver off-thread: harvest returns to the
                    # device step without waiting on host-side emit work
                    self.emitter.push(req, req.out[-1])
                self.slot_budget[s] -= 1
            if finished or self.slot_budget[s] <= 0:
                req.done = True
                req.t_done = now
                self.done.append(req)
                self.slot_req[s] = None
                self.stats.record_completion(req)
                self._tracer.event("request_done", track="engine",
                                   rid=req.rid, slot=s,
                                   e2e_ms=round(req.e2e * 1e3, 3),
                                   **wl.done_args(req, info))
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until the queue and every slot are empty (or ``max_ticks``);
        returns all finished requests, completion order."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        if self.emitter is not None:
            self.emitter.flush()
        return self.done


# The historical LM engine surface lives in serve/lm.py on top of ServeCore;
# re-exported here so every existing import path keeps working.  (Safe: by
# this line ServeCore/Request exist, which is all serve/lm.py needs.)
from repro.serve.lm import ServeEngine  # noqa: E402,F401
