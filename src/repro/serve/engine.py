"""Batched serving engine: continuous batching over a fixed-slot decode batch.

Requests enter a queue; free slots are (re)filled by prefilling the prompt
into that slot's cache region; every engine tick runs one fused serve_step
for all slots.  Slots whose sequence hit EOS/max-len are returned and freed.

This is the (b)-deliverable serving driver; serve_step itself is the unit the
decode dry-run cells lower at production shapes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 max_len: int = 512, eos_id: int = -1, tp: int = 1,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.tp = tp
        self.greedy = greedy
        self.cache = model.init_cache(tp=tp, batch=slots, max_len=max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_budget = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._tokens = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, tp=tp))

    # ------------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        req = Request(rid=len(self.queue) + len(self.done),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      t_enqueue=time.time())
        self.queue.append(req)
        return req

    @staticmethod
    def _merge_slot(old_cache, new_cache, slot: int):
        """Keep `new_cache` state for `slot` only; other slots keep `old`.
        Cache NamedTuples put batch at dim 0 for `length`, dim 1 otherwise."""
        fields = old_cache._fields
        merged = []
        for name in fields:
            o, n = getattr(old_cache, name), getattr(new_cache, name)
            if name == "length":
                merged.append(o.at[slot].set(n[slot]))
            else:
                merged.append(o.at[:, slot].set(n[:, slot]))
        return type(old_cache)(*merged)

    def _fill_slot(self, slot: int, req: Request):
        """Prefill by teacher-forcing the prompt through decode steps, then
        restore every other slot's cache region (slot isolation) — a
        production engine would run a fused prefill kernel into the slot."""
        self.slot_req[slot] = req
        self.slot_budget[slot] = req.max_new_tokens
        snapshot = self.cache
        cache = self.cache
        for t in req.prompt[:-1]:
            toks = self._tokens.copy()
            toks[slot, 0] = t
            _, cache = self._decode(self.params, cache, jnp.asarray(toks))
        self.cache = self._merge_slot(snapshot, cache, slot)
        self._tokens[slot, 0] = int(req.prompt[-1])

    def tick(self) -> int:
        """One engine iteration; returns number of active slots."""
        # admit
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                self._fill_slot(s, self.queue.pop(0))
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self._tokens))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            if not req.out_tokens:
                req.t_first_token = time.time()
            req.out_tokens.append(tok)
            self._tokens[s, 0] = tok
            self.slot_budget[s] -= 1
            if tok == self.eos_id or self.slot_budget[s] <= 0:
                req.done = True
                req.t_done = time.time()
                self.done.append(req)
                self.slot_req[s] = None
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.done
