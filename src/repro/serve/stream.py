"""Streaming DSP/vision workload on the generic serve core (ISSUE 7).

The dissertation's second half accelerates classical DSP — FIR filtering
and 2D convolution on the PR approximate multiplier (Ch. 7) — and this
module serves that pipeline through the SAME machinery the LM workload
uses: slot lifecycle, continuous batching, plan ladder, QoS controller,
tracing/metrics.  A request is a short clip of fixed-length sample frames;
every engine tick pushes one frame per active slot through

    FIR (approx, ``dispatch.fir``)  ->  reshape to a tile  ->
    3x3 blur conv (approx, ``dispatch.conv2d``)  ->  1x1 gain conv

with the three stages as plan *sites* (``fir`` / ``conv2d`` / ``gain`` —
the layer/head analogue), each taking its own slice of the traced degree
vector.  Plans calibrate on application-level quality — PSNR against the
exact-arithmetic pipeline (``core.error_analysis.psnr_db``) — instead of
logit error, per the approximation surveys' guidance.

Fixed-point contract: samples are Q-``cfg.q`` int32 (|x| <= 2**q); FIR
taps and conv kernels are quantized with ``dsp.quantize_weights`` so their
l1 norm bounds the int32 accumulator, and each stage shifts back to the
sample Q format — the whole pipeline is jit-safe integer arithmetic, and
the ``pallas``/``xla`` kernel routes are bit-identical.

Per-slot stream state is a NamedTuple on the ``models/cache_ops.py``
layout (``length`` (B,) at axis 0, other fields batch at axis 1), so the
generic ``cache_reset_slot`` / ``cache_mask_update`` helpers give this
workload the same reuse-after-free bit-identity guarantee the LM caches
have.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx import ApproxPolicy
from repro.core.error_analysis import psnr_db
from repro.kernels import dispatch as kdispatch
from repro.kernels import dsp
from repro.models.cache_ops import cache_mask_update, cache_reset_slot
from repro.serve import engine as _engine
from repro.serve.servable import ServableModel

#: PSNR-flavored histogram buckets (dB) for the stream quality tap
PSNR_BUCKETS = (10.0, 15.0, 20.0, 25.0, 30.0, 35.0, 40.0, 45.0, 50.0,
                60.0, 70.0, 80.0, 100.0, 150.0)


@dataclass(frozen=True)
class StreamConfig:
    """Arch-config analogue for the stream pipeline.  Duck-types what the
    plan machinery reads (``name``, ``n_layers``) plus the autotuner's
    cost-model override (``site_macs``)."""

    name: str = "dsp-stream-v1"
    frame: int = 256              # samples per frame (== tile H*W)
    taps: int = 8                 # FIR order
    tile: tuple = (16, 16)        # (H, W) the frame reshapes to
    q: int = 12                   # sample Q format (|x| <= 2**q)
    n_layers: int = 2             # plan sites = n_layers + 1: fir, conv, gain

    def __post_init__(self):
        H, W = self.tile
        if H * W != self.frame:
            raise ValueError(f"tile {self.tile} does not hold frame="
                             f"{self.frame} samples")

    def site_macs(self) -> list:
        """Per-frame MAC counts per plan site (autotune cost weights):
        T per FIR output sample, 9 per blur pixel, 1 per gain pixel."""
        return [float(self.taps * self.frame), float(9 * self.frame),
                float(self.frame)]

    def site_names(self) -> list:
        return ["fir", "conv2d", "gain"]


class StreamState(NamedTuple):
    """Per-slot stream state (cache_ops layout).

    ``length``: (B,) int32 — frames processed per slot (axis 0 = batch).
    ``tail``:   (1, B, T-1) int32 — FIR history carried across frames
                (leading stack axis, batch at axis 1), so frame-by-frame
                filtering is bit-identical to one whole-signal pass.
    """

    length: jnp.ndarray
    tail: jnp.ndarray


def default_params(cfg: StreamConfig) -> dict:
    """Deterministic reference weights: a Hann low-pass FIR, the classic
    1-2-1 Gaussian blur, and a 0.9 output gain — all quantized to l1-safe
    int32 (``dsp.quantize_weights``)."""
    win = np.hanning(cfg.taps + 2)[1:-1]
    gauss = np.array([[1.0, 2.0, 1.0], [2.0, 4.0, 2.0], [1.0, 2.0, 1.0]])
    return {
        "taps": dsp.quantize_weights(win, cfg.q),           # l1 <= 2**q
        "kern": dsp.quantize_weights(gauss, 8),             # l1 <= 256
        "gain": np.array([[int(round(0.9 * (1 << cfg.q)))]], np.int32),
    }


def psnr_metric(ref, out) -> float:
    """Plan-calibration error metric: negated PSNR (front_mask minimizes
    the error axis, so quality metrics enter negated).  Monotone in MSE and
    finite even for bit-identical outputs (psnr_db floors the MSE)."""
    return -psnr_db(ref, out)


psnr_metric.metric_name = "neg_psnr_db"


class StreamAdapter(ServableModel):
    """ServableModel serving the approximate FIR + conv2d pipeline
    frame-by-frame.  Payloads are (F, frame) int32 clips; every step emits
    one processed frame per active slot."""

    unit = "frames"
    admit_span = "admit"
    step_span = "stream"
    payload_arg = "payload_frames"
    budget_arg = "max_frames"
    first_event = "first_frame"
    admit_site = None             # admission is a slot reset, no fused math
    step_sites = ("fir", "conv2d")

    def __init__(self, cfg: Optional[StreamConfig] = None):
        self.cfg = cfg or StreamConfig()
        # plan machinery hooks: build_plan stamps the policy's default
        # block; the stream pipeline is already integer arithmetic, so the
        # default AXQ spec is just a carrier
        self.policy = ApproxPolicy()
        self._reset = jax.jit(cache_reset_slot)
        # clean pipeline range bound: l1-safe taps/kern quantization and the
        # <1 gain keep |frame| <= 2**q end-to-end, so any high-bit SEU in
        # the tail or an injected activation fault leaves the band — the
        # protocol-default guarded_step with this limit is the stream guard
        self.guard_limit = float(2 << self.cfg.q)

    # ---- weights / slot state ----------------------------------------

    def init_params(self) -> dict:
        return default_params(self.cfg)

    def init_state(self, *, batch: int, max_len: int = 0) -> StreamState:
        T = self.cfg.taps
        return StreamState(length=jnp.zeros((batch,), jnp.int32),
                           tail=jnp.zeros((1, batch, T - 1), jnp.int32))

    def init_feed(self, slots: int):
        return np.zeros((slots, self.cfg.frame), np.int32)

    def reset_slot(self, state, slot):
        return cache_reset_slot(state, slot)

    # ---- request validation ------------------------------------------

    def validate(self, frames):
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        if frames.ndim != 2 or frames.shape[1] != self.cfg.frame:
            raise ValueError(
                f"stream payload must be (F, {self.cfg.frame}) frames, got "
                f"shape {frames.shape}")
        if frames.shape[0] == 0:
            raise ValueError("empty clip")
        lim = 1 << self.cfg.q
        if np.abs(frames).max(initial=0) > lim:
            raise ValueError(
                f"samples exceed the Q{self.cfg.q} range (|x| <= {lim})")
        return frames

    def payload_units(self, frames) -> int:
        return int(frames.shape[0])

    def default_budget(self, frames) -> int:
        return int(frames.shape[0])

    # ---- compute edges ------------------------------------------------

    def admit(self, params, state, feed, slot, req, degree):
        """Admission is pure slot surgery: rewind the state region (zero
        FIR history — the reuse-after-free guarantee) and stage the clip's
        first frame in the feed.  No fused ingest math, so 0 units."""
        state = self._reset(state, jnp.asarray(slot, jnp.int32))
        req.cursor = 1
        feed[slot] = req.payload[0]
        return state, 0

    def step(self, params, state, feed, active, key, degree):
        """ONE fused pipeline step over all slots: FIR -> blur -> gain,
        each site at its own slice of the traced degree vector."""
        cfg = self.cfg
        H, W = cfg.tile
        B = feed.shape[0]
        y, new_tail = kdispatch.fir(
            feed, params["taps"], tail=state.tail[0],
            degree=kdispatch.site_degree(degree, 0), shift=cfg.q)
        img = y.reshape(B, H, W)
        img = kdispatch.conv2d(img, params["kern"],
                               degree=kdispatch.site_degree(degree, 1),
                               shift=8, pad="edge")
        img = kdispatch.conv2d(img, params["gain"],
                               degree=kdispatch.site_degree(degree, 2),
                               shift=cfg.q)
        out = img.reshape(B, cfg.frame)
        new_state = StreamState(length=state.length + 1,
                                tail=new_tail[None])
        return out, cache_mask_update(state, new_state, active)

    def harvest(self, req, feed, slot, emission):
        req.out.append(np.asarray(emission, np.int32))
        if req.cursor < len(req.payload):
            feed[slot] = req.payload[req.cursor]
            req.cursor += 1
            return True, False, {}
        return True, True, {}

    # ---- calibration / quality ---------------------------------------

    def forward(self, params, batch, degree=None, remat="none"):
        """Whole-clip forward for plan calibration (the autotuner's probe
        surface): ``batch["frames"]`` (B, F, frame) int32 -> (B, F, frame)
        f32 in sample units.  A ``lax.scan`` over frames reuses the exact
        per-frame step, so calibration measures the same arithmetic serving
        executes; ``degree=None`` is the exact pipeline (``exact_model``
        returns self)."""
        frames = jnp.asarray(batch["frames"], jnp.int32)
        B, F, L = frames.shape
        active = jnp.ones((B,), bool)

        def body(tail, fr):
            state = StreamState(length=jnp.zeros((B,), jnp.int32), tail=tail)
            out, new_state = self.step(params, state, fr, active, None,
                                       degree)
            return new_state.tail, out

        tail0 = jnp.zeros((1, B, self.cfg.taps - 1), jnp.int32)
        _, ys = jax.lax.scan(body, tail0, frames.transpose(1, 0, 2))
        out = ys.transpose(1, 0, 2).astype(jnp.float32) / (1 << self.cfg.q)
        return out, {}

    def exact_model(self):
        return self

    def quality_tap(self, *, every, registry, tracer):
        """Live per-frame PSNR vs the exact-arithmetic pipeline, bucketed
        in dB (the stream analogue of the LM logit-RMS tap)."""
        from repro.obs.quality import QualityTap

        cfg = self.cfg

        def probe(p, state, feed, active, deg):
            approx, _ = self.step(p, state, feed, active, None, deg)
            exact, _ = self.step(p, state, feed, active, None,
                                 jnp.full_like(deg, 8))
            w = active.astype(jnp.float32)[:, None]
            n = jnp.maximum(jnp.sum(w) * approx.shape[-1], 1.0)
            err = jnp.sum(((approx - exact).astype(jnp.float32) ** 2) * w) / n
            peak = jnp.float32(1 << cfg.q)
            return 10.0 * jnp.log10(peak ** 2
                                    / jnp.maximum(err, peak ** 2 * 1e-18))

        return QualityTap(probe=probe, every=every, registry=registry,
                          tracer=tracer, metric_name="psnr_db",
                          buckets=PSNR_BUCKETS)


class StreamServeEngine(_engine.ServeCore):
    """Stream-workload engine facade: ``ServeCore`` over a
    :class:`StreamAdapter`, with clip-flavored ``submit``."""

    def __init__(self, adapter: Optional[StreamAdapter] = None, params=None,
                 *, slots: int = 4, **kw):
        adapter = adapter or StreamAdapter()
        params = adapter.init_params() if params is None else params
        kw.setdefault("max_len", 0)
        super().__init__(adapter, params, slots=slots, **kw)

    def submit(self, frames, max_frames: Optional[int] = None, **kw):
        """Enqueue one clip; processed frames accumulate in
        ``request.out`` as (frame,) int32 arrays.  Policy keywords
        (``deadline_ms`` / ``ttft_deadline_ms``) pass through."""
        return super().submit(frames, max_frames, **kw)


def make_clip(n_frames: int, frame: int, q: int = 12, seed: int = 0,
              kind: str = "chirp") -> np.ndarray:
    """Deterministic synthetic test clip (benchmarks/examples): a noisy
    chirp ("chirp") or uniform noise ("noise"), Q-``q`` int32 (F, frame)."""
    rng = np.random.default_rng(seed)
    t = np.arange(n_frames * frame, dtype=np.float64)
    if kind == "chirp":
        sig = 0.7 * np.sin(2 * np.pi * t * (0.002 + 1e-7 * t))
        sig = sig + 0.05 * rng.standard_normal(t.size)
    else:
        sig = rng.uniform(-0.9, 0.9, t.size)
    q12 = np.clip(np.round(sig * (1 << q)), -(1 << q), (1 << q))
    return q12.astype(np.int32).reshape(n_frames, frame)
