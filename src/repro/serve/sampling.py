"""Token sampling for the fused serve step.

Greedy argmax or temperature/top-k categorical sampling under an explicit
PRNG key — pure function of (logits, key), so the whole serve step stays a
single compiled executable and runs are reproducible from the engine seed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG_INF = -1e30


def sample_tokens(logits: Array, key, *, greedy: bool,
                  temperature=1.0, top_k: int = 0) -> Array:
    """logits: (B, V) f32 -> (B,) int32 next tokens.

    ``greedy``/``top_k`` are trace-time constants (baked into the compiled
    step); ``temperature`` and ``key`` are traced, so they can move per tick
    without recompilation.  Each batch row draws from its own fold of ``key``
    — co-batched requests never share randomness.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    if top_k and top_k < l.shape[-1]:
        vals, _ = jax.lax.top_k(l, top_k)
        l = jnp.where(l < vals[..., -1:], NEG_INF, l)
    keys = jax.random.split(key, l.shape[0])
    return jax.vmap(jax.random.categorical)(keys, l).astype(jnp.int32)
