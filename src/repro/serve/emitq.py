"""Background detokenize/emit queue (DESIGN.md §15d).

The engine's harvest loop banks raw emissions (token ids, frame indices)
into ``req.out`` — a cheap host append.  Everything downstream of that —
detokenization, delivery to a consumer callback — is Python work that has
no business sitting between two device steps.  :class:`AsyncEmitter` moves
it onto a daemon worker thread: harvest pushes ``(req, item)`` and returns
immediately; the worker detokenizes and appends to ``req.detok`` (and fires
the optional ``on_emit`` callback) in arrival order.

Per-request order is preserved (single worker, FIFO queue).  ``flush()``
blocks until everything pushed so far is delivered — tests and drain paths
call it to make the asynchrony deterministic.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


def default_detok(item) -> str:
    """Stand-in detokenizer: stable printable piece per id (no tokenizer
    dependency in-container; launchers swap in a real one)."""
    return f"<{int(item)}>"


class AsyncEmitter:
    """Single-worker background emit queue.

    push(req, item): enqueue one emission; never blocks the caller.
    flush(): wait until the queue is empty and in-flight work is done.
    close(): flush and stop the worker (idempotent).
    """

    def __init__(self, detok: Optional[Callable] = None,
                 on_emit: Optional[Callable] = None):
        self._detok = detok or default_detok
        self._on_emit = on_emit
        self._q: "queue.Queue" = queue.Queue()
        self._idle = threading.Event()
        self._idle.set()
        self._closed = False
        self.emitted = 0
        self.errors = 0
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="repro-emitq")
        self._worker.start()

    def push(self, req, item) -> None:
        if self._closed:
            raise RuntimeError("emitter closed")
        self._idle.clear()
        self._q.put((req, item))

    def _run(self) -> None:
        while True:
            got = self._q.get()
            if got is None:
                self._q.task_done()
                return
            req, item = got
            try:
                piece = self._detok(item)
                if not hasattr(req, "detok"):
                    req.detok = []
                req.detok.append(piece)
                if self._on_emit is not None:
                    self._on_emit(req, piece)
                self.emitted += 1
            except Exception:   # emit failures must never kill the worker
                self.errors += 1
            finally:
                self._q.task_done()
                if self._q.unfinished_tasks == 0:
                    self._idle.set()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until all pushed emissions are delivered."""
        return self._idle.wait(timeout=timeout)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.flush()
        self._q.put(None)
        self._worker.join(timeout=5.0)
