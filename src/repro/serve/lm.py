"""LM workload adapter: token decode on the generic serve core.

Everything token-specific that used to live inside the engine — sampling
(greedy / top-k, traced temperature), EOS stopping, the prompt-prefix fused
prefill, KV-cache init/reset, prompt-length bounds, the logit-RMS quality
tap — is an :class:`LMAdapter` implementing the
:class:`~repro.serve.servable.ServableModel` protocol.  The historical
:class:`ServeEngine` construction surface (and every attribute the tests,
benches and launchers read: ``cache``, ``eos_id``, ``submit(prompt,
max_new_tokens)``) is a thin facade over
:class:`~repro.serve.engine.ServeCore` — behavior through the adapter is
bit-identical to the pre-protocol engine (same jitted step jaxpr, same
admission arithmetic, same EOS/budget bookkeeping).

  eos_id semantics: ``-1`` (the default) disables EOS stopping — no vocab
  id compares equal.  When set, sampling ``eos_id`` finishes the request;
  the EOS token itself is neither emitted into ``out_tokens`` nor charged
  against ``max_new_tokens``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache_ops import cache_mask_update
from repro.models.registry import Model
from repro.serve import engine as _engine
from repro.serve.admission import AdmissionConfig, bucket_for
from repro.serve.sampling import sample_tokens
from repro.serve.servable import ServableModel


class Request(_engine.Request):
    """Generic request with the historical LM field names as read-only
    views (``prompt``/``out_tokens``/``t_first_token``/...) — existing
    callers and the serve tests read these unchanged."""

    @property
    def prompt(self) -> np.ndarray:
        return self.payload

    @property
    def max_new_tokens(self) -> int:
        return self.budget

    @property
    def out_tokens(self) -> list:
        return self.out

    @property
    def prefill_tokens(self) -> int:
        return self.admitted_units

    @property
    def t_first_token(self) -> float:
        return self.t_first_emit

    @property
    def degree_at_first_token(self) -> Optional[tuple]:
        return self.degree_at_first_emit


class LMAdapter(ServableModel):
    """ServableModel over a :class:`~repro.models.registry.Model`: token
    units, fused-prefill admission, sample-and-feed-back decode steps."""

    unit = "tokens"
    admit_span = "prefill"
    step_span = "decode"
    payload_arg = "prompt_tokens"
    budget_arg = "max_new_tokens"
    first_event = "first_token"
    admit_site = "prefill"
    step_sites = ("decode",)
    request_cls = Request
    #: clean smoke-family logits sit well under this; a high-exponent SEU
    #: or NaN/Inf injection blows past it (resil.guards)
    guard_limit = 1e4

    def __init__(self, model: Model, *, tp: int = 1, eos_id: int = -1,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, max_len: int = 512,
                 admission: Optional[AdmissionConfig] = None):
        self.model = model
        self.cfg = model.cfg
        self.tp = tp
        self.eos_id = eos_id
        cfg = model.cfg
        # prompt-length bound: stateful families ingest unbounded prompts;
        # window caches ring-wrap only while window <= max_len (decode
        # saturates otherwise — attention.py); dense attention is bounded
        # by the cache capacity outright
        window = cfg.local_window if cfg.family == "hybrid" else cfg.swa_window
        if cfg.family == "ssm" or (window is not None and window <= max_len):
            self._max_prompt = None
        else:
            self._max_prompt = max_len
        vocab = cfg.vocab
        #: python-side executable census: each key counts TRACES (the
        #: counter lives inside the staged function body, so it bumps once
        #: per compilation, not per call) — the compile-count regression
        #: tests pin admission to the bucket ladder with this
        self.trace_counts = {"prefill": 0, "prefill_batch": 0,
                             "prefill_chunk": 0, "step": 0}

        def serve_step(p, cache, tokens, active, key, deg):
            self.trace_counts["step"] += 1
            logits, new_cache = model.decode_step(p, cache, tokens, tp=tp,
                                                  degree=deg, active=active)
            # free slots are masked out: length frozen, region unwritten
            new_cache = cache_mask_update(cache, new_cache, active)
            nxt = sample_tokens(logits[:, 0, :vocab], key, greedy=greedy,
                                temperature=temperature, top_k=top_k)
            return nxt, new_cache

        def guarded_serve_step(p, cache, tokens, active, key, deg, fault):
            # guard the *logits*, pre-sampling: the injection point is the
            # model's output activation (dispatch.inject_fault), the check
            # runs where corruption is still observable (sampling collapses
            # a poisoned distribution to a plausible-looking token id)
            from repro.kernels import dispatch as kdispatch
            from repro.resil import guards

            self.trace_counts["step"] += 1
            logits, new_cache = model.decode_step(p, cache, tokens, tp=tp,
                                                  degree=deg, active=active)
            new_cache = cache_mask_update(cache, new_cache, active)
            lv = kdispatch.inject_fault(logits[:, 0, :vocab], fault)
            ok = guards.slot_ok(lv, limit=self.guard_limit)
            # sampling must stay defined on quarantined slots (their token
            # is discarded, but NaN would poison the whole fused gather)
            safe = jnp.where(jnp.isfinite(lv), lv, 0.0)
            nxt = sample_tokens(safe, key, greedy=greedy,
                                temperature=temperature, top_k=top_k)
            return nxt, new_cache, ok

        self._serve_step = serve_step
        self._guarded_serve_step = guarded_serve_step

        def _prefill_impl(p, c, t, s, deg):
            self.trace_counts["prefill"] += 1
            return model.prefill(p, c, t, s, tp=tp, degree=deg)

        self._prefill = jax.jit(_prefill_impl)
        self._reset = jax.jit(model.reset_slot)

        # ---- bucketed/packed/chunked admission (DESIGN.md §15) --------
        self.admission = admission.resolved(max_len) if admission else None
        if self.admission is not None and getattr(cfg, "moe", None):
            # MoE capacity routing couples tokens ACROSS packed rows (the
            # per-expert capacity is computed over the whole call), so a
            # bucketed/packed prefill would not be bit-identical to
            # sequential admission — MoE keeps the exact-length path
            self.admission = None
        self._chunk_ok = False
        if self.admission is not None:
            import os

            def _prefill_batch_impl(p, c, t, s, ln, deg):
                self.trace_counts["prefill_batch"] += 1
                return model.prefill_batch(p, c, t, s, ln, tp=tp, degree=deg)

            self._prefill_batch = jax.jit(_prefill_batch_impl)
            self._chunk_ok = (self.admission.chunk_tokens > 0
                              and model.supports_chunked_prefill()
                              and os.environ.get("REPRO_KV_INT8", "0") != "1")
            if self._chunk_ok:
                def _prefill_chunk_impl(p, c, t, s, off, n, deg):
                    self.trace_counts["prefill_chunk"] += 1
                    return model.prefill_chunk(p, c, t, s, off, n, tp=tp,
                                               degree=deg)

                self._prefill_chunk = jax.jit(_prefill_chunk_impl)

    # ---- weights / slot state ----------------------------------------

    def prepack(self, params):
        return self.model.prepack(params)

    def init_state(self, *, batch: int, max_len: int):
        return self.model.init_cache(tp=self.tp, batch=batch,
                                     max_len=max_len)

    def init_feed(self, slots: int):
        # per-slot next-token feed for the fused decode step
        return np.zeros((slots, 1), np.int32)

    def reset_slot(self, state, slot):
        return self.model.reset_slot(state, slot)

    # ---- request validation ------------------------------------------

    def validate(self, prompt):
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if self._max_prompt is not None and prompt.size > self._max_prompt:
            raise ValueError(
                f"prompt length {prompt.size} exceeds cache capacity "
                f"{self._max_prompt} (max_len)")
        return prompt

    def payload_units(self, prompt) -> int:
        return int(prompt.size)

    def default_budget(self, prompt) -> int:
        return 32

    # ---- compute edges ------------------------------------------------

    def admit(self, params, cache, feed, slot, req, degree):
        """Ingest the prompt prefix with one fused prefill call; the final
        prompt token rides the next fused decode step (it produces the
        first generated token)."""
        prompt = req.payload
        sl = jnp.asarray(slot, jnp.int32)
        if prompt.size > 1:
            _, cache = self._prefill(params, cache, jnp.asarray(prompt[:-1]),
                                     sl, degree)
            ingested = int(prompt.size) - 1
        else:
            cache = self._reset(cache, sl)
            ingested = 0
        feed[slot, 0] = int(prompt[-1])
        req.cursor = ingested
        return cache, ingested

    # ---- bucketed / packed / chunked admission ------------------------

    def admit_batch(self, params, cache, feed, pairs, degree):
        """Pack up to ``admission.pack`` prompt prefixes into ONE bucketed
        prefill call.  Calls are padded to exactly ``pack`` rows with
        dummies (slot = B, dropped out-of-bounds), so the executable set is
        one per bucket.  Prefixes longer than the largest bucket (unbounded
        window/SSM ingest) fall back to the exact-length path."""
        a = self.admission
        if a is None:
            return super().admit_batch(params, cache, feed, pairs, degree)
        B = feed.shape[0]
        ingested = {}
        bucketed = []
        for slot, req in pairs:
            n = req.payload_units - 1
            if n > a.buckets[-1]:
                cache, ingested[id(req)] = self.admit(params, cache, feed,
                                                      slot, req, degree)
            else:
                bucketed.append((slot, req))
        for i in range(0, len(bucketed), a.pack):
            group = bucketed[i:i + a.pack]
            lens = [r.payload_units - 1 for _, r in group]
            Pb = bucket_for(max(lens + [1]), a.buckets)
            toks = np.zeros((a.pack, Pb), np.int32)
            slots = np.full((a.pack,), B, np.int32)
            lengths = np.zeros((a.pack,), np.int32)
            for row, ((slot, req), n) in enumerate(zip(group, lens)):
                toks[row, :n] = req.payload[:-1]
                slots[row] = slot
                lengths[row] = n
                feed[slot, 0] = int(req.payload[-1])
                req.cursor = n
                ingested[id(req)] = n
            cache = self._prefill_batch(params, cache, jnp.asarray(toks),
                                        jnp.asarray(slots),
                                        jnp.asarray(lengths), degree)
            self.last_admit_bucket = Pb
        return cache, [ingested[id(r)] for _, r in pairs]

    def admit_chunk(self, params, cache, feed, slot, req, degree):
        """Advance one ``chunk_tokens`` chunk of ``req``'s prompt prefix;
        ``req.cursor`` carries progress (quarantine/rewind zero it).  The
        final prompt token rides the decode feed once the prefix lands."""
        a = self.admission
        C = a.chunk_tokens
        prompt = req.payload
        target = prompt.size - 1
        sl = jnp.asarray(slot, jnp.int32)
        if req.cursor == 0:
            cache = self._reset(cache, sl)
        take = min(C, target - req.cursor)
        toks = np.zeros((C,), np.int32)
        toks[:take] = prompt[req.cursor:req.cursor + take]
        cache = self._prefill_chunk(params, cache, jnp.asarray(toks), sl,
                                    jnp.asarray(req.cursor, jnp.int32),
                                    jnp.asarray(take, jnp.int32), degree)
        req.cursor += take
        if req.cursor >= target:
            feed[slot, 0] = int(prompt[-1])
        return cache, take

    def admit_complete(self, req) -> bool:
        if self.admission is None:
            return True
        return req.cursor >= max(req.payload_units - 1, 0)

    def wants_chunked(self, req) -> bool:
        return (self._chunk_ok
                and req.payload_units - 1 > self.admission.chunk_tokens)

    def admit_calls(self, req) -> int:
        n = req.payload_units - 1
        if self.admission is not None and self.wants_chunked(req):
            return -(-n // self.admission.chunk_tokens)
        return 1

    def warmup_admission(self, params, cache, feed, degree) -> None:
        """Trace one executable per bucket (+ the chunk and slot-reset
        executables) with all-dummy rows: slot = B scatters are dropped, so
        the live state is untouched and the results are discarded."""
        a = self.admission
        if a is None:
            return
        B = feed.shape[0]
        dummy = jnp.asarray(B, jnp.int32)
        for Pb in a.buckets:
            out = self._prefill_batch(
                params, cache, jnp.zeros((a.pack, Pb), jnp.int32),
                jnp.full((a.pack,), B, jnp.int32),
                jnp.zeros((a.pack,), jnp.int32), degree)
            jax.block_until_ready(out)
        if self._chunk_ok:
            zero = jnp.asarray(0, jnp.int32)
            out = self._prefill_chunk(
                params, cache, jnp.zeros((a.chunk_tokens,), jnp.int32),
                dummy, zero, zero, degree)
            jax.block_until_ready(out)
        jax.block_until_ready(self._reset(cache, dummy))

    def step(self, params, cache, feed, active, key, degree):
        return self._serve_step(params, cache, feed, active, key, degree)

    def guarded_step(self, params, cache, feed, active, key, degree, fault):
        return self._guarded_serve_step(params, cache, feed, active, key,
                                        degree, fault)

    def harvest(self, req, feed, slot, emission):
        tok = int(emission)
        if self.eos_id >= 0 and tok == self.eos_id:
            return False, True, {"eos": True}
        req.out.append(tok)
        feed[slot, 0] = tok
        return True, False, {"eos": False}

    def done_args(self, req, info) -> dict:
        return {"eos": bool(info.get("eos", False)),
                "tokens": len(req.out)}

    # ---- quality ------------------------------------------------------

    def quality_tap(self, *, every, registry, tracer):
        from repro.obs.quality import QualityTap

        return QualityTap(self.model, tp=self.tp, every=every,
                          registry=registry, tracer=tracer)


class ServeEngine(_engine.ServeCore):
    """The historical LM serving engine: ``ServeCore`` specialized with an
    :class:`LMAdapter` — constructor signature, attribute surface
    (``cache``, ``_tokens``, sampling knobs) and behavior identical to the
    pre-protocol engine."""

    def __init__(self, model: Model, params, *, slots: int = 8,
                 max_len: int = 512, eos_id: int = -1, tp: int = 1,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0, qos=None, degree=None,
                 prepack: bool = True, plan=None, registry=None,
                 tracer=None, quality_every: int = 0,
                 admission: Optional[AdmissionConfig] = None, **resil_kw):
        workload = LMAdapter(model, tp=tp, eos_id=eos_id, greedy=greedy,
                             temperature=temperature, top_k=top_k,
                             max_len=max_len, admission=admission)
        super().__init__(workload, params, slots=slots, max_len=max_len,
                         seed=seed, qos=qos, degree=degree, prepack=prepack,
                         plan=plan, registry=registry, tracer=tracer,
                         quality_every=quality_every, **resil_kw)
        self.model = model
        self.eos_id = eos_id
        self.tp = tp
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k

    # historical attribute views over the generic core state
    @property
    def cache(self):
        return self.state

    @cache.setter
    def cache(self, value):
        self.state = value

    @property
    def _tokens(self):
        return self._feed

    def submit(self, prompt, max_new_tokens: int = 32, **kw) -> Request:
        """Enqueue one request (FIFO).  Returns the live Request — tokens
        appear in ``request.out_tokens`` as ticks generate them."""
        return super().submit(prompt, max_new_tokens, **kw)
