"""ServableModel: the workload protocol the serve engine is generic over.

The engine (``serve/engine.py::ServeCore``) owns the *scheduling* machinery
— FIFO queue, fixed slot batch, free-slot masking, the QoS degree ladder,
tracing/metrics — and knows nothing about what flows through the slots.
Everything workload-specific (what a unit of work is, how a payload is
ingested into a slot, what one fused step computes, when a request
finishes) lives behind this protocol.  Two production workloads implement
it: the LM adapter (``serve/lm.py`` — sampling, EOS, KV caches) and the
streaming DSP/vision pipeline (``serve/stream.py`` — approximate FIR +
conv2d frames, Ch. 7 accelerators).

State contract: ``init_state`` returns a NamedTuple following the cache
layout convention of ``models/cache_ops.py`` — a ``length`` field of shape
(batch,) with batch at axis 0, every other field with batch at axis 1
(leading stack axis) — so the generic ``cache_reset_slot`` /
``cache_mask_update`` helpers apply unchanged, and a freed slot handed to
the next request is bit-identical to a fresh engine (the engine's
reuse-after-free guarantee holds per workload for free).

Vocabulary contract: the engine's trace events and summaries must speak the
workload's language ("prefill"/"first_token" for LMs, "admit"/"first_frame"
for streams), so the *names* are protocol attributes too — the engine never
hardcodes them.

Degree contract: ``admit``/``step`` receive the engine's traced degree
operand (None | scalar | per-site vector — models/degrees.py) and must
keep it traced (slice with ``dispatch.site_degree``, never ``int()``), so
QoS ladder moves stay zero-recompile for every workload.
"""

from __future__ import annotations

from typing import Optional


class ServableModel:
    """Base/protocol for engine workloads.  Subclasses override everything
    marked NotImplementedError; the attribute defaults are generic labels a
    workload usually re-brands."""

    # ---- vocabulary: how the engine narrates this workload ------------
    #: what one emitted unit is called (metric family names, summaries)
    unit: str = "items"
    #: trace-span name for slot admission/ingest
    admit_span: str = "admit"
    #: enqueue/admit trace arg naming the payload size
    payload_arg: str = "payload_items"
    #: enqueue trace arg naming the emission budget
    budget_arg: str = "budget"
    #: trace-event name for a request's first emission
    first_event: str = "first_emit"
    #: step vocabulary stem: the engine's tick span is "{step_span}_tick"
    #: and the step counter families are "repro_{step_span}_*"
    step_span: str = "step"
    #: Request subclass the engine constructs on submit (workloads may
    #: attach named read-only views of the generic fields)
    request_cls = None  # resolved to serve.engine.Request when None
    #: dispatch call-site counted per admission ingest (None = uncounted)
    admit_site: Optional[str] = "admit"
    #: dispatch call-sites counted per fused step
    step_sites: tuple = ()

    #: the underlying arch config (plan validation / degree site names);
    #: must expose ``name`` and ``n_layers`` at minimum
    cfg = None

    #: per-slot magnitude bound for the guarded step's sanity check (None =
    #: finite-only); workloads set the bound the clean pipeline can never
    #: leave (LM: a logit limit; stream: the Q-format range)
    guard_limit: Optional[float] = None

    #: admission pipeline config (serve/admission.py) — None keeps the
    #: legacy exact-length one-request-at-a-time admission path; the engine
    #: reads this to drive bucketed/packed/chunked admission
    admission = None

    # ---- weights ------------------------------------------------------
    def prepack(self, params):
        """Quantize-once residency hook (DESIGN.md §9); identity by default."""
        return params

    # ---- slot state ---------------------------------------------------
    def init_state(self, *, batch: int, max_len: int):
        """Fresh per-slot stream state: a NamedTuple on the cache_ops layout
        (``length`` (batch,) at axis 0; other fields batch at axis 1)."""
        raise NotImplementedError

    def init_feed(self, slots: int):
        """Host-side (slots, ...) array the engine hands each fused step —
        the per-slot step input (next LM id, next stream frame)."""
        raise NotImplementedError

    def reset_slot(self, state, slot):
        """Rewind one slot's state region (jitted by the engine)."""
        raise NotImplementedError

    # ---- request validation ------------------------------------------
    def validate(self, payload):
        """Canonicalize a submitted payload (or raise ValueError at submit
        time — rejecting mid-tick would lose the request)."""
        raise NotImplementedError

    def payload_units(self, payload) -> int:
        """Payload size in this workload's units (trace/summary label)."""
        raise NotImplementedError

    def default_budget(self, payload) -> int:
        """Emission budget when the caller doesn't pass one."""
        raise NotImplementedError

    # ---- the three compute edges -------------------------------------
    def admit(self, params, state, feed, slot: int, req, degree):
        """Ingest ``req.payload`` into ``slot``: reset the slot region,
        consume any prefix that rides a fused ingest call, and write the
        first step input into ``feed``.  Returns ``(state, ingested)`` —
        ``ingested`` units count toward the admission counters (0 when the
        payload rides the step feed only)."""
        raise NotImplementedError

    # ---- budgeted admission (pipeline edge, DESIGN.md §15) ------------
    # The engine only calls these when :attr:`admission` is set; the
    # defaults preserve legacy single-call semantics so workloads opt in
    # incrementally.

    def admit_batch(self, params, state, feed, pairs, degree):
        """Admit several requests in one device call: ``pairs`` is a list of
        ``(slot, req)``.  Returns ``(state, ingested_list)``.  Default:
        sequential :meth:`admit` calls (no packing win, same semantics)."""
        ingested = []
        for slot, req in pairs:
            state, n = self.admit(params, state, feed, slot, req, degree)
            ingested.append(n)
        return state, ingested

    def admit_chunk(self, params, state, feed, slot: int, req, degree):
        """Advance one chunk of ``req``'s admission into ``slot`` (progress
        carried in ``req.cursor``; the engine's rewind path resets it).
        Returns ``(state, ingested)``."""
        raise NotImplementedError(f"{type(self).__name__} cannot chunk")

    def admit_complete(self, req) -> bool:
        """Whether ``req``'s payload is fully ingested — a slot only joins
        the fused decode batch once this holds."""
        return True

    def wants_chunked(self, req) -> bool:
        """Whether this request should admit via :meth:`admit_chunk`."""
        return False

    def admit_calls(self, req) -> int:
        """Device calls needed to admit ``req`` (doomed-shed estimate in
        resil.policy: calls x admit_eta_ms vs remaining TTFT budget)."""
        return 1

    def warmup_admission(self, params, state, feed, degree) -> None:
        """Trace every admission executable (bucket ladder, chunk size) with
        dummy rows so no request compiles after startup.  Must not mutate
        ``state``/``feed`` observably.  Default: nothing to warm."""

    def step(self, params, state, feed, active, key, degree):
        """ONE fused step over all slots (the engine jits this once):
        ``(emission, new_state)`` where emission is a (slots, ...) batch.
        Free slots must be masked via ``cache_mask_update`` so their state
        never advances."""
        raise NotImplementedError

    def guarded_step(self, params, state, feed, active, key, degree, fault):
        """Fault-aware twin of :meth:`step` (repro.resil, DESIGN.md §13):
        same contract plus a traced per-slot ``fault`` operand — a (slots,)
        float32 vector, 0.0 = clean, NaN/Inf = corrupt that slot's
        activations via ``dispatch.inject_fault`` — and a third output:
        per-slot ``ok`` bools from the jit-safe guard check
        (``resil.guards.slot_ok`` against :attr:`guard_limit`).  The engine
        never banks an emission whose ok bit is False; it quarantines the
        slot instead.  This default wraps :meth:`step` (inject + check on
        the emission); workloads override to place the injection/guard
        inside the pipeline (the LM adapter guards logits pre-sampling)."""
        from repro.kernels import dispatch as kdispatch
        from repro.resil import guards

        emission, new_state = self.step(params, state, feed, active, key,
                                        degree)
        emission = kdispatch.inject_fault(emission, fault)
        return emission, new_state, guards.slot_ok(emission,
                                                   limit=self.guard_limit)

    def harvest(self, req, feed, slot: int, emission):
        """Bank one slot's step emission into ``req.out`` and advance its
        feed.  Returns ``(emitted, finished, info)``: ``emitted`` False
        drops the emission (e.g. LM EOS — neither banked nor charged);
        ``finished`` ends the request regardless of remaining budget;
        ``info`` feeds :meth:`done_args`."""
        raise NotImplementedError

    def done_args(self, req, info: dict) -> dict:
        """Trace args for the request_done event (workload vocabulary)."""
        return {self.unit: len(req.out), **info}

    # ---- quality / calibration hooks ---------------------------------
    def quality_tap(self, *, every: int, registry, tracer):
        """Build the live-vs-exact quality sampler (obs/quality.py) for
        ``quality_every=N``; workloads without one raise."""
        raise NotImplementedError(
            f"{type(self).__name__} has no quality tap")

    def exact_model(self):
        """An exact-arithmetic twin for calibration references
        (tune.autotune probes); self if ``degree=None`` already means exact."""
        return self
