"""Serving QoS accounting: per-request latency breakdown + engine counters.

MLPerf-style definitions:
  queue_time  enqueue -> admission into a slot
  ttft        enqueue -> first generated token (includes queueing + prefill)
  tpot        mean inter-token time after the first token
  e2e         enqueue -> completion

Token accounting is split prefill-vs-decode: prompt tokens are ingested by
the fused prefill call (plus the final prompt token, which rides the decode
step that emits the first output token); generated tokens are decode tokens.

Counters live in a :class:`repro.obs.metrics.Registry` (DESIGN.md §11):
:class:`EngineStats` is a thin view over one — the legacy attribute reads
(``stats.prefill_tokens`` etc.) keep working, while the same numbers export
as Prometheus text / JSON through ``stats.registry``.  ``degree_history``
entries are normalized to ``(tick, degrees_tuple)`` at record time
(``core.dynamic.degree_record(as_tuple=True)``): a global scalar degree
records as a 1-tuple, so consumers never isinstance-branch.
"""

from __future__ import annotations

from collections import deque

from repro.core.dynamic import degree_record
from repro.obs import metrics as obs_metrics

#: latency histogram buckets (seconds) shared by the TTFT/TPOT/queue/e2e
#: families — smoke-scale CPU serving sits in the low milliseconds, TPU
#: decode in the sub-millisecond rungs
LATENCY_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 0.01, 0.025,
                   0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class EngineStats:
    """Engine-lifetime counters (all ticks / admissions), registry-backed.

    Every counter the engine maintains is a family in ``self.registry``
    (a fresh per-engine :class:`~repro.obs.metrics.Registry` by default,
    so co-resident engines don't sum into each other; pass a shared one
    to co-export with the kernel-dispatch counters).  The legacy scalar
    attributes are read-only properties over the registry.
    """

    def __init__(self, registry: obs_metrics.Registry | None = None, *,
                 unit: str = "tokens", admit_name: str = "prefill",
                 step_name: str = "decode"):
        self.registry = (registry if registry is not None
                         else obs_metrics.Registry())
        # workload vocabulary (servable.py): the LM defaults reproduce the
        # historical family names (repro_prefill_tokens_total, ...) exactly;
        # a stream engine exports repro_admit_frames_total etc.
        self.unit = unit
        r = self.registry
        self.c_admit_units = r.counter(
            f"repro_{admit_name}_{unit}_total",
            f"payload {unit} ingested via the fused {admit_name} call")
        self.c_admit_calls = r.counter(
            f"repro_{admit_name}_calls_total",
            f"fused {admit_name} invocations")
        self.c_step_units = r.counter(
            f"repro_{step_name}_{unit}_total",
            "active slot-steps executed by the fused step")
        self.c_steps = r.counter(
            f"repro_{step_name}_steps_total",
            "engine ticks that ran the fused step")
        # legacy LM-named aliases (same counter objects; tests/benches read
        # these regardless of workload)
        self.c_prefill_tokens = self.c_admit_units
        self.c_prefill_calls = self.c_admit_calls
        self.c_decode_tokens = self.c_step_units
        self.c_decode_steps = self.c_steps
        self.c_admitted = r.counter(
            "repro_requests_admitted_total", "requests admitted into a slot")
        self.c_completed = r.counter(
            "repro_requests_completed_total", "requests finished (EOS/budget)")
        self.c_route_steps = r.counter(
            "repro_kernel_route_steps_total",
            "engine ticks by resolved kernel backend", labels=("site", "backend"))
        self.h_ttft = r.histogram(
            "repro_ttft_seconds", "enqueue -> first generated token",
            buckets=LATENCY_BUCKETS)
        self.h_tpot = r.histogram(
            "repro_tpot_seconds", "mean inter-token time after the first",
            buckets=LATENCY_BUCKETS)
        self.h_queue = r.histogram(
            "repro_queue_seconds", "enqueue -> admission into a slot",
            buckets=LATENCY_BUCKETS)
        self.h_e2e = r.histogram(
            "repro_e2e_seconds", "enqueue -> completion",
            buckets=LATENCY_BUCKETS)
        self.g_degree = r.gauge(
            "repro_degree_ebits", "live approximation degree by plan site",
            labels=("site",))
        # resilience families (repro.resil, DESIGN.md §13); zero-valued and
        # free unless the engine runs with faults/guards/policy enabled
        self.c_faults = r.counter(
            "repro_faults_injected_total",
            "faults injected by the engine's FaultPlan", labels=("kind",))
        self.c_guard_trips = r.counter(
            "repro_guard_trips_total",
            "runtime guard trips (slot quarantine or quality sentinel)",
            labels=("reason",))
        self.c_retries = r.counter(
            "repro_retries_total", "guard-tripped requests requeued")
        self.c_failed = r.counter(
            "repro_requests_failed_total", "requests failed (retries spent)")
        self.c_shed = r.counter(
            "repro_requests_shed_total",
            "requests shed by backpressure", labels=("reason",))
        self.c_deadline_miss = r.counter(
            "repro_deadline_miss_total",
            "requests terminated past their deadline", labels=("edge",))
        self.c_brownout = r.counter(
            "repro_brownout_total",
            "forced QoS rung degradations under overload")
        self.c_scrubs = r.counter(
            "repro_param_scrubs_total", "golden parameter restores")
        self.c_dropped_ticks = r.counter(
            "repro_dropped_ticks_total", "fused steps skipped by drop faults")
        # admission pipeline families (DESIGN.md §15); zero-valued unless
        # the engine runs with an AdmissionConfig
        self.c_warmups = r.counter(
            "repro_admission_warmups_total",
            "AOT warmup passes over the admission + step executables")
        self.c_admit_bucket = r.counter(
            "repro_prefill_bucket_total",
            "bucketed prefill flushes by padded length", labels=("bucket",))
        self.c_packed_rows = r.counter(
            "repro_packed_rows_total",
            "prompt rows admitted via multi-row packed prefill calls")
        self.c_chunk_calls = r.counter(
            "repro_prefill_chunk_calls_total",
            "chunked prefill device calls (long-prompt admission)")
        # recent (tick, degrees_tuple) trace — ALWAYS a tuple (a global
        # scalar records as a 1-tuple); bounded so long engines don't leak
        self.degree_history: deque = deque(maxlen=512)

    # ---- legacy scalar reads (tests, benches, summarize) -------------

    @property
    def prefill_tokens(self) -> int:
        return int(self.c_prefill_tokens.value)

    @property
    def prefill_calls(self) -> int:
        return int(self.c_prefill_calls.value)

    @property
    def decode_tokens(self) -> int:
        return int(self.c_decode_tokens.value)

    @property
    def decode_steps(self) -> int:
        return int(self.c_decode_steps.value)

    @property
    def admitted(self) -> int:
        return int(self.c_admitted.value)

    # ---- recording ---------------------------------------------------

    def record_degree(self, tick: int, degree, site_names=None) -> tuple:
        """Append a tuple-normalized degree to the history and refresh the
        ``repro_degree_ebits{site=..}`` gauge family.  ``site_names`` maps
        vector positions to plan site names (``layer_i`` / ``head``); a
        1-entry record without names exports as ``site="global"``."""
        rec = degree_record(degree, as_tuple=True)
        self.degree_history.append((tick, rec))
        if site_names is not None and len(site_names) == len(rec):
            for name, e in zip(site_names, rec):
                self.g_degree.labels(site=name).set(e)
        elif len(rec) == 1:
            self.g_degree.labels(site="global").set(rec[0])
        else:
            for i, e in enumerate(rec):
                self.g_degree.labels(site=f"site_{i}").set(e)
        return rec

    def record_completion(self, req) -> None:
        """Observe one finished request into the latency histograms.
        Reads the LM-named request fields with a fallback to the generic
        ServeCore names, so both workloads (and legacy request shims)
        observe identically."""
        self.c_completed.inc()
        self.h_queue.observe(req.queue_time)
        self.h_e2e.observe(req.e2e)
        if _rget(req, "t_first_token", "t_first_emit") > 0:
            self.h_ttft.observe(req.ttft)
        if len(_rget(req, "out_tokens", "out")) > 1:
            self.h_tpot.observe(req.tpot)


def _rget(req, *names, default=None):
    """Read the first present attribute: LM-era name first (the serve tests
    pin request shims carrying only those), generic ServeCore name second."""
    for name in names:
        val = getattr(req, name, None)
        if val is not None:
            return val
    return default


def _units(req) -> int:
    """Payload size in workload units: the generic Request carries it
    (``payload_units``); legacy request shims fall back to the prompt."""
    u = _rget(req, "payload_units")
    if u is not None:
        return int(u)
    p = _rget(req, "prompt", "payload")
    return int(p.size) if p is not None else 0


def _pct(xs, q: float) -> float:
    """Linearly-interpolated percentile (inclusive / numpy ``linear``
    method) — the nearest-rank rounding it replaces put p95 on an observed
    sample, which over-reported tails at small n."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(done, stats: EngineStats | None = None,
              wall_s: float | None = None) -> dict:
    """Aggregate finished requests into a flat metrics dict (ms units).
    Key names keep the LM-era vocabulary ("generated_tokens", ...) for
    stability; request fields are read LM-name-first with generic-name
    fallback (``_rget``), so stream-workload requests summarize too."""
    outs = [_rget(r, "out_tokens", "out") for r in done]
    ttft = [r.ttft for r in done
            if _rget(r, "t_first_token", "t_first_emit") > 0]
    tpot = [r.tpot for r, o in zip(done, outs) if len(o) > 1]
    queue = [r.queue_time for r in done]
    e2e = [r.e2e for r in done]
    gen = sum(len(o) for o in outs)
    out = {
        "requests": len(done),
        "generated_tokens": gen,
        "prompt_tokens": sum(_units(r) for r in done),
        "ttft_p50_ms": round(_pct(ttft, 0.50) * 1e3, 2),
        "ttft_p95_ms": round(_pct(ttft, 0.95) * 1e3, 2),
        "ttft_p99_ms": round(_pct(ttft, 0.99) * 1e3, 2),
        "tpot_p50_ms": round(_pct(tpot, 0.50) * 1e3, 2),
        "tpot_p95_ms": round(_pct(tpot, 0.95) * 1e3, 2),
        "queue_p50_ms": round(_pct(queue, 0.50) * 1e3, 2),
        "queue_p95_ms": round(_pct(queue, 0.95) * 1e3, 2),
        "e2e_p50_ms": round(_pct(e2e, 0.50) * 1e3, 2),
        "e2e_p95_ms": round(_pct(e2e, 0.95) * 1e3, 2),
    }
    # which degree served each request's FIRST token: a mid-run rung change
    # is visible here even when every request finishes on the final rung
    first_deg: dict = {}
    for r in done:
        d = _rget(r, "degree_at_first_token", "degree_at_first_emit")
        if d is not None:
            key = ".".join(str(x) for x in d)
            first_deg[key] = first_deg.get(key, 0) + 1
    if first_deg:
        out["degree_at_first_token"] = dict(sorted(first_deg.items()))
    # terminal status partition (resil policies): only surfaced when some
    # request ended non-ok, so legacy summaries are byte-identical
    statuses: dict = {}
    for r in done:
        st = getattr(r, "status", "ok")
        statuses[st] = statuses.get(st, 0) + 1
    if set(statuses) - {"ok"}:
        out["request_status"] = dict(sorted(statuses.items()))
    if wall_s is not None and wall_s > 0:
        out["gen_tok_per_s"] = round(gen / wall_s, 1)
    if stats is not None:
        out["engine_prefill_tokens"] = stats.prefill_tokens
        out["engine_prefill_calls"] = stats.prefill_calls
        out["engine_decode_tokens"] = stats.decode_tokens
        out["engine_decode_steps"] = stats.decode_steps
        if stats.degree_history:
            # entries are tuple-normalized at record time: a global ladder
            # records 1-tuples, a plan ladder the rung's per-site tuple
            out["degree_final_ebits"] = list(stats.degree_history[-1][1])
    return out
