"""Serving QoS accounting: per-request latency breakdown + engine counters.

MLPerf-style definitions:
  queue_time  enqueue -> admission into a slot
  ttft        enqueue -> first generated token (includes queueing + prefill)
  tpot        mean inter-token time after the first token
  e2e         enqueue -> completion

Token accounting is split prefill-vs-decode: prompt tokens are ingested by
the fused prefill call (plus the final prompt token, which rides the decode
step that emits the first output token); generated tokens are decode tokens.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class EngineStats:
    """Engine-lifetime counters (all ticks / admissions)."""

    prefill_tokens: int = 0     # prompt tokens ingested via fused prefill
    prefill_calls: int = 0      # fused prefill invocations (== admissions P>1)
    decode_tokens: int = 0      # slot-steps executed by the fused decode step
    decode_steps: int = 0       # engine ticks that ran the fused step
    admitted: int = 0           # requests admitted into a slot
    # recent (tick, degree) trace — degree is a global ebits int or, under
    # an ApproxPlan ladder, the per-layer degrees tuple of the active rung;
    # bounded so long-lived engines don't leak
    degree_history: deque = field(default_factory=lambda: deque(maxlen=512))


def _pct(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def summarize(done, stats: EngineStats | None = None,
              wall_s: float | None = None) -> dict:
    """Aggregate finished requests into a flat metrics dict (ms units)."""
    ttft = [r.ttft for r in done if r.t_first_token > 0]
    tpot = [r.tpot for r in done if len(r.out_tokens) > 1]
    queue = [r.queue_time for r in done]
    gen = sum(len(r.out_tokens) for r in done)
    out = {
        "requests": len(done),
        "generated_tokens": gen,
        "prompt_tokens": sum(int(r.prompt.size) for r in done),
        "ttft_p50_ms": round(_pct(ttft, 0.50) * 1e3, 2),
        "ttft_p95_ms": round(_pct(ttft, 0.95) * 1e3, 2),
        "tpot_p50_ms": round(_pct(tpot, 0.50) * 1e3, 2),
        "tpot_p95_ms": round(_pct(tpot, 0.95) * 1e3, 2),
        "queue_p50_ms": round(_pct(queue, 0.50) * 1e3, 2),
        "queue_p95_ms": round(_pct(queue, 0.95) * 1e3, 2),
    }
    if wall_s is not None and wall_s > 0:
        out["gen_tok_per_s"] = round(gen / wall_s, 1)
    if stats is not None:
        out["engine_prefill_tokens"] = stats.prefill_tokens
        out["engine_prefill_calls"] = stats.prefill_calls
        out["engine_decode_tokens"] = stats.decode_tokens
        out["engine_decode_steps"] = stats.decode_steps
        if stats.degree_history:
            final = stats.degree_history[-1][1]
            # global ladder: an int; plan ladder: the rung's per-layer tuple
            out["degree_final_ebits"] = (
                list(final) if isinstance(final, (tuple, list)) else final)
    return out
