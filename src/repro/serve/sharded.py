"""Tensor-parallel serving: the serve core on the dist mesh (DESIGN.md §14).

``repro.dist`` and ``repro.serve`` meet here.  :class:`ShardedServeCore`
runs the workload-generic engine with its parameters and decode state
partitioned over a :class:`jax.sharding.Mesh` through the existing
name-pattern rules (``dist/sharding.py``): column/row-parallel projections
over the ``"model"`` axis, slot batch over the data axes.  Everything else
— slot lifecycle, QoS ladder, guards/policy, tracing — is inherited
unchanged from :class:`~repro.serve.engine.ServeCore`; the single fused
step still compiles exactly once per mesh configuration (GSPMD partitions
it), so rung walks and fault operands cause zero recompiles on the sharded
step just as on a single device.

Two collective regimes on the decode critical path:

  * ``ring=False`` (default) — GSPMD inserts exact f32 all-reduces for the
    row-parallel projections: sharded decode is bit-identical to the same
    params served on one device (greedy token streams match exactly).
  * ``ring=True`` — the int8 ppermute ring all-reduce from
    ``dist.collectives`` replaces those reductions (``kernels/ops.py``
    ring-TP lever, scoped per engine via :func:`repro.kernels.ops.ring_tp`):
    ~4x fewer wire bytes at <2% reduction error — the dissertation's
    approximation philosophy applied to the interconnect.

:func:`lm_decode_collective_bytes` lowers one decode step and measures its
collective bytes from the compiled HLO (``dist/hlo_analysis.py``) — the
budget assertion ``bench_elastic`` and the dist-serve tests pin.

Host-CPU dry-runs: export ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
before importing jax (the PR-1 compat shim pins the cpu platform).
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist import meshctx, sharding
from repro.kernels import ops as kops
from repro.serve.engine import ServeCore
from repro.serve.lm import LMAdapter, Request


def _model_axis(mesh) -> int:
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))["model"])


class ShardedServeCore(ServeCore):
    """:class:`~repro.serve.engine.ServeCore` with params/state partitioned
    over ``mesh`` and every trace/compile scoped to it.

    ``mesh`` must carry a ``"model"`` axis (``meshctx.make_mesh``); the
    slot batch shards over the remaining axes, so ``slots`` must divide by
    the product of the data axes.  ``ring=True`` routes the tensor-parallel
    output reductions through the int8 ring all-reduce (no-op on a 1-wide
    model axis).  Everything else is the generic core: the same workload
    protocol, the same resilience wiring, the same observability.
    """

    def __init__(self, workload, params, *, mesh=None, ring: bool = False,
                 **kw):
        self.mesh = mesh if mesh is not None else meshctx.get_mesh()
        self.ring = bool(ring) and _model_axis(self.mesh) > 1
        # admission warmup must trace against the FINAL shardings: run it
        # after the device_puts below, not inside super().__init__ (a
        # warmup over replicated args would compile executables the first
        # live call immediately retraces)
        self._defer_warmup = True
        with self._mesh_ctx():
            super().__init__(workload, params, **kw)
            family = getattr(workload.cfg, "family", "") or ""
            pspecs = sharding.partition_params(self.params, family)
            self.params = jax.device_put(self.params,
                                         sharding.named(pspecs, self.mesh))
            cspecs = sharding.partition_cache(self.state, family)
            self.state = jax.device_put(self.state,
                                        sharding.named(cspecs, self.mesh))
            if self._golden is not None:
                # re-point the scrub source at the *sharded* tree: a scrub
                # must restore placement along with the bits (rebinding the
                # host copy would silently re-replicate the params)
                self._golden = self.params
            self._maybe_warmup()

    def _mesh_ctx(self):
        """Every trace under this engine's mesh + ring lever: construction
        (prefill/reset jits bind here) and each tick (the fused step traces
        lazily at first call)."""
        ctx = contextlib.ExitStack()
        ctx.enter_context(meshctx.use_mesh(self.mesh))
        if self.ring:
            ctx.enter_context(kops.ring_tp())
        return ctx

    def tick(self) -> int:
        with self._mesh_ctx():
            return super().tick()


class ShardedServeEngine(ShardedServeCore):
    """LM facade over the sharded core: ``ServeEngine``'s construction
    surface plus ``mesh=``/``ring=``.  ``tp`` defaults to the mesh's model
    axis — params must come from ``model.init(key, tp=<model axis>)`` so
    the padded head/expert dims divide the axis."""

    def __init__(self, model, params, *, mesh=None, ring: bool = False,
                 slots: int = 8, max_len: int = 512, eos_id: int = -1,
                 tp: Optional[int] = None, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0,
                 admission=None, **kw):
        mesh = mesh if mesh is not None else meshctx.get_mesh()
        tp = _model_axis(mesh) if tp is None else tp
        workload = LMAdapter(model, tp=tp, eos_id=eos_id, greedy=greedy,
                             temperature=temperature, top_k=top_k,
                             max_len=max_len, admission=admission)
        super().__init__(workload, params, mesh=mesh, ring=ring,
                         slots=slots, max_len=max_len, **kw)
        self.model = model
        self.tp = tp
        self.eos_id = eos_id

    @property
    def cache(self):
        return self.state

    def submit(self, prompt, max_new_tokens: int = 32, **kw) -> Request:
        return super().submit(prompt, max_new_tokens, **kw)


def lm_decode_collective_bytes(arch: str = "tinyllama-1.1b-smoke", *,
                               tp: int = 2, batch: int = 2,
                               max_len: int = 32,
                               ring: bool = False) -> dict:
    """Lower+compile one sharded LM decode step on a ``(1, tp)`` mesh and
    return its collective wire bytes by kind (plus ``"total"``), measured
    from the optimized HLO by ``dist.hlo_analysis``.  Needs ``tp`` local
    devices.  This is the decode-step collective *budget* probe: the
    elastic bench asserts ``ring=True`` bytes stay within half the exact
    f32 budget."""
    from repro.configs import get_config
    from repro.dist.hlo_analysis import analyze_hlo
    from repro.models import build_model

    mesh = meshctx.make_mesh((1, tp), ("data", "model"))
    cfg = get_config(arch)
    model = build_model(cfg)
    ctx = contextlib.ExitStack()
    ctx.enter_context(meshctx.use_mesh(mesh))
    if ring and tp > 1:
        ctx.enter_context(kops.ring_tp())
    with ctx:
        params = model.init(jax.random.PRNGKey(0), tp=tp)
        cache = model.init_cache(tp=tp, batch=batch, max_len=max_len)
        params = jax.device_put(
            params, sharding.named(sharding.partition_params(params,
                                                             cfg.family),
                                   mesh))
        cache = jax.device_put(
            cache, sharding.named(sharding.partition_cache(cache,
                                                           cfg.family),
                                  mesh))
        tokens = jnp.zeros((batch, 1), jnp.int32)

        def step(p, c, t):
            return model.decode_step(p, c, t, tp=tp)

        txt = jax.jit(step).lower(params, cache, tokens).compile().as_text()
    rep = analyze_hlo(txt)
    out = dict(rep.collectives.bytes_by_kind)
    out["total"] = rep.collectives.total_bytes
    return out
