"""repro — `axdsp`: approximate-arithmetic DSP/AI acceleration framework in JAX.

Reproduction of V. Leon, "From Circuits to SoC Processors: Arithmetic
Approximation Techniques & Embedded Computing Methodologies for DSP
Acceleration" (NTUA PhD dissertation, 2022), adapted to TPU-native JAX.
See DESIGN.md.
"""
__version__ = "0.1.0"
