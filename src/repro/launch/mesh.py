"""Production mesh construction (spec'd by the dry-run contract).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax

from repro.dist import meshctx


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod (256 chips) or (2, 16, 16) multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return meshctx.make_mesh(shape, axes)


def make_mesh_for(devices: int, tp: int = 16, pods: int = 1):
    """Elastic variant: mesh for an arbitrary surviving-device count
    (dist/elastic.py computes the plan)."""
    assert devices % (tp * pods) == 0
    data = devices // (tp * pods)
    if pods > 1:
        return meshctx.make_mesh((pods, data, tp), ("pod", "data", "model"))
    return meshctx.make_mesh((data, tp), ("data", "model"))
