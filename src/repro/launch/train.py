"""Production training entrypoint: builds the mesh, shards state via the
partition rules, and runs the fault-tolerant trainer.

  python -m repro.launch.train --arch tinyllama-1.1b --steps 1000 \
      [--mesh 16x16|2x16x16|dxm] [--approx axq8|exact] [--qos]

On this CPU container use smoke archs (--arch tinyllama-1.1b-smoke); on a TPU
pod the same entrypoint drives the full configs.
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.approx import policy_from_flag
from repro.core.dynamic import QoSController
from repro.data.pipeline import make_pipeline
from repro.dist import meshctx
from repro.kernels import dispatch as kdispatch
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.train import step as step_mod
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--approx", default="exact")
    ap.add_argument("--plan", default=None,
                    help="ApproxPlan JSON (repro.tune): train under the "
                         "plan's policy with its per-layer degree ladder as "
                         "the QoS ladder (implies the plan's mode/block)")
    ap.add_argument("--qos", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/launch_train")
    ap.add_argument("--kernels", default=None,
                    choices=("auto", "pallas", "xla"),
                    help="attention kernel backend (default: REPRO_KERNELS "
                         "env or auto = pallas on TPU, xla elsewhere)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run "
                         "(data/step/checkpoint spans, straggler and "
                         "QoS-rung events)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text-format metrics (step/loss/"
                         "checkpoint counters, step-time histogram, degree "
                         "gauges) at exit")
    args = ap.parse_args()

    kdispatch.set_backend(args.kernels)
    if args.trace_out:
        obs_trace.enable()

    d, m = (int(x) for x in args.mesh.split("x")[:2])
    mesh = meshctx.make_mesh((d, m), ("data", "model"))
    meshctx.set_mesh(mesh)

    cfg = get_config(args.arch)
    plan = None
    if args.plan is not None:
        from repro.tune import ApproxPlan

        plan = ApproxPlan.load(args.plan)
        plan.validate_for(cfg)
        policy = plan.policy(dynamic=True)
    else:
        try:
            policy = policy_from_flag(args.approx, dynamic=args.qos)
        except ValueError as e:
            raise SystemExit(str(e))
    model = build_model(cfg, policy)
    pipe = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch)
    # same contract as serve: --qos steps the ladder (the plan's rungs when
    # --plan is given); a plan WITHOUT --qos trains the most-accurate rung
    # as a fixed configuration
    ladder = (plan.qos_ladder() if plan is not None
              else [{"ebits": 8}, {"ebits": 7}, {"ebits": 6}, {"ebits": 5}])
    qos = QoSController(
        ladder=ladder,
        low_water=-0.005, high_water=0.05) if args.qos else None
    static_degrees = (list(plan.degrees(0))
                      if (plan is not None and qos is None) else None)
    trainer = Trainer(
        model,
        step_mod.StepConfig(remat="none", total_steps=args.steps,
                            warmup=max(args.steps // 20, 5),
                            compress_grads=args.compress_grads),
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=args.ckpt_dir, qos=qos,
                      static_degrees=static_degrees),
        pipe, tp=m,
        registry=obs_metrics.get_registry() if args.metrics_out else None)
    out = trainer.run()
    print(f"[launch.train] done at step {out['final_step']}; "
          f"preempted={out['preempted']}; stragglers={len(out['stragglers'])}")
    if args.trace_out:
        obs_trace.get_tracer().write(args.trace_out)
        print(f"[launch.train] wrote Chrome trace -> {args.trace_out}")
    if args.metrics_out:
        obs_metrics.get_registry().write(args.metrics_out)
        print(f"[launch.train] wrote Prometheus metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
