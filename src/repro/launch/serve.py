"""Serving entrypoint: continuous-batching engine over a selected arch.

  python -m repro.launch.serve --arch tinyllama-1.1b-smoke --requests 16
On a TPU pod the full configs drive the same engine with the decode
sharding proven by the dry-run (KV cache TP over the model axis, optional
int8 cache via REPRO_KV_INT8=1).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.dist import meshctx
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x")[:2])
    meshctx.set_mesh(meshctx.make_mesh((d, m), ("data", "model")))
    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), tp=m)
    eng = ServeEngine(model, params, slots=args.slots, max_len=512, tp=m)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(2, 10))),
                   args.new_tokens)
    done = eng.run_until_drained()
    dt = time.time() - t0
    tot = sum(len(r.out_tokens) for r in done)
    print(f"[launch.serve] {len(done)} reqs, {tot} tokens, {dt:.2f}s "
          f"({tot/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
