"""Serving entrypoint: continuous-batching engine over a selected workload.

  python -m repro.launch.serve --arch tinyllama-1.1b-smoke --requests 16
  # temperature/top-k sampling, per-request latency table, QoS degree loop:
  python -m repro.launch.serve --arch tinyllama-1.1b-smoke \
      --temperature 0.8 --top-k 40 --seed 7 --qos --metrics
  # per-layer approximation plan (repro.tune): serve the tuned degree
  # ladder, QoS stepping whole calibrated configurations:
  python -m repro.launch.serve --arch tinyllama-1.1b-smoke \
      --plan plans/approx_plan.json --qos --metrics
  # streaming DSP/vision pipeline (Ch. 7 accelerators) on the same engine:
  python -m repro.launch.serve --workload stream --requests 8 --qos --metrics
  # elastic sharded fleet: 3 tensor-parallel replicas, int8 ring
  # collectives, survive a seeded replica loss live (docs/distributed_serving.md):
  python -m repro.launch.serve --replicas 3 --tp 2 --ring \
      --faults replica_loss=0.02 --metrics

``--workload lm`` (default) decodes tokens; ``--workload stream`` serves
frame clips through the approximate FIR + conv2d pipeline
(repro.serve.stream) — same slot lifecycle, continuous batching, plan
ladder, QoS controller, and observability surfaces.

``--replicas N`` (N > 1) lifts either workload onto a
:class:`repro.dist.fleet.FleetSupervisor`: N data-parallel replica
engines — for lm, each a :class:`repro.serve.sharded.ShardedServeEngine`
on its own ``(1, tp)`` mesh slice — with least-loaded routing, fleet-level
``replica_loss`` fault injection, queue migration + in-flight rewind on
replica death, and ``plan_rescale`` survivor-mesh replanning.

On a TPU pod the full configs drive the same engine with the decode
sharding proven by the dry-run (KV cache TP over the model axis, optional
int8 cache via REPRO_KV_INT8=1).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.approx import policy_from_flag
from repro.core.dynamic import QoSController
from repro.dist import meshctx
from repro.kernels import dispatch as kdispatch
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import ServeEngine
from repro.serve.metrics import summarize


def _policy_from_args(args):
    """ServePolicy from the CLI flags, or None when no policy flag is set."""
    if (args.deadline_ms is None and args.retries is None
            and args.shed is None and not args.brownout):
        return None
    from repro.resil import ServePolicy

    if args.brownout and not args.qos:
        raise SystemExit("--brownout degrades the QoS ladder under "
                         "overload: it needs --qos (or --plan with "
                         "--qos) to have a ladder to walk")
    return ServePolicy(
        deadline_ms=args.deadline_ms,
        max_retries=args.retries if args.retries is not None else 2,
        max_queue=args.shed,
        brownout=args.brownout)


def _admission_from_args(args):
    """AdmissionConfig from the CLI flags, or None when no admission flag
    is set (the engine then runs the legacy exact-length admission path).
    ``--prefill-buckets auto`` derives the power-of-two ladder from the
    engine's max_len."""
    if not (args.prefill_buckets or args.pack > 1 or args.chunk_tokens):
        return None
    from repro.serve.admission import AdmissionConfig

    buckets: tuple = ()
    if args.prefill_buckets and args.prefill_buckets != "auto":
        buckets = tuple(int(b) for b in args.prefill_buckets.split(","))
    return AdmissionConfig(buckets=buckets, pack=max(args.pack, 1),
                           chunk_tokens=args.chunk_tokens)


def _resil_kwargs(args) -> dict:
    """Build the engine's resilience kwargs from the CLI flags (shared by
    both workloads — the resil subsystem is workload-generic).  Empty dict
    when no resilience flag is set: the engine then compiles and runs the
    exact legacy path."""
    kw: dict = {}
    if args.faults:
        from repro.resil import FaultPlan, FaultSpec, GuardConfig

        kw["faults"] = FaultPlan(FaultSpec.parse(args.faults),
                                 seed=args.fault_seed)
        kw["guards"] = GuardConfig()
    policy = _policy_from_args(args)
    if policy is not None:
        kw["policy"] = policy
    return kw


def _fleet_fault_plans(args, replicas: int):
    """Split ``--faults`` for a fleet: ``replica_loss`` is drawn by one
    fleet-level plan (the supervisor binds it to the replica count); the
    engine-level kinds become one plan per replica, seed-offset so the
    replicas see distinct storms, with ``replica_loss`` zeroed — engines
    record-but-ignore the kind, so leaving it in would silently drop the
    configured rate."""
    if not args.faults:
        return None, [None] * replicas
    import dataclasses

    from repro.resil import FaultPlan, FaultSpec

    spec = FaultSpec.parse(args.faults)
    fleet_plan = (FaultPlan(FaultSpec(replica_loss=spec.replica_loss),
                            seed=args.fault_seed)
                  if spec.replica_loss else None)
    espec = dataclasses.replace(spec, replica_loss=0.0)
    if not any((espec.seu_state, espec.seu_param, espec.nan, espec.spike,
                espec.drop)):
        return fleet_plan, [None] * replicas
    return fleet_plan, [FaultPlan(espec, seed=args.fault_seed + rid)
                        for rid in range(replicas)]


def _print_resil(eng, done) -> None:
    """Resilience summary lines (only when something happened)."""
    s = eng.stats
    def fam_total(fam) -> int:
        return sum(int(c.value) for c in fam.children.values())

    counts = {
        "faults_injected": fam_total(s.c_faults),
        "guard_trips": fam_total(s.c_guard_trips),
        "retries": int(s.c_retries.value),
        "shed": fam_total(s.c_shed),
        "deadline_miss": fam_total(s.c_deadline_miss),
        "brownout_rungs": int(s.c_brownout.value),
        "param_scrubs": int(s.c_scrubs.value),
    }
    if any(counts.values()):
        line = " ".join(f"{k}={v}" for k, v in counts.items() if v)
        print(f"[launch.serve]   resil: {line}")


def _write_obs(args) -> None:
    """Shared exit-time observability dumps (both workloads)."""
    if args.trace_out:
        obs_trace.get_tracer().write(args.trace_out)
        print(f"[launch.serve] wrote Chrome trace -> {args.trace_out}")
    if args.metrics_out:
        obs_metrics.get_registry().write(args.metrics_out)
        print(f"[launch.serve] wrote Prometheus metrics -> {args.metrics_out}")


def _serve_stream(args) -> None:
    """--workload stream: frame clips through the DSP/vision pipeline."""
    from repro.serve.stream import StreamAdapter, StreamServeEngine, make_clip

    adapter = StreamAdapter()
    cfg = adapter.cfg
    plan = None
    if args.plan is not None:
        from repro.tune import ApproxPlan

        plan = ApproxPlan.load(args.plan)      # ServeCore validates vs cfg
    qos = QoSController(
        ladder=[{"degrees": [e] * (cfg.n_layers + 1)} for e in (8, 7, 6, 5)],
        low_water=0.25, high_water=0.75, cooldown_steps=8,
    ) if args.qos else None
    registry = obs_metrics.get_registry() if args.metrics_out else None
    eng = StreamServeEngine(adapter, slots=args.slots, seed=args.seed,
                            qos=qos, plan=plan, registry=registry,
                            quality_every=args.quality_every,
                            **_resil_kwargs(args))
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(make_clip(args.frames, cfg.frame, q=cfg.q, seed=i))
    done = eng.run_until_drained()
    dt = time.time() - t0
    frames = sum(len(r.out) for r in done)
    print(f"[launch.serve] stream: {len(done)} clips, {frames} frames, "
          f"{dt:.2f}s ({frames / max(dt, 1e-9):.1f} frames/s) "
          f"[kernels={kdispatch.resolved_backend()}]")
    if args.metrics:
        for k, v in summarize(done, eng.stats, wall_s=dt).items():
            print(f"[launch.serve]   {k:24s} {v}")
        if qos is not None:
            print(f"[launch.serve]   degree ladder visits: "
                  f"{[e for _, e in list(eng.stats.degree_history)[-8:]]} "
                  f"(last 8)")
        _print_resil(eng, done)
    _write_obs(args)


def _serve_fleet(args) -> None:
    """--replicas N: a data-parallel fleet of engines under a
    FleetSupervisor — per-replica mesh slices, least-loaded routing,
    replica-loss survival (migrate + rewind + plan_rescale).  Both
    workloads ride the same supervisor; lm replicas are sharded engines
    (tensor-parallel over the replica's model axis, optional int8 ring
    collectives on the decode path)."""
    from repro.dist.fleet import FleetSupervisor
    from repro.resil import GuardConfig

    tp = args.tp if args.tp else int(args.mesh.split("x")[1])
    fleet_plan, engine_plans = _fleet_fault_plans(args, args.replicas)
    policy = _policy_from_args(args)
    registry = obs_metrics.get_registry() if args.metrics_out else None

    def engine_kwargs(rid: int) -> dict:
        kw: dict = {"slots": args.slots, "seed": args.seed,
                    "registry": registry,
                    "quality_every": args.quality_every,
                    "prepack": not args.no_prepack}
        if engine_plans[rid] is not None:
            kw["faults"] = engine_plans[rid]
            kw["guards"] = GuardConfig()
        if policy is not None:
            kw["policy"] = policy
        return kw

    if args.workload == "stream":
        from repro.serve.stream import (StreamAdapter, StreamServeEngine,
                                        make_clip)

        adapter = StreamAdapter()
        scfg = adapter.cfg
        ladder = [{"degrees": [e] * (scfg.n_layers + 1)}
                  for e in (8, 7, 6, 5)]

        def build(mesh, rid):
            # QoS controllers are stateful: one per replica, never shared
            qos = QoSController(ladder=ladder, low_water=0.25,
                                high_water=0.75, cooldown_steps=8
                                ) if args.qos else None
            return StreamServeEngine(adapter, qos=qos, **engine_kwargs(rid))

        payloads = [make_clip(args.frames, scfg.frame, q=scfg.q, seed=i)
                    for i in range(args.requests)]
        budget = None
        unit = "frames"
    else:
        from repro.serve.sharded import ShardedServeEngine

        cfg = get_config(args.arch)
        plan = None
        if args.plan is not None:
            from repro.tune import ApproxPlan

            plan = ApproxPlan.load(args.plan)
            plan.validate_for(cfg)
            apolicy = plan.policy(dynamic=True)
        else:
            try:
                apolicy = policy_from_flag(args.approx, dynamic=args.qos)
            except ValueError as e:
                raise SystemExit(str(e))
        model = build_model(cfg, apolicy)
        params = model.init(jax.random.PRNGKey(0), tp=tp)

        admission = _admission_from_args(args)

        def build(mesh, rid):
            qos = QoSController(ladder=[{"ebits": e} for e in (8, 7, 6, 5)],
                                low_water=0.25, high_water=0.75,
                                cooldown_steps=8) if args.qos else None
            return ShardedServeEngine(
                model, params, mesh=mesh, ring=args.ring, max_len=512,
                eos_id=args.eos_id, greedy=args.temperature <= 0,
                temperature=max(args.temperature, 1e-6), top_k=args.top_k,
                qos=qos, plan=plan, admission=admission,
                **engine_kwargs(rid))

        rng = np.random.default_rng(args.seed)
        payloads = [rng.integers(0, cfg.vocab, int(rng.integers(2, 10)))
                    for _ in range(args.requests)]
        budget = args.new_tokens
        unit = "tokens"

    sup = FleetSupervisor(build, args.replicas, tp=tp, faults=fleet_plan,
                          policy=policy, registry=registry,
                          rescale_ms=args.rescale_ms,
                          route_by=args.route_by)
    t0 = time.time()
    for p in payloads:
        sup.submit(p, budget)
    done = sup.run_until_drained()
    dt = time.time() - t0
    units = sum(len(r.out) for r in done)
    counts = sup.status_counts()
    status = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[launch.serve] fleet: {len(done)} reqs on {args.replicas} "
          f"replica(s) x tp={tp}, {len(sup.live)} up at exit, {units} "
          f"{unit}, {dt:.2f}s [{status}] "
          f"[kernels={kdispatch.resolved_backend()}]")
    if sup.rescales:
        plan = sup.rescales[-1]
        print(f"[launch.serve]   last rescale: data={plan.data} "
              f"model={plan.model} idle={plan.idle_devices} "
              f"({len(sup.rescales)} rescale(s))")
    if args.metrics:
        events: dict = {}
        for _, name, _ in sup.resil_log:
            events[name] = events.get(name, 0) + 1
        if events:
            line = " ".join(f"{k}={v}" for k, v in sorted(events.items()))
            print(f"[launch.serve]   fleet events: {line}")
        for r in sup.replicas:
            served = len(r.engine.done)
            state = "up" if r.alive else f"dead@tick{r.died_at}"
            print(f"[launch.serve]   replica {r.rid}: {state}, "
                  f"{served} reqs finished")
    _write_obs(args)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=("lm", "stream"),
                    help="what to serve: lm (token decode, default) or "
                         "stream (frame-by-frame approximate DSP/vision "
                         "pipeline — repro.serve.stream)")
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--frames", type=int, default=8,
                    help="frames per clip (--workload stream)")
    ap.add_argument("--mesh", default="1x1")
    # -- elastic fleet (repro.dist.fleet; docs/distributed_serving.md) ----
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="serve through a FleetSupervisor over N "
                         "data-parallel replica engines (N > 1); each lm "
                         "replica is a ShardedServeEngine on its own "
                         "(1, tp) mesh slice")
    ap.add_argument("--tp", type=int, default=0, metavar="M",
                    help="tensor-parallel degree per replica (fleet mode; "
                         "default: the model axis of --mesh)")
    ap.add_argument("--ring", action="store_true",
                    help="route the sharded decode's row-parallel "
                         "reductions through the int8 ppermute ring "
                         "(compressed wire bytes, calibrated error "
                         "envelope)")
    ap.add_argument("--rescale-ms", type=float, default=5.0,
                    help="modeled survivor-mesh re-shard latency charged "
                         "per rescale (repro_rescale_seconds histogram)")
    ap.add_argument("--route-by", default="slots",
                    choices=("slots", "backlog"),
                    help="fleet routing load signal: slots counts requests "
                         "(queued + in-slot); backlog counts admission "
                         "work in payload units, so chunked long prompts "
                         "weigh what they cost")
    # -- admission pipeline (repro.serve.admission; docs/serving.md) ------
    ap.add_argument("--prefill-buckets", default=None, metavar="LIST",
                    help="bucketed AOT prefill: comma list of ascending "
                         "prompt-prefix lengths (e.g. 16,32,64,128), or "
                         "'auto' for the power-of-two ladder up to "
                         "max_len; every bucket executable is traced at "
                         "startup, so no request compiles after warmup")
    ap.add_argument("--pack", type=int, default=1, metavar="N",
                    help="pack up to N short prompts into one bucketed "
                         "prefill call (each row scatters into its own "
                         "slot; bit-identical to sequential admission)")
    ap.add_argument("--chunk-tokens", type=int, default=0, metavar="C",
                    help="chunked prefill: split prompts longer than C "
                         "into C-token chunks admitted across ticks, "
                         "interleaved with decode, bounding short-request "
                         "TTFT behind long arrivals (0 = off; dense "
                         "full-attention archs only — others fall back to "
                         "whole-prompt bucketed prefill)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 enables categorical sampling")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k most likely tokens")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine PRNG seed (sampling is reproducible per seed)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop-token id; -1 disables EOS stopping")
    ap.add_argument("--qos", action="store_true",
                    help="drive the runtime approximation degree from load "
                         "(DyFXU ladder ebits 8->5, no recompilation)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the TTFT/TPOT/queue latency summary and "
                         "prefill-vs-decode token accounting")
    ap.add_argument("--kernels", default=None,
                    choices=("auto", "pallas", "xla"),
                    help="attention/GEMM kernel backend (default: "
                         "REPRO_KERNELS env or auto = pallas on TPU, xla "
                         "elsewhere)")
    ap.add_argument("--approx", default="exact",
                    help="projection arithmetic: exact | axqN (block-int8 "
                         "GEMMs at N effective bits, e.g. axq8/axq6); "
                         "ignored when --plan is given (the plan carries "
                         "its own policy)")
    ap.add_argument("--plan", default=None,
                    help="path to an ApproxPlan JSON (repro.tune): serve "
                         "with per-layer degrees; with --qos the controller "
                         "steps the plan's calibrated degree ladder")
    ap.add_argument("--no-prepack", action="store_true",
                    help="disable quantize-once weight residency (keep the "
                         "per-call weight quantization; A/B lever — prepack "
                         "is bit-identical and strictly cheaper)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run "
                         "(enqueue/prefill/decode/QoS-rung spans; open in "
                         "chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text-format metrics (engine "
                         "counters, latency histograms, kernel routes, "
                         "degree gauges) at exit")
    ap.add_argument("--quality-every", type=int, default=0, metavar="N",
                    help="sample the live-vs-exact logit error every N "
                         "ticks into a per-rung histogram (0 = off; needs "
                         "--qos/--plan or an approx degree)")
    # -- resilience (repro.resil; docs/robustness.md) --------------------
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request e2e deadline; a request past it "
                         "terminates with status=deadline (queued or "
                         "in-slot), never silently")
    ap.add_argument("--retries", type=int, default=None, metavar="N",
                    help="guard-trip requeues before a request fails "
                         "(default 2; capped-exponential backoff)")
    ap.add_argument("--shed", type=int, default=None, metavar="Q",
                    help="queue-length backpressure cap: overflow sheds "
                         "newest-first (or browns out first, see "
                         "--brownout)")
    ap.add_argument("--brownout", action="store_true",
                    help="under overload force the QoS controller down the "
                         "approximation ladder BEFORE shedding (graceful "
                         "degradation; needs --qos)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="inject a seeded fault storm: comma list of "
                         "kind=rate — seu_state, seu_param, nan, spike, "
                         "drop (e.g. 'seu_state=0.02,nan=0.05'); enables "
                         "runtime guards + quarantine; with --replicas, "
                         "replica_loss=RATE kills whole replicas (drawn "
                         "fleet-level; the engine kinds keep their "
                         "per-replica storms)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault schedule seed: the same seed reproduces "
                         "the identical injected-fault sequence and "
                         "recovery trace")
    args = ap.parse_args()

    kdispatch.set_backend(args.kernels)
    if args.trace_out:
        obs_trace.enable()
    if args.replicas > 1:
        _serve_fleet(args)
        return
    if args.workload == "stream":
        _serve_stream(args)
        return

    d, m = (int(x) for x in args.mesh.split("x")[:2])
    if args.tp:
        m = args.tp
    meshctx.set_mesh(meshctx.make_mesh((d, m), ("data", "model")))
    cfg = get_config(args.arch)
    plan = None
    if args.plan is not None:
        from repro.tune import ApproxPlan

        plan = ApproxPlan.load(args.plan)
        plan.validate_for(cfg)
        # the plan pins mode/block; its degrees are the runtime knob
        policy = plan.policy(dynamic=True)
    else:
        try:
            policy = policy_from_flag(args.approx, dynamic=args.qos)
        except ValueError as e:
            raise SystemExit(str(e))
    model = build_model(cfg, policy)
    params = model.init(jax.random.PRNGKey(0), tp=m)
    if not args.no_prepack:
        # rebind: the f32 copies of packed weights are dropped here — the
        # engine holds only the int8 residency forms
        params = model.prepack(params)
    qos = QoSController(
        ladder=[{"ebits": e} for e in (8, 7, 6, 5)],
        low_water=0.25, high_water=0.75, cooldown_steps=8,
    ) if args.qos else None
    registry = obs_metrics.get_registry() if args.metrics_out else None
    eng = ServeEngine(model, params, slots=args.slots, max_len=512, tp=m,
                      eos_id=args.eos_id, greedy=args.temperature <= 0,
                      temperature=max(args.temperature, 1e-6),
                      top_k=args.top_k, seed=args.seed, qos=qos,
                      prepack=False, plan=plan, registry=registry,
                      quality_every=args.quality_every,
                      admission=_admission_from_args(args),
                      **_resil_kwargs(args))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(2, 10))),
                   args.new_tokens)
    done = eng.run_until_drained()
    dt = time.time() - t0
    s = summarize(done, eng.stats, wall_s=dt)
    print(f"[launch.serve] {s['requests']} reqs, {s['generated_tokens']} "
          f"generated tokens, {dt:.2f}s ({s['gen_tok_per_s']:.1f} gen tok/s) "
          f"[kernels={kdispatch.resolved_backend()}]")
    if args.metrics:
        for k, v in s.items():
            print(f"[launch.serve]   {k:24s} {v}")
        if qos is not None:
            print(f"[launch.serve]   degree ladder visits: "
                  f"{[e for _, e in list(eng.stats.degree_history)[-8:]]} (last 8)")
        _print_resil(eng, done)
    _write_obs(args)


if __name__ == "__main__":
    main()
