import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16x16 single-pod, 2x16x16 multi-pod) and records the
artifacts the roofline reads:
  - compiled.memory_analysis()   (fits per device?)
  - compiled.cost_analysis()     (XLA's aggregate flops/bytes — NOTE: while
                                  bodies counted once; see dist/hlo_analysis)
  - trip-count-aware dot FLOPs / traffic / collective bytes from the HLO text

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]       # subprocess per cell
  python -m repro.launch.dryrun --list
Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json.
"""  # noqa: E402

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402
from pathlib import Path  # noqa: E402

ROOT = Path(__file__).resolve().parents[3]
OUT_DIR = ROOT / "experiments" / "dryrun"

ARCHS = [
    "qwen2-moe-a2.7b",
    "granite-moe-3b-a800m",
    "mistral-nemo-12b",
    "h2o-danube-1.8b",
    "qwen2.5-3b",
    "tinyllama-1.1b",
    "recurrentgemma-2b",
    "internvl2-1b",
    "hubert-xlarge",
    "mamba2-370m",
]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             step_overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.dist import meshctx, sharding
    from repro.dist.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model, input_specs
    from repro.train import step as step_mod

    t0 = time.time()
    cfg = get_config(arch)
    reason = cfg.skip_reason(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "skip" if reason else "pending", "skip_reason": reason,
    }
    if reason:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    meshctx.set_mesh(mesh)
    tp = mesh.shape["model"]
    model = build_model(cfg)
    shp = SHAPES[shape_name]
    scfg = step_mod.StepConfig(**({"remat": "full"} | (step_overrides or {})))

    key = jax.random.PRNGKey(0)
    batch_sds = input_specs(cfg, shape_name)

    if shp.kind in ("train", "prefill"):
        state_sds = jax.eval_shape(
            partial(step_mod.init_state, model, tp=tp), key)
        pspecs = sharding.partition_params(state_sds.params, cfg.family)
        state_specs = step_mod.TrainState(
            pspecs, sharding.partition_opt_state(state_sds.opt, pspecs),
            jax.sharding.PartitionSpec())
        batch_specs = sharding.partition_batch(batch_sds)
        if shp.kind == "train":
            fn = partial(step_mod.train_step, model, scfg, tp=tp)
            jitted = jax.jit(
                fn,
                in_shardings=(sharding.named(state_specs, mesh),
                              sharding.named(batch_specs, mesh)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)
        else:  # prefill == forward-only at scale (inference-prefill cell)
            def fwd(params, batch):
                logits, aux = model.forward(params, batch, tp=tp, remat="dots")
                return logits

            jitted = jax.jit(
                fwd,
                in_shardings=(sharding.named(pspecs, mesh),
                              sharding.named(batch_specs, mesh)),
            )
            lowered = jitted.lower(state_sds.params, batch_sds)
    else:  # decode
        params_sds = jax.eval_shape(partial(model.init, tp=tp), key)
        if os.environ.get("REPRO_SERVE_BF16", "0") == "1":
            # §Perf hillclimb B1: serve from bf16 weights (dense_apply casts
            # to activation dtype anyway — numerically identical path)
            params_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params_sds)
        pspecs = sharding.partition_params(params_sds, cfg.family)
        cache_sds = jax.eval_shape(
            partial(model.init_cache, tp, shp.global_batch, shp.seq_len))
        cache_specs = sharding.partition_cache(cache_sds, cfg.family)
        tok_specs = sharding.partition_batch(batch_sds)
        fn = partial(step_mod.serve_step, model, tp=tp)
        jitted = jax.jit(
            fn,
            in_shardings=(sharding.named(pspecs, mesh),
                          sharding.named(cache_specs, mesh),
                          sharding.named(tok_specs["tokens"], mesh)),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, batch_sds["tokens"])

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per executable
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)
    n_total, n_active = cfg.param_count()

    rec.update(
        status="ok",
        chips=mesh.size,
        tp=tp,
        seq=shp.seq_len,
        global_batch=shp.global_batch,
        kind=shp.kind,
        lower_s=round(t_lower - t0, 2),
        compile_s=round(t_compile - t_lower, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
        ),
        xla_cost=dict(
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
        ),
        hlo_analysis=rep.as_dict(),
        params_total=n_total,
        params_active=n_active,
        hlo_lines=len(hlo.splitlines()),
    )
    return rec


def cell_out_path(arch: str, shape_name: str, multi_pod: bool,
                  tag: str = "") -> Path:
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16") +         (f"__{tag}" if tag else "")
    d = OUT_DIR / mesh_name
    d.mkdir(parents=True, exist_ok=True)
    return d / f"{arch}__{shape_name}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=SHAPE_NAMES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell as a subprocess (both meshes unless "
                         "--multi-pod/--single-pod given)")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--step-overrides", default="",
                    help='JSON StepConfig overrides, e.g. {"remat":"full"}')
    ap.add_argument("--tag", default="",
                    help="experiment tag (results in <mesh>__<tag>/)")
    args = ap.parse_args()

    if args.list:
        from repro.configs import get_config

        for a in ARCHS:
            cfg = get_config(a)
            cells = [s for s, v in cfg.valid_shapes().items() if v]
            skips = [f"{s}({cfg.skip_reason(s)})"
                     for s, v in cfg.valid_shapes().items() if v is None]
            print(f"{a:<24} run: {', '.join(cells)}"
                  + (f"  SKIP: {'; '.join(skips)}" if skips else ""))
        return

    if args.all:
        if args.multi_pod:
            meshes = [True]
        elif args.single_pod:
            meshes = [False]
        else:
            meshes = [False, True]
        failures = []
        for mp in meshes:
            for a in ARCHS:
                for s in SHAPE_NAMES:
                    out = cell_out_path(a, s, mp)
                    if out.exists() and not args.force:
                        print(f"[skip-cached] {out.name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", a, "--shape", s]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.step_overrides:
                        cmd += ["--step-overrides", args.step_overrides]
                    print(f"[run] {a} x {s} mesh={'2x16x16' if mp else '16x16'}",
                          flush=True)
                    r = subprocess.run(cmd, cwd=str(ROOT))
                    if r.returncode != 0:
                        failures.append((a, s, mp))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells done")
        return

    assert args.arch and args.shape, "--arch and --shape (or --all/--list)"
    overrides = json.loads(args.step_overrides) if args.step_overrides else None
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, overrides)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
               "status": "error", "error": traceback.format_exc()}
    out = cell_out_path(args.arch, args.shape, args.multi_pod, args.tag)
    out.write_text(json.dumps(rec, indent=2))
    if rec["status"] == "ok":
        print(f"OK {args.arch} x {args.shape}: "
              f"compile {rec['compile_s']}s, "
              f"temp/device {rec['memory']['temp_bytes']/2**30:.2f} GiB, "
              f"dot_flops/device {rec['hlo_analysis']['dot_flops']:.3e}, "
              f"coll {rec['hlo_analysis']['collectives']['total_bytes']/2**30:.3f} GiB")
        print("memory_analysis:", rec["memory"])
        print("cost_analysis:", rec["xla_cost"])
    elif rec["status"] == "skip":
        print(f"SKIP {args.arch} x {args.shape}: {rec['skip_reason']}")
    else:
        print(rec.get("error", "error"), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
