"""Kernel backend dispatch: the single routing point between the model
attention call sites and the Pallas kernels (DESIGN.md §8).

Backend selection — ``REPRO_KERNELS`` env var, overridable per-process via
:func:`set_backend` (``launch.serve``/``launch.train`` ``--kernels`` flag,
``bench_serving`` A/B):

  pallas   always route qualifying shapes through the Pallas kernels
           (interpret-mode emulation off-TPU: correctness/step-count work)
  xla      always the pure-jnp paths (models/attention.py)
  auto     pallas on TPU, xla elsewhere (default — CPU CI stays on the
           fast jnp paths, TPU gets the kernels with ``interpret=False``)

``interpret`` is resolved per backend (``jax.default_backend() != "tpu"``)
instead of the old hardcoded ``True``.

Routing contract:

  * :func:`prefill_attention` — the model-layout (B, S, H, D) GQA entry for
    full-sequence attention (train forward, fused serve prefill).  Qualifies
    when causal or un-windowed (the kernel's grids); GQA is flattened to the
    kernel's (BH, S, D) layout (kv heads repeated — the kernel layout
    contract; the jnp fallback keeps the grouped never-materialized form).
    Differentiable: routes through ``flash_attention_vjp``.
  * :func:`decode_attention` — single-token decode against a KVCache /
    QuantKVCache, routed to kernels/flash_decode.py with free-slot masking
    and the runtime ebits degree; falls back to decode_attn(_quant).
  * :func:`axq_matmul` / :func:`axq_gated` — the GEMM-side twin (DESIGN.md
    §9): AXQ projections route to the axqmm Pallas kernels (fused epilogues,
    prepacked-weight residency) or the pure-jnp qmm refs.  Float weights go
    through a custom-VJP (kernel fwd, ``qmm_ref``-oracle bwd — or an STE
    exact-matmul bwd for the MoE experts) so ``--kernels pallas`` training
    routes AXQ too; :class:`~repro.kernels.qstore.PackedQWeight` operands
    take the quantize-once inference path.

``last_route`` records the decision per call site — keys ``"prefill"`` /
``"decode"`` (attention) and ``"gemm"`` / ``"gated"`` (AXQ projections) —
for tests and benchmarks.  Every decision is also published through
``repro.obs`` (DESIGN.md §11): a ``repro_kernel_route_trace_total{site=..,
backend=..}`` counter on the process-global metrics registry plus a
``kernel_route`` trace event.  Routers run at *trace* time (inside jit
tracing), so these count compilations — the serve engine's
``repro_kernel_route_steps_total`` counts executed steps per backend.

Runtime degree contract: every router takes the DyFXU degree as a *traced*
scalar (``ebits`` / ``degree``), so moving it never recompiles.  Per-layer
plans (repro.tune, models/degrees.py) keep that contract by slicing their
(n_layers + 1,) degree vector down to this layer's scalar before the call —
inside ``lax.scan`` the slice is automatic (the vector rides the scan xs);
unrolled call sites (e.g. the hybrid tail blocks in models/rglru.py) use
:func:`site_degree`.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import (qmm_gated_packed_ref, qmm_gated_ref,
                                     qmm_packed_ref, qmm_ref)
from repro.kernels import axqmm as _axq
from repro.kernels.flash_attention import flash_attention_vjp
from repro.kernels.flash_decode import decode_attn_flash
from repro.kernels.qstore import PackedQWeight, resolve_block

Array = jnp.ndarray

_VALID = ("auto", "pallas", "xla")

_override: Optional[str] = None

#: last routing decision per call site ("prefill" / "decode" attention,
#: "gemm" / "gated" AXQ projections) — debug aid for tests and benchmarks,
#: written at trace time.
last_route: dict = {}


def _record_route(site: str, backend: str) -> None:
    """Publish one routing decision: ``last_route`` (tests), the global
    metrics registry (counter by site x backend), and a trace event.
    Called at trace time — counts reflect compilations, not executions."""
    last_route[site] = backend
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    obs_metrics.get_registry().counter(
        "repro_kernel_route_trace_total",
        "kernel routing decisions at trace time, by call site and backend",
        labels=("site", "backend")).labels(site=site, backend=backend).inc()
    obs_trace.event("kernel_route_trace", track="dispatch", site=site,
                    backend=backend)


def set_backend(name: Optional[str]) -> None:
    """Process-wide override of ``REPRO_KERNELS`` (None -> back to env).
    Takes effect for functions traced afterwards (the serve engine traces
    its fused step at construction, so build engines after switching)."""
    global _override
    if name is not None and name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    _override = name


def backend_setting() -> str:
    setting = _override or os.environ.get("REPRO_KERNELS", "auto")
    if setting not in _VALID:
        raise ValueError(
            f"REPRO_KERNELS must be one of {_VALID}, got {setting!r}")
    return setting


def resolved_backend() -> str:
    """'pallas' or 'xla' after resolving 'auto' against the live platform."""
    setting = backend_setting()
    if setting == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return setting


def use_pallas() -> bool:
    return resolved_backend() == "pallas"


def interpret_mode() -> bool:
    """Pallas interpret flag for the current platform (auto, not hardcoded).
    Single source of truth: flash_attention._resolve_interpret (shared by
    both kernels)."""
    from repro.kernels.flash_attention import _resolve_interpret

    return _resolve_interpret(None)


def site_degree(degree, site: int):
    """Index a per-layer degree vector down to one site's scalar knob.

    ``degree`` may be None (static spec), a traced scalar (global DyFXU
    degree — passes through), or a per-site vector (an ApproxPlan rung);
    ``site`` is the layer id (or ``n_layers`` for the head site).  The
    returned scalar is what the kernels scalar-prefetch — indexing a traced
    vector keeps the zero-recompile contract."""
    if degree is None:
        return None
    d = jnp.asarray(degree)
    return d[site] if d.ndim else d


def inject_fault(x, fault):
    """Resilience fault hook (repro.resil, DESIGN.md §13): corrupt a batch
    activation ``x`` (slots leading axis) with a traced per-slot ``fault``
    operand — a (slots,) float32 vector where 0.0 means clean and NaN/Inf
    marks the slot for corruption.  Float activations take ``x + fault``
    (exact identity for clean slots, NaN/Inf poisoning for marked ones);
    integer activations flip the high magnitude bit on marked slots
    (SEU-style — NaN compares unordered so ``fault != 0`` is True for it).
    ``fault=None`` is the no-resilience path: returns ``x`` untouched with
    zero trace footprint."""
    if fault is None:
        return x
    f = jnp.asarray(fault, jnp.float32).reshape(
        (x.shape[0],) + (1,) * (x.ndim - 1))
    if jnp.issubdtype(x.dtype, jnp.floating):
        return x + f.astype(x.dtype)
    mask = jnp.asarray(1 << (8 * x.dtype.itemsize - 2), x.dtype)
    return jnp.where(f != 0.0, x ^ mask, x)


# ---------------------------------------------------------------------------
# call-site routers
# ---------------------------------------------------------------------------


def prefill_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: Optional[int] = None) -> Array:
    """Full-sequence GQA attention, model layout: q (B, S, H, D),
    k/v (B, S, KVr, D) -> (B, S, H, D)."""
    from repro.models import attention as attn  # lazy: kernels<->models layering

    B, S, H, D = q.shape
    qualifies = use_pallas() and S > 1 and (causal or window is None)
    _record_route("prefill", "pallas" if qualifies else "xla")
    if not qualifies:
        return attn.attn_blockwise(q, k, v, causal=causal, window=window)
    kf = attn.repeat_kv(k, H)
    vf = attn.repeat_kv(v, H)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    o = flash_attention_vjp(flat(q), flat(kf), flat(vf), causal, window)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def decode_attention(q1: Array, knew: Array, vnew: Array, cache, *,
                     window: Optional[int] = None, degree=None, active=None):
    """Single-token decode against the cache: q1 (B, 1, H, D),
    knew/vnew (B, 1, KVr, D) -> (out (B, 1, H, D), advanced cache).

    ``degree``: runtime ebits knob (int8 cache dequant degrade on the pallas
    path; the jnp path dequantizes exactly).  ``active``: (B,) bool free-slot
    mask (pallas path zeroes masked outputs; the jnp path computes and lets
    the engine discard them).
    """
    from repro.models import attention as attn

    if use_pallas():
        _record_route("decode", "pallas")
        return decode_attn_flash(q1, knew, vnew, cache, window=window,
                                 active=active, degree=degree)
    _record_route("decode", "xla")
    if isinstance(cache, attn.QuantKVCache):
        return attn.decode_attn_quant(q1, knew, vnew, cache, window=window)
    return attn.decode_attn(q1, knew, vnew, cache, window=window)


# ---------------------------------------------------------------------------
# GEMM routing (AXQ projections — DESIGN.md §9)
# ---------------------------------------------------------------------------

# legacy pre-dispatch escape hatch: force the Pallas GEMM regardless of the
# attention backend setting (kept for parity with the seed's ops.py knob)
_GEMM_FORCE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _gemm_route() -> str:
    return "pallas" if (use_pallas() or _GEMM_FORCE_PALLAS) else "xla"


def _float0(a):
    return np.zeros(np.shape(a), jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _axq_core(block: int, route: str, ste: bool):
    """Differentiable AXQ matmul core for *float* weights, cached per
    (block, backend, bwd-flavor).  Forward runs the Pallas kernel (or the
    jnp ref); backward differentiates the ``qmm_ref`` oracle — both backends
    therefore produce identical gradients, so AXQ training no longer
    silently requires the jnp reference path.  ``ste=True`` swaps in a
    straight-through exact-matmul backward (quantization is
    piecewise-constant; the MoE experts train through this)."""

    def run(x, w, e):
        if route == "pallas":
            return _axq.axqmm(x, w, block=block, ebits=e)
        return qmm_ref(x, w, block=block, ebits=e)

    core = jax.custom_vjp(run)

    def fwd(x, w, e):
        return run(x, w, e), (x, w, e)

    def bwd(res, g):
        x, w, e = res
        if ste:
            g16 = g.astype(jnp.bfloat16)
            dx = jnp.matmul(g16, w.astype(jnp.bfloat16).T,
                            preferred_element_type=jnp.float32).astype(x.dtype)
            dw = jnp.matmul(x.astype(jnp.bfloat16).T, g16,
                            preferred_element_type=jnp.float32).astype(w.dtype)
        else:
            _, vjp = jax.vjp(
                lambda xx, ww: qmm_ref(xx, ww, block=block, ebits=e), x, w)
            dx, dw = vjp(g)
        return dx, dw, _float0(e)

    core.defvjp(fwd, bwd)
    return core


@functools.lru_cache(maxsize=None)
def _axq_gated_core(block: int, route: str, act: str, ste: bool):
    """Differentiable fused gated core (float weights): kernel fwd,
    oracle bwd — see :func:`_axq_core`."""
    actf = _axq._ACTS[act]

    def run(x, wu, wg, e):
        if route == "pallas":
            return _axq.axqmm_gated(x, wu, wg, block=block, ebits=e, act=act)
        return qmm_gated_ref(x, wu, wg, actf, block=block, ebits=e)

    core = jax.custom_vjp(run)

    def fwd(x, wu, wg, e):
        return run(x, wu, wg, e), (x, wu, wg, e)

    def bwd(res, g):
        x, wu, wg, e = res
        if ste:
            def exact(xx, wuu, wgg):
                u = jnp.matmul(xx, wuu, preferred_element_type=jnp.float32)
                t = jnp.matmul(xx, wgg, preferred_element_type=jnp.float32)
                return actf(t) * u
            _, vjp = jax.vjp(exact, x, wu, wg)
        else:
            _, vjp = jax.vjp(
                lambda xx, wuu, wgg: qmm_gated_ref(
                    xx, wuu, wgg, actf, block=block, ebits=e), x, wu, wg)
        dx, dwu, dwg = vjp(g)
        return (dx.astype(x.dtype), dwu.astype(wu.dtype),
                dwg.astype(wg.dtype), _float0(e))

    core.defvjp(fwd, bwd)
    return core


def axq_matmul(x2: Array, w, *, block: int = 256, ebits=8,
               bias: Optional[Array] = None, residual: Optional[Array] = None,
               ste: bool = False) -> Array:
    """AXQ GEMM router: x2 (M, K) @ w -> (M, N) f32.

    ``w`` is either a float (K, N) array (trainable: quantized on the fly
    inside a custom-VJP) or a :class:`PackedQWeight` (quantize-once
    residency: per-call work is activation quantization only; inference).
    ``bias`` (N,) / ``residual`` (M, N) fuse into the kernel's f32 writeback
    only on the *packed* pallas route (the inference hot path); the float
    (training) route and the jnp fallback apply them as the same-ordered f32
    adds after the matmul, so every route computes identical values."""
    route = _gemm_route()
    _record_route("gemm", route)
    e = jnp.asarray(ebits, jnp.int32)
    x2 = x2.astype(jnp.float32)
    if isinstance(w, PackedQWeight):
        if route == "pallas":
            return _axq.axqmm_packed(x2, w, e, bias=bias, residual=residual)
        y = qmm_packed_ref(x2, w.qw, w.scales, e)
        if bias is not None:
            y = y + bias.astype(jnp.float32)[None, :]
        if residual is not None:
            y = y + residual.astype(jnp.float32)
        return y
    blk = resolve_block(x2.shape[-1], block)
    y = _axq_core(blk, route, ste)(x2, w.astype(jnp.float32), e)
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y


def axq_gated(x2: Array, w_up, w_gate, *, act: str = "silu",
              block: int = 256, ebits=8, ste: bool = False) -> Array:
    """Fused gated-MLP first-half router: ``act(x@w_gate) * (x@w_up)``.
    Same float-vs-packed contract as :func:`axq_matmul`; the pallas route
    streams one shared x tile through both GEMMs and gates in-VMEM."""
    route = _gemm_route()
    _record_route("gated", route)
    e = jnp.asarray(ebits, jnp.int32)
    x2 = x2.astype(jnp.float32)
    if isinstance(w_up, PackedQWeight):
        if route == "pallas":
            return _axq.axqmm_gated_packed(x2, w_up, w_gate, e, act=act)
        return qmm_gated_packed_ref(x2, w_up.qw, w_up.scales, w_gate.qw,
                                    w_gate.scales, _axq._ACTS[act], e)
    blk = resolve_block(x2.shape[-1], block)
    return _axq_gated_core(blk, route, act, ste)(
        x2, w_up.astype(jnp.float32), w_gate.astype(jnp.float32), e)


# ---------------------------------------------------------------------------
# DSP routing (approximate FIR / conv2d — the Ch. 7 accelerators)
# ---------------------------------------------------------------------------


def _pr_knobs(degree, p, r):
    """Resolve the PR knobs: either a ladder ``degree`` (effective bits,
    mapped via ``dsp.degree_to_pr``) or explicit raw (p, r) — not both."""
    from repro.kernels import dsp as _dsp

    if degree is not None:
        if p is not None or r is not None:
            raise ValueError("pass either degree= or explicit p=/r=, not both")
        return _dsp.degree_to_pr(degree)
    return (jnp.int32(0) if p is None else jnp.asarray(p, jnp.int32),
            jnp.int32(0) if r is None else jnp.asarray(r, jnp.int32))


def fir(x, taps, *, tail=None, degree=None, p=None, r=None, n: int = 16,
        shift: int = 0):
    """Approximate-FIR router (DyFXU PR datapath): pallas kernel vs the
    bit-identical jnp ref, selected like every other site (``REPRO_KERNELS``
    / :func:`set_backend`), recorded under ``last_route["fir"]``.

    Two call modes (int32 operands; the float/differentiable entry is
    :func:`fir_approx`):

    * offline / valid-mode (``tail=None``): ``x`` is a whole (L,) signal;
      host-side int64 accumulation (arbitrary Q14 operands), returns a
      numpy (L - T,) array.  Benchmarks and examples.
    * streaming (``tail`` given): ``x`` (B, L) frame batch, ``tail``
      (B, T-1) carried history; jit-safe int32 accumulation (taps l1 norm
      <= ``2**shift``), returns ``(y, new_tail)``.  The serve engine.

    ``degree`` is the ladder knob (None = exact, traced scalar = runtime
    rung); raw (p, r) may be passed instead for sweep-style benches."""
    from repro.kernels import dsp as _dsp

    backend = "pallas" if use_pallas() else "xla"
    _record_route("fir", backend)
    pk, rk = _pr_knobs(degree, p, r)
    interp = interpret_mode()
    if tail is None:
        return _dsp.fir_valid(x, taps, pk, rk, n=n, backend=backend,
                              interpret=interp)
    return _dsp.fir_frames(x, tail, taps, pk, rk, n=n, shift=shift,
                           backend=backend, interpret=interp)


def conv2d(img, kern, *, degree=None, p=None, r=None, n: int = 16,
           shift: int = 0, pad: str = "zero"):
    """Approximate-conv2d router (same-size 2D correlation on the PR
    datapath): img (B, H, W) int32, kern (kh, kw) int32 with l1 norm <=
    ``2**shift``; jit-safe, recorded under ``last_route["conv2d"]``.  Same
    degree/knob contract as :func:`fir`."""
    from repro.kernels import dsp as _dsp

    backend = "pallas" if use_pallas() else "xla"
    _record_route("conv2d", backend)
    pk, rk = _pr_knobs(degree, p, r)
    return _dsp.conv2d_pr(img, kern, pk, rk, n=n, shift=shift, pad=pad,
                          backend=backend, interpret=interpret_mode())


@functools.lru_cache(maxsize=None)
def _fir_core(T: int, q: int, n: int, route: str, interp: bool):
    """Differentiable float FIR core, cached per (taps, Q format, backend):
    quantize -> PR streaming kernel -> dequantize forward; exact-correlation
    STE backward (the PR bit surgery is piecewise-constant), ``_float0``
    cotangents for the integer knobs — the GEMM ``_axq_core`` pattern."""
    from repro.kernels import dsp as _dsp

    scale = float(1 << q)
    lim = float((1 << (n - 1)) - 1)

    def run(x, t, pk, rk):
        xq = jnp.clip(jnp.round(x * scale), -lim, lim).astype(jnp.int32)
        tq = jnp.clip(jnp.round(t * scale), -lim, lim).astype(jnp.int32)
        tail = jnp.zeros((x.shape[0], T - 1), jnp.int32)
        y, _ = _dsp.fir_frames(xq, tail, tq, pk, rk, n=n, shift=0,
                               backend=route, interpret=interp)
        return y.astype(jnp.float32) / (scale * scale)

    core = jax.custom_vjp(run)

    def exact(x, t):
        ext = jnp.concatenate(
            [jnp.zeros((x.shape[0], T - 1), x.dtype), x], axis=1)
        win = jnp.stack([ext[:, i:i + x.shape[1]] for i in range(T)])
        return jnp.einsum("i,ibl->bl", t, win)

    def fwd(x, t, pk, rk):
        return run(x, t, pk, rk), (x, t)

    def bwd(res, g):
        x, t = res
        _, vjp = jax.vjp(exact, x, t)
        dx, dt = vjp(g)
        return dx, dt, _float0(jnp.int32(0)), _float0(jnp.int32(0))

    core.defvjp(fwd, bwd)
    return core


def fir_approx(x: Array, taps: Array, *, degree=None, q: int = 12,
               n: int = 16) -> Array:
    """Differentiable float FIR entry (custom-VJP like the GEMM routes):
    x (B, L) f32 in ~[-1, 1], taps (T,) f32 with |l1| <~ 1 (so Q-``q``
    products fit int32 lanes).  Zero-history causal filtering; forward runs
    the int PR datapath, backward is the exact correlation (STE)."""
    route = "pallas" if use_pallas() else "xla"
    _record_route("fir", route)
    pk, rk = _pr_knobs(degree, None, None)
    return _fir_core(int(taps.shape[0]), q, n, route, interpret_mode())(
        x.astype(jnp.float32), taps.astype(jnp.float32), pk, rk)
