"""Kernel backend dispatch: the single routing point between the model
attention call sites and the Pallas kernels (DESIGN.md §8).

Backend selection — ``REPRO_KERNELS`` env var, overridable per-process via
:func:`set_backend` (``launch.serve``/``launch.train`` ``--kernels`` flag,
``bench_serving`` A/B):

  pallas   always route qualifying shapes through the Pallas kernels
           (interpret-mode emulation off-TPU: correctness/step-count work)
  xla      always the pure-jnp paths (models/attention.py)
  auto     pallas on TPU, xla elsewhere (default — CPU CI stays on the
           fast jnp paths, TPU gets the kernels with ``interpret=False``)

``interpret`` is resolved per backend (``jax.default_backend() != "tpu"``)
instead of the old hardcoded ``True``.

Routing contract:

  * :func:`prefill_attention` — the model-layout (B, S, H, D) GQA entry for
    full-sequence attention (train forward, fused serve prefill).  Qualifies
    when causal or un-windowed (the kernel's grids); GQA is flattened to the
    kernel's (BH, S, D) layout (kv heads repeated — the kernel layout
    contract; the jnp fallback keeps the grouped never-materialized form).
    Differentiable: routes through ``flash_attention_vjp``.
  * :func:`decode_attention` — single-token decode against a KVCache /
    QuantKVCache, routed to kernels/flash_decode.py with free-slot masking
    and the runtime ebits degree; falls back to decode_attn(_quant).

``last_route`` records the decision per site for tests/benchmarks.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_vjp
from repro.kernels.flash_decode import decode_attn_flash

Array = jnp.ndarray

_VALID = ("auto", "pallas", "xla")

_override: Optional[str] = None

#: last routing decision per call site ("prefill" / "decode") — debug aid
#: for tests and benchmarks, written at trace time.
last_route: dict = {}


def set_backend(name: Optional[str]) -> None:
    """Process-wide override of ``REPRO_KERNELS`` (None -> back to env).
    Takes effect for functions traced afterwards (the serve engine traces
    its fused step at construction, so build engines after switching)."""
    global _override
    if name is not None and name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    _override = name


def backend_setting() -> str:
    setting = _override or os.environ.get("REPRO_KERNELS", "auto")
    if setting not in _VALID:
        raise ValueError(
            f"REPRO_KERNELS must be one of {_VALID}, got {setting!r}")
    return setting


def resolved_backend() -> str:
    """'pallas' or 'xla' after resolving 'auto' against the live platform."""
    setting = backend_setting()
    if setting == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return setting


def use_pallas() -> bool:
    return resolved_backend() == "pallas"


def interpret_mode() -> bool:
    """Pallas interpret flag for the current platform (auto, not hardcoded).
    Single source of truth: flash_attention._resolve_interpret (shared by
    both kernels)."""
    from repro.kernels.flash_attention import _resolve_interpret

    return _resolve_interpret(None)


# ---------------------------------------------------------------------------
# call-site routers
# ---------------------------------------------------------------------------


def prefill_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: Optional[int] = None) -> Array:
    """Full-sequence GQA attention, model layout: q (B, S, H, D),
    k/v (B, S, KVr, D) -> (B, S, H, D)."""
    from repro.models import attention as attn  # lazy: kernels<->models layering

    B, S, H, D = q.shape
    qualifies = use_pallas() and S > 1 and (causal or window is None)
    last_route["prefill"] = "pallas" if qualifies else "xla"
    if not qualifies:
        return attn.attn_blockwise(q, k, v, causal=causal, window=window)
    kf = attn.repeat_kv(k, H)
    vf = attn.repeat_kv(v, H)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    o = flash_attention_vjp(flat(q), flat(kf), flat(vf), causal, window)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def decode_attention(q1: Array, knew: Array, vnew: Array, cache, *,
                     window: Optional[int] = None, degree=None, active=None):
    """Single-token decode against the cache: q1 (B, 1, H, D),
    knew/vnew (B, 1, KVr, D) -> (out (B, 1, H, D), advanced cache).

    ``degree``: runtime ebits knob (int8 cache dequant degrade on the pallas
    path; the jnp path dequantizes exactly).  ``active``: (B,) bool free-slot
    mask (pallas path zeroes masked outputs; the jnp path computes and lets
    the engine discard them).
    """
    from repro.models import attention as attn

    if use_pallas():
        last_route["decode"] = "pallas"
        return decode_attn_flash(q1, knew, vnew, cache, window=window,
                                 active=active, degree=degree)
    last_route["decode"] = "xla"
    if isinstance(cache, attn.QuantKVCache):
        return attn.decode_attn_quant(q1, knew, vnew, cache, window=window)
    return attn.decode_attn(q1, knew, vnew, cache, window=window)
