"""Quantized-weight residency: the quantize-once prepack layer (DESIGN.md §9).

The dissertation's accelerators (and the ASIC/FPGA designs surveyed in
arXiv:2307.11128 / arXiv:2203.08737) encode the *static* operand once, at
configuration time; only the cheap runtime knob (DyFXU effective bits) varies
per invocation.  The software embodiment before this module inverted that
cost model: every ``approx_matmul`` call re-quantized the weight operand from
f32 — O(K·N) quantize work per matmul per step plus a live f32 copy.

This module restores the hardware cost model:

  * :class:`PackedQWeight` — AXQ weights as ``(int8 qw K-major, f32
    per-(row, k-block) scales)``; bit-identical to what the on-the-fly path
    produces in-trace (same ``quantize_block``), so swapping prepacked params
    in changes *when* quantization happens, never *what* is computed.
  * :class:`PackedEmulWeight` — the *_EMUL modes' per-tensor int8 weight with
    the static operand transform (perforation / RAD / ROUP encoding) already
    applied; again bit-identical to the per-call transform.
  * :func:`prepack_params` — walks any model family's param tree (transformer
    / MoE / SSM / RG-LRU hybrid, scan-stacked or not) and packs every dense
    weight whose policy spec is AXQ or *_EMUL.  Call it at init,
    checkpoint-load, or serve admission (``ServeEngine`` does, and
    ``Model.prepack`` is the public hook).
  * :func:`resolve_block` — the single, cached, loud-failure resolution of
    the quantization block against a contraction dim (replaces the in-trace
    ``while K % block: block //= 2`` loop that silently recomputed per call
    and span forever on ``block == 0``).

Prepacked leaves are plain NamedTuples of arrays — jit/scan/vmap/shard_map
slice and batch them like any pytree; the static ``block`` is derived from
the array shapes, never carried as a traced leaf.

Per-layer plans (repro.tune) compose with residency for free: packing is
degree-independent (the int8 values are always full-precision-int8; the
runtime ``ebits`` degrade happens in-kernel on the packed values), so ONE
packed tree serves *every* rung of a plan's degree ladder — per-layer
degrees are scalar-prefetch operands sliced from the plan vector
(models/degrees.py), never a reason to repack or recompile.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import encodings as enc
from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.core.quantization import quantize_block

Array = jnp.ndarray

_EMUL_MODES = (ApproxMode.PR_EMUL, ApproxMode.RAD_EMUL, ApproxMode.ROUP_EMUL)


@functools.lru_cache(maxsize=None)
def resolve_block(K: int, requested: int) -> int:
    """Largest power-of-two shrink of ``requested`` that divides ``K``.

    Cached per (K, requested) — shapes are static under trace, so the loop
    runs once per distinct GEMM geometry instead of on every call site
    retrace.  Fails loudly instead of looping forever / dividing by zero on
    a non-positive block.
    """
    if requested <= 0:
        raise ValueError(f"quantization block must be positive, got {requested}")
    if K <= 0:
        raise ValueError(f"contraction dim must be positive, got {K}")
    block = min(requested, K)
    while K % block:
        block //= 2
        if block == 0:  # unreachable for block>=1 (K % 1 == 0): keep it loud
            raise ValueError(f"no block divides K={K} (requested {requested})")
    return block


class PackedQWeight(NamedTuple):
    """AXQ weight residency: int8 values K-major + per-(row, k-block) scales.

    ``qw``: (..., N, K) int8 — the kernel's "wT" layout, both operands stream
    contiguous k-blocks; ``scales``: (..., N, K // block) f32.
    """

    qw: Array
    scales: Array

    @property
    def k(self) -> int:
        return self.qw.shape[-1]

    @property
    def n(self) -> int:
        return self.qw.shape[-2]

    @property
    def block(self) -> int:
        return self.qw.shape[-1] // self.scales.shape[-1]


class PackedEmulWeight(NamedTuple):
    """*_EMUL weight residency: per-tensor int8 with the static operand
    transform (perforation / RAD / ROUP encoding) pre-applied.

    ``qw``: (..., K, N) int8; ``scale``: (...,) f32 per leading slice (one
    scalar per scan-stacked layer).
    """

    qw: Array
    scale: Array

    @property
    def k(self) -> int:
        return self.qw.shape[-2]

    @property
    def n(self) -> int:
        return self.qw.shape[-1]


def is_packed(w) -> bool:
    return isinstance(w, (PackedQWeight, PackedEmulWeight))


# ---------------------------------------------------------------------------
# single-weight prepack
# ---------------------------------------------------------------------------


def prepack_weight(w: Array, block: int) -> PackedQWeight:
    """Quantize-once AXQ pack of ``w`` (..., K, N) — bit-identical to the
    on-the-fly path (same :func:`quantize_block` on the same K-major view).
    Leading dims (scan-stacked layers, experts) quantize per slice."""
    wT = jnp.swapaxes(jnp.asarray(w).astype(jnp.float32), -1, -2)
    qt = quantize_block(wT, block)
    return PackedQWeight(qt.values, qt.scales)


def _quantize_per_tensor_sliced(w: Array, bits: int):
    """Per-tensor symmetric quantization over the trailing (K, N) dims —
    per *slice* for stacked weights, matching the per-call quantization of
    each layer's 2-D weight."""
    qmax = (1 << (bits - 1)) - 1
    w = jnp.asarray(w).astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=(-2, -1)), 1e-30)
    scale = amax / qmax
    q = jnp.clip(jnp.round(w / scale[..., None, None]), -qmax, qmax)
    return q.astype(jnp.int32), scale


def emul_weight_transform(qw: Array, spec: ApproxSpec) -> Array:
    """The static weight-operand transform of the *_EMUL modes (int32 lanes).
    Shared verbatim by the on-the-fly path and the prepack — the single
    source of bit-identity between them."""
    n = spec.lane_bits
    if spec.mode == ApproxMode.PR_EMUL:
        return enc.perforate_operand(qw, n, spec.p) if spec.p else qw
    if spec.mode == ApproxMode.RAD_EMUL:
        return enc.rad_encode(qw, n, spec.k)
    if spec.mode == ApproxMode.ROUP_EMUL:
        qw = enc.rad_encode(qw, n, spec.k)
        # perforation of radix-4 digits above the high-radix digit
        if spec.p:
            y0 = enc.highradix_digit(qw, n, spec.k)
            high = qw - y0
            qw = enc.perforate_operand(high, 2 * n, spec.k // 2 + spec.p) + y0
        return qw
    raise ValueError(f"not an emulation mode: {spec.mode}")


def prepack_emul_weight(w: Array, spec: ApproxSpec) -> PackedEmulWeight:
    """Quantize + transform the weight operand once for a *_EMUL spec."""
    assert spec.lane_bits <= 8, "emulation lane limited to 8 bits (ops.py)"
    qw, scale = _quantize_per_tensor_sliced(w, spec.lane_bits)
    qw = emul_weight_transform(qw, spec)
    # the exact-integer matmul ingests int8 lanes; the cast is part of the
    # contract (identical to the per-call `qw.astype(int8)`)
    return PackedEmulWeight(qw.astype(jnp.int8), scale)


def pack_for_spec(w: Array, spec: ApproxSpec):
    """Pack one (..., K, N) weight for ``spec``; returns ``w`` unchanged for
    specs with no static operand encoding (EXACT / POW2_W)."""
    if is_packed(w):
        return w
    if spec.mode == ApproxMode.AXQ:
        return prepack_weight(w, resolve_block(w.shape[-2], spec.block))
    if spec.mode in _EMUL_MODES:
        return prepack_emul_weight(w, spec)
    return w


# ---------------------------------------------------------------------------
# param-tree walkers (per model family)
# ---------------------------------------------------------------------------


def _pack_dense(p: dict, path: str, policy: ApproxPolicy) -> dict:
    """Pack one init_dense param dict ({"w": arr[, "b": arr]})."""
    spec = policy.spec_for(path)
    packed = pack_for_spec(p["w"], spec)
    if packed is p["w"]:
        return p
    return {**p, "w": packed}


def _pack_gated_mlp(p: dict, path: str, policy: ApproxPolicy) -> dict:
    return {k: _pack_dense(v, f"{path}/{k}", policy) for k, v in p.items()}


def _pack_embed(p: dict, policy: ApproxPolicy) -> dict:
    """Tied unembedding: logits = x @ emb.T, so the K-major pack of ``emb.T``
    is ``emb`` itself.  The pack rides inside the embed dict under
    ``unembed_q``; the token-lookup ``emb`` stays untouched."""
    spec = policy.spec_for("unembed")
    if spec.mode == ApproxMode.EXACT or "unembed_q" in p:
        return p
    packed = pack_for_spec(jnp.swapaxes(p["emb"], -1, -2), spec)
    if packed is None or not is_packed(packed):
        return p
    return {**p, "unembed_q": packed}


def _pack_transformer(params: dict, cfg, policy: ApproxPolicy) -> dict:
    out = dict(params)
    layers = dict(params["layers"])
    for key in ("wq", "wk", "wv", "wo"):
        layers[key] = _pack_dense(layers[key], f"layer/{key}", policy)
    if "mlp" in layers:
        layers["mlp"] = _pack_gated_mlp(layers["mlp"], "layer/mlp", policy)
    if "moe" in layers:
        moe = dict(layers["moe"])
        # expert spec shared with apply time (incl. the REPRO_MOE_INT8
        # EXACT->AXQ8 promotion) — pack iff the experts will route AXQ
        from repro.models.moe import expert_spec  # lazy: layering

        espec = expert_spec(policy, "layer/moe")
        if espec.mode == ApproxMode.AXQ:
            moe["experts"] = {
                k: pack_for_spec(w, espec) for k, w in moe["experts"].items()
            }
        if "shared" in moe:
            moe["shared"] = {
                k: pack_for_spec(w, policy.spec_for(f"layer/moe/shared/{k}"))
                for k, w in moe["shared"].items()
            }
        layers["moe"] = moe
    out["layers"] = layers
    for fe, n_fc in (("v_proj", ("fc1", "fc2")), ("a_proj", ("fc1",))):
        if fe in params:
            out[fe] = {k: _pack_dense(params[fe][k], f"{fe}/{k}", policy)
                       for k in n_fc}
    if "unembed" in params:
        out["unembed"] = _pack_dense(params["unembed"], "unembed", policy)
    elif cfg.tie_embeddings:
        out["embed"] = _pack_embed(params["embed"], policy)
    return out


def _pack_ssm(params: dict, cfg, policy: ApproxPolicy) -> dict:
    out = dict(params)
    layers = dict(params["layers"])
    for key in ("in_proj", "out_proj"):
        layers[key] = _pack_dense(layers[key], f"layer/{key}", policy)
    out["layers"] = layers
    out["embed"] = _pack_embed(params["embed"], policy)
    return out


def _pack_rec_block(bp: dict, path: str, policy: ApproxPolicy) -> dict:
    out = dict(bp)
    for key in ("wx", "wg", "wa", "wi", "wo"):
        out[key] = _pack_dense(bp[key], f"{path}/{key}", policy)
    out["mlp"] = _pack_gated_mlp(bp["mlp"], f"{path}/mlp", policy)
    return out


def _pack_attn_block(bp: dict, path: str, policy: ApproxPolicy) -> dict:
    out = dict(bp)
    for key in ("wq", "wk", "wv", "wo"):
        out[key] = _pack_dense(bp[key], f"{path}/{key}", policy)
    if "mlp" in bp:
        out["mlp"] = _pack_gated_mlp(bp["mlp"], f"{path}/mlp", policy)
    return out


def _pack_hybrid(params: dict, cfg, policy: ApproxPolicy) -> dict:
    # packs resolve against the serve-time paths ("g/...", "tail/...") —
    # the ones prefill/decode dispatch through (rglru.py)
    out = dict(params)
    groups = dict(params["groups"])
    for gkey, gp in groups.items():
        if gkey.startswith("rec"):
            groups[gkey] = _pack_rec_block(gp, "g", policy)
        else:
            groups[gkey] = _pack_attn_block(gp, "g", policy)
    out["groups"] = groups
    out["tail"] = [_pack_rec_block(bp, "tail", policy) for bp in params["tail"]]
    out["unembed"] = _pack_dense(params["unembed"], "unembed", policy)
    return out


def prepack_params(params: dict, cfg, policy: ApproxPolicy) -> dict:
    """Quantize-once pass over a model param tree: every dense weight whose
    policy spec carries a static operand encoding (AXQ / *_EMUL) is replaced
    by its packed residency form.  Idempotent; EXACT-only policies return the
    tree with every array untouched.  The result is inference-only — packed
    leaves are int8 and carry no gradients."""
    if cfg.family == "ssm":
        return _pack_ssm(params, cfg, policy)
    if cfg.family == "hybrid":
        return _pack_hybrid(params, cfg, policy)
    return _pack_transformer(params, cfg, policy)
