"""Fused single-token decode attention against the serving KV cache.

Replaces the pure-jnp ``decode_attn`` / ``decode_attn_quant`` full-``T_max``
einsum in the engine's fused step (kernels/dispatch.py routes the call).

Grid (B, KVr, n_t): t innermost walks the slot's cache region in ``bt``-sized
tiles with online-softmax scratch per (slot, kv-head); the q block is the
whole GQA group (G, D), so grouped query heads share each loaded kv tile.
Tiles that start beyond the slot's valid length are *skipped at runtime*
(``pl.when`` on the scalar-prefetched length — the dissertation's
computation-skipping pillar keyed on per-slot serving state, not a static
shape).  Block specs read the cache natively as (B, T, KVr, D); no transpose
or repeat_kv materialization on the decode path.

The int8 variant dequantizes tiles in-kernel — HBM holds int8, the
per-(token, head) scales ride along D x smaller — and first applies the
runtime effective-bits degrade to the integer mantissas: ``axqmm``'s DyFXU
scalar-prefetch knob (``ebits``) at the attention operand, so the QoS
controller's degree ladder reaches the decode hot loop with zero recompiles.

Slot semantics mirror ``models.attention.decode_attn``: the (ring-)buffer
write of the new token happens *outside* the kernel (a cheap scatter);
``nvalid = min(length + 1, T)`` already counts the just-written token, and
softmax over the valid set is permutation-invariant, so ring wraparound
order never matters.  Free slots (``active == 0``) produce exact-zero
outputs — the engine discards them, but they can never leak NaNs from an
uninitialized output block.

Validated vs decode_attn/decode_attn_quant incl. ring wraparound and
freed-slot masking (tests/test_flash_decode.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.axqmm import _degrade_tile
from repro.kernels.flash_attention import NEG_INF, _resolve_interpret

Array = jnp.ndarray


def _tiles(T: int, bt: int) -> tuple[int, int]:
    """(bt, n_t) with a ragged final tile when bt does not divide T — the
    cache is never padded or re-tiled per step; out-of-bounds lanes of the
    last tile are masked in-kernel (``cols < nvalid`` plus the v sanitize),
    so an odd cache capacity keeps full-width tiles instead of degrading
    toward 1-token tiles."""
    bt = min(bt, T)
    return bt, -(-T // bt)


def _online_block(s, v, acc_ref, m_ref, l_ref):
    """One online-softmax accumulation step; s (G, bt) pre-masked."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def _finish(o_ref, acc_ref, l_ref, active_ref, b):
    act = (active_ref[b] > 0).astype(jnp.float32)
    o_ref[0, 0] = (act * acc_ref[...] /
                   jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _decode_kernel(nvalid_ref, active_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, n_t: int, bt: int, scale: float):
    b, t = pl.program_id(0), pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    nv = nvalid_ref[b]

    @pl.when(t * bt < nv)          # runtime skip: tile wholly past the length
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bt, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bt)
        cols = t * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
        s = jnp.where(cols < nv, s, NEG_INF)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        # sanitize past-length rows: a ragged final tile reads out of bounds
        # (undefined lanes) and 0 * NaN would poison the p @ v accumulation
        v = jnp.where(cols.reshape(bt, 1) < nv, v, 0.0)
        _online_block(s, v, acc_ref, m_ref, l_ref)

    @pl.when(t == n_t - 1)
    def _done():
        _finish(o_ref, acc_ref, l_ref, active_ref, b)


def _decode_kernel_quant(ebits_ref, nvalid_ref, active_ref, q_ref,
                         k_ref, ks_ref, v_ref, vs_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, n_t: int, bt: int,
                         scale: float):
    b, t = pl.program_id(0), pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    nv = nvalid_ref[b]

    @pl.when(t * bt < nv)
    def _compute():
        shift = jnp.maximum(8 - ebits_ref[0], 0)
        q = q_ref[0, 0].astype(jnp.float32) * scale                  # (G, D)
        kq = _degrade_tile(k_ref[0, :, 0, :].astype(jnp.int32), shift)
        k = kq.astype(jnp.float32) * ks_ref[0, :, 0][:, None]        # (bt, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = t * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
        s = jnp.where(cols < nv, s, NEG_INF)
        vq = _degrade_tile(v_ref[0, :, 0, :].astype(jnp.int32), shift)
        v = vq.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        # sanitize past-length rows (ragged final tile: undefined lanes)
        v = jnp.where(cols.reshape(bt, 1) < nv, v, 0.0)
        _online_block(s, v, acc_ref, m_ref, l_ref)

    @pl.when(t == n_t - 1)
    def _done():
        _finish(o_ref, acc_ref, l_ref, active_ref, b)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def flash_decode(qg: Array, k: Array, v: Array, nvalid: Array, active: Array,
                 *, bt: int = 128, interpret: Optional[bool] = None) -> Array:
    """qg: (B, KVr, G, D) grouped queries; k/v: (B, T, KVr, D) cache
    (new token already written); nvalid/active: (B,) int32.
    Returns (B, KVr, G, D) f32."""
    interpret = _resolve_interpret(interpret)
    B, KVr, G, D = qg.shape
    T = k.shape[1]
    bt, n_t = _tiles(T, bt)
    kern = functools.partial(_decode_kernel, n_t=n_t, bt=bt,
                             scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KVr, n_t),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, t, *pf: (b, h, 0, 0)),
                pl.BlockSpec((1, bt, 1, D), lambda b, h, t, *pf: (b, t, h, 0)),
                pl.BlockSpec((1, bt, 1, D), lambda b, h, t, *pf: (b, t, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, t, *pf: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVr, G, D), jnp.float32),
        interpret=interpret,
    )(nvalid.astype(jnp.int32), active.astype(jnp.int32), qg, k, v)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def flash_decode_quant(qg: Array, k: Array, ks: Array, v: Array, vs: Array,
                       nvalid: Array, active: Array, ebits: Array,
                       *, bt: int = 128,
                       interpret: Optional[bool] = None) -> Array:
    """int8 cache variant: k/v (B, T, KVr, D) int8, ks/vs (B, T, KVr) f32
    scales, ebits (1,) int32 runtime degree (8 = exact dequant)."""
    interpret = _resolve_interpret(interpret)
    B, KVr, G, D = qg.shape
    T = k.shape[1]
    bt, n_t = _tiles(T, bt)
    kern = functools.partial(_decode_kernel_quant, n_t=n_t, bt=bt,
                             scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, KVr, n_t),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, t, *pf: (b, h, 0, 0)),
                pl.BlockSpec((1, bt, 1, D), lambda b, h, t, *pf: (b, t, h, 0)),
                pl.BlockSpec((1, bt, 1), lambda b, h, t, *pf: (b, t, h)),
                pl.BlockSpec((1, bt, 1, D), lambda b, h, t, *pf: (b, t, h, 0)),
                pl.BlockSpec((1, bt, 1), lambda b, h, t, *pf: (b, t, h)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, t, *pf: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVr, G, D), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(ebits, jnp.int32).reshape(1), nvalid.astype(jnp.int32),
      active.astype(jnp.int32), qg, k, ks, v, vs)


def decode_attn_flash(q1: Array, knew: Array, vnew: Array, cache, *,
                      window: Optional[int] = None, active=None, degree=None,
                      interpret: Optional[bool] = None):
    """Drop-in for ``models.attention.decode_attn`` / ``decode_attn_quant``
    through the fused kernel.

    q1: (B, 1, H, D); knew/vnew: (B, 1, KVr, D); cache: KVCache or
    QuantKVCache.  ``active`` (B,) bool masks freed slots to zero output;
    ``degree`` is the runtime ebits knob (quant cache only).  Returns
    (out (B, 1, H, D), advanced cache) — same slot/ring math, same length
    semantics as the jnp paths.
    """
    from repro.models import attention as attn  # lazy: kernels<->models layering

    B, _, H, D = q1.shape
    T = cache.k.shape[1]
    kvh = cache.k.shape[2]
    pos = cache.length
    ring = window is not None and window <= T
    slot = jnp.mod(pos, T) if ring else jnp.minimum(pos, T - 1)
    bidx = jnp.arange(B)
    quant = isinstance(cache, attn.QuantKVCache)
    if quant:
        kq, ksn = attn._q8(knew)
        vq, vsn = attn._q8(vnew)
        k = cache.k.at[bidx, slot].set(kq[:, 0])
        v = cache.v.at[bidx, slot].set(vq[:, 0])
        ks = cache.ks.at[bidx, slot].set(ksn[:, 0])
        vs = cache.vs.at[bidx, slot].set(vsn[:, 0])
        new_cache = attn.QuantKVCache(k, v, ks, vs, pos + 1)
    else:
        k = cache.k.at[bidx, slot].set(knew[:, 0].astype(cache.k.dtype))
        v = cache.v.at[bidx, slot].set(vnew[:, 0].astype(cache.v.dtype))
        new_cache = attn.KVCache(k=k, v=v, length=pos + 1)
    qg = attn._group_q(q1, kvh)[:, 0]             # (B, KVr, G, D)
    nvalid = jnp.minimum(pos + 1, T)
    act = (jnp.ones((B,), jnp.int32) if active is None
           else jnp.asarray(active).astype(jnp.int32))
    if quant:
        ebits = jnp.asarray(8 if degree is None else degree, jnp.int32)
        out = flash_decode_quant(qg, k, ks, v, vs, nvalid, act, ebits,
                                 interpret=interpret)
    else:
        out = flash_decode(qg, k, v, nvalid, act, interpret=interpret)
    return out.reshape(B, 1, H, D).astype(q1.dtype), new_cache
