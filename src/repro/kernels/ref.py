"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes/degrees and assert_allclose against
these references (tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import axmult
from repro.core.quantization import qmm_ref  # noqa: F401  (axqmm oracle)

Array = jnp.ndarray


def pr_multiply_ref(a: Array, b: Array, p, r, n: int = 16) -> Array:
    """Oracle for kernels.axmult_elem.pr_multiply: the core-library DyFXU
    emulation (itself validated against the paper's definitions)."""
    return axmult.pr_multiply_dynamic(a, b, n, jnp.asarray(p), jnp.asarray(r))


def axqmm_ref(x: Array, w: Array, block: int = 512, ebits=8) -> Array:
    """Oracle for kernels.axqmm.axqmm (block-quantized effective-bits GEMM)."""
    return qmm_ref(x, w, block=block, ebits=ebits)
