"""Pallas TPU kernels for the perf-critical compute paths + the approx-matmul
dispatch (ops.py) and the attention-kernel backend dispatch (dispatch.py).
ref.py holds the pure-jnp oracles."""
from .dispatch import resolved_backend, set_backend  # noqa: F401
from .ops import approx_matmul  # noqa: F401
