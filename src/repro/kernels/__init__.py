"""Pallas TPU kernels for the perf-critical compute paths + the approx-matmul
dispatch (ops.py).  ref.py holds the pure-jnp oracles."""
from .ops import approx_matmul  # noqa: F401
