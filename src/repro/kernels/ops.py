"""approx_matmul: the single dispatch point between model code and the
paper's approximation techniques (DESIGN.md §3).

Every Dense/einsum in the model zoo calls :func:`approx_matmul`; the
``ApproxSpec`` decides the path:

  EXACT       bf16 dot, f32 accumulation (baseline / dry-run default)
  AXQ         block-quantized int8 GEMM w/ runtime effective-bits degree —
              Pallas kernel on TPU (kernels/axqmm.py), pure-jnp ref on CPU
  PR_EMUL     bit-exact AxFXU emulation: per-tensor int8 quantization, operand
              transforms (round/perforate), exact integer matmul, dequant.
              Because PR transforms each operand independently, the
              approximate-multiplier matmul == exact matmul of transformed
              operands (the paper's accelerators accumulate exactly).
  RAD_EMUL    same with the hybrid high-radix encoding on the weight operand
  ROUP_EMUL   cooperative combination
  POW2_W      weights snapped to powers of two (RAD shift-only insight)

Emulation lane width is limited to 8 bits in-graph (int32 accumulation stays
exact for K <= 2^15); wider studies use core.axmult numpy mirrors.
"""

from __future__ import annotations

import contextlib
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import encodings as enc
from repro.core.approx import ApproxMode, ApproxSpec
from repro.core.quantization import degrade, qmm_ref
from repro.kernels import qstore

Array = jnp.ndarray
# §Perf lever (EXPERIMENTS.md hillclimb A1): keep the activation-gradient
# partial sums in bf16 so GSPMD's TP all-reduces of dx move half the bytes.
# The paper's philosophy applied to the collective layer: trade arithmetic
# exactness of the backward reduction for wire bytes.
_BWD_BF16 = os.environ.get("REPRO_BWD_BF16", "0") == "1"


@jax.custom_vjp
def _matmul_bf16_bwd(x2: Array, w: Array) -> Array:
    # bf16 partials in fwd too: the TP psum of the projection output moves
    # half the bytes (MXU still accumulates f32 internally on real TPU).
    return jnp.matmul(x2.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.bfloat16)


def _mm_fwd(x2, w):
    return _matmul_bf16_bwd(x2, w), (x2, w)


def _mm_bwd(res, g):
    x2, w = res
    g16 = g.astype(jnp.bfloat16)
    # dx partials produced (and hence TP-all-reduced) in bf16
    dx = jnp.matmul(g16, w.astype(jnp.bfloat16).T,
                    preferred_element_type=jnp.bfloat16).astype(x2.dtype)
    dw = jnp.matmul(x2.astype(jnp.bfloat16).T, g16,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


_matmul_bf16_bwd.defvjp(_mm_fwd, _mm_bwd)

# §Perf lever A2 (EXPERIMENTS.md iteration 2): route the TP output reductions
# (wo / mlp-down / out_proj — weights contract over the 'model'-sharded dim)
# through the int8 ring all-reduce: 4x wire bytes, HLO-measurable (integer
# collectives are not float-normalized).  Forward-only; backward stays exact
# via custom_vjp (GSPMD handles dx/dw with standard collectives).
_RING_TP = os.environ.get("REPRO_RING_TP", "0") == "1"


@jax.custom_vjp
def _ring_tp_matmul(x2: Array, w: Array) -> Array:
    from jax.sharding import PartitionSpec as P

    from repro.dist import meshctx
    from repro.dist.collectives import ring_allreduce_int8_local

    mesh = meshctx.get_mesh()
    if mesh.shape["model"] == 1:
        return jnp.matmul(x2, w.astype(x2.dtype),
                          preferred_element_type=jnp.float32)
    b = meshctx.batch_axes(mesh)

    def body(xl, wl):
        acc = jnp.matmul(xl, wl.astype(xl.dtype),
                         preferred_element_type=jnp.float32)
        return ring_allreduce_int8_local(acc, "model")

    # check_vma=False: the ring's all-gather phase leaves every shard with
    # the full reduced value (replicated over 'model'), which the static
    # checker cannot infer through ppermute loops.
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(b if b else None, "model"), P("model", None)),
        out_specs=P(b if b else None, None),
        check_vma=False,
    )(x2, w)


def _ring_fwd(x2, w):
    return _ring_tp_matmul(x2, w), (x2, w)


def _ring_bwd(res, g):
    x2, w = res
    dx = jnp.matmul(g, w.astype(g.dtype).T,
                    preferred_element_type=jnp.float32).astype(x2.dtype)
    dw = jnp.matmul(x2.astype(jnp.float32).T, g.astype(jnp.float32),
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


_ring_tp_matmul.defvjp(_ring_fwd, _ring_bwd)


@jax.custom_vjp
def _ring_dx_matmul(x2: Array, w: Array) -> Array:
    """Column-sharded projection (wq/up/gate: w P(None,'model')) — no fwd
    psum; the dx reduction in backward goes through the int8 ring."""
    return jnp.matmul(x2, w.astype(x2.dtype), preferred_element_type=jnp.float32)


def _ring_dx_fwd(x2, w):
    return _ring_dx_matmul(x2, w), (x2, w)


def _ring_dx_bwd(res, g):
    from jax.sharding import PartitionSpec as P

    from repro.dist import meshctx
    from repro.dist.collectives import ring_allreduce_int8_local

    x2, w = res
    mesh = meshctx.get_mesh()
    dw = jnp.matmul(x2.astype(jnp.float32).T, g.astype(jnp.float32),
                    preferred_element_type=jnp.float32).astype(w.dtype)
    if mesh.shape["model"] == 1:
        dx = jnp.matmul(g, w.astype(g.dtype).T,
                        preferred_element_type=jnp.float32).astype(x2.dtype)
        return dx, dw
    b = meshctx.batch_axes(mesh)

    def body(gl, wl):
        part = jnp.matmul(gl, wl.astype(gl.dtype).T,
                          preferred_element_type=jnp.float32)
        return ring_allreduce_int8_local(part, "model")

    dx = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(b if b else None, "model"), P(None, "model")),
        out_specs=P(b if b else None, None),
        check_vma=False,
    )(g, w).astype(x2.dtype)
    return dx, dw


_ring_dx_matmul.defvjp(_ring_dx_fwd, _ring_dx_bwd)

_RING_PATHS = ("/wo", "/down", "/out_proj")
_RING_DX_PATHS = ("/wq", "/wk", "/wv", "/up", "/gate", "unembed")


@contextlib.contextmanager
def ring_tp(enabled: bool = True):
    """Scoped REPRO_RING_TP: route the TP output reductions through the
    int8 ring while tracing under this context.  The flag is read at trace
    time, so wrapping the *first call* of a jitted step (which compiles
    once) is enough — the sharded serve engine uses this to turn the lever
    on per-engine instead of per-process."""
    global _RING_TP
    prev = _RING_TP
    _RING_TP = bool(enabled)
    try:
        yield
    finally:
        _RING_TP = prev


def _quantize_per_tensor(x: Array, bits: int) -> tuple[Array, Array]:
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def _emul_matmul_packed(x: Array, pw: qstore.PackedEmulWeight,
                        spec: ApproxSpec) -> Array:
    """Exact integer matmul against a prepacked (quantized + transformed)
    emulation weight; only the activation side is quantized/transformed
    per call."""
    n = spec.lane_bits
    assert n <= 8, "in-graph emulation lane limited to 8 bits (see module doc)"
    qx, sx = _quantize_per_tensor(x, n)
    if spec.mode in (ApproxMode.PR_EMUL, ApproxMode.ROUP_EMUL):
        qx = enc.round_operand(qx, spec.r)
    acc = jnp.matmul(
        qx.astype(jnp.int8).astype(jnp.int32),
        pw.qw.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * (sx * pw.scale)


def _emul_matmul(x: Array, w, spec: ApproxSpec) -> Array:
    """Exact integer matmul of technique-transformed quantized operands.
    Float weights are packed on the fly through the same quantize+transform
    the prepack pass runs once (kernels/qstore.py) — prepacked and
    on-the-fly execution are bit-identical by construction."""
    if not isinstance(w, qstore.PackedEmulWeight):
        w = qstore.prepack_emul_weight(w, spec)
    return _emul_matmul_packed(x, w, spec)


def approx_matmul(
    x: Array,
    w,
    spec: ApproxSpec | None = None,
    *,
    degree: Optional[Array] = None,
    out_dtype=None,
    path: str = "",
    bias: Optional[Array] = None,
    residual: Optional[Array] = None,
) -> Array:
    """x @ w through the approximation dispatch.

    x: (..., K); w: (K, N) float — or a prepacked residency form
    (:class:`~repro.kernels.qstore.PackedQWeight` for AXQ,
    :class:`~repro.kernels.qstore.PackedEmulWeight` for the *_EMUL modes):
    quantize-once weights skip the per-call quantize+transpose entirely.
    `degree` is the runtime DyFXU knob (traced int32 scalar, effective bits
    for AXQ dynamic mode); ignored by static specs.  `path` lets the ring-TP
    lever recognize contracting-sharded projections.  ``bias`` (N,) and
    ``residual`` (..., N) are AXQ-only epilogue operands, added in f32
    before the output cast (fused into the kernel writeback on the Pallas
    route).
    """
    spec = spec or ApproxSpec()
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    packed = qstore.is_packed(w)
    N = w.n if packed else w.shape[-1]
    if spec.mode != ApproxMode.AXQ and (bias is not None or residual is not None):
        raise ValueError("bias/residual epilogues are AXQ-only (fused path)")

    if spec.mode == ApproxMode.EXACT:
        if packed:
            raise ValueError(
                f"prepacked weight reached an EXACT spec at {path!r} — the "
                "prepack policy and the apply policy disagree")
        if _RING_TP and path.endswith(_RING_PATHS):
            y = _ring_tp_matmul(x2, w)
        elif _RING_TP and path.endswith(_RING_DX_PATHS):
            y = _ring_dx_matmul(x2, w)
        elif _BWD_BF16:
            y = _matmul_bf16_bwd(x2, w)
        else:
            y = jnp.matmul(x2, w.astype(x2.dtype),
                           preferred_element_type=jnp.float32)
    elif spec.mode == ApproxMode.AXQ:
        if packed and not isinstance(w, qstore.PackedQWeight):
            raise ValueError(f"AXQ spec at {path!r} got {type(w).__name__}")
        from repro.kernels import dispatch as kdispatch  # lazy: import cycle

        e = degree if (spec.dynamic and degree is not None) else spec.ebits
        res2 = None if residual is None else residual.reshape(-1, N)
        y = kdispatch.axq_matmul(x2, w, block=spec.block, ebits=e,
                                 bias=bias, residual=res2)
    elif spec.mode in (ApproxMode.PR_EMUL, ApproxMode.RAD_EMUL, ApproxMode.ROUP_EMUL):
        if packed and not isinstance(w, qstore.PackedEmulWeight):
            raise ValueError(f"emul spec at {path!r} got {type(w).__name__}")
        y = _emul_matmul(x2.astype(jnp.float32), w, spec)
    elif spec.mode == ApproxMode.POW2_W:
        if packed:
            raise ValueError(f"prepacked weight reached a POW2_W spec at {path!r}")
        w2 = enc.pow2_snap(w.astype(jnp.float32)).astype(x2.dtype)
        y = jnp.matmul(x2, w2, preferred_element_type=jnp.float32)
    else:
        raise ValueError(spec.mode)
    return y.reshape(*lead, N).astype(out_dtype)


def approx_gated_matmul(x: Array, w_up, w_gate, spec: ApproxSpec, *,
                        act: str = "silu", degree: Optional[Array] = None,
                        out_dtype=None) -> Array:
    """Fused gated-MLP first half ``act(x @ w_gate) * (x @ w_up)`` through
    the AXQ dispatch — one kernel, one shared x stream, gate applied
    in-VMEM before writeback (DESIGN.md §9).  Weights float or prepacked."""
    assert spec.mode == ApproxMode.AXQ, spec.mode
    from repro.kernels import dispatch as kdispatch  # lazy: import cycle

    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    N = w_up.n if qstore.is_packed(w_up) else w_up.shape[-1]
    e = degree if (spec.dynamic and degree is not None) else spec.ebits
    y = kdispatch.axq_gated(x2, w_up, w_gate, act=act, block=spec.block,
                            ebits=e)
    return y.reshape(*lead, N).astype(out_dtype)
