"""Approximate DSP compute cores on the PR multiplier (Ch. 7 accelerators).

The dissertation's DSP accelerators — 1D FIR filtering and 2D convolution —
are product-sum pipelines over the Ch. 5 PR (perforation + rounding)
multiplier.  This module holds the *compute cores* behind the
``kernels.dispatch.fir`` / ``dispatch.conv2d`` routers: the batched operand
layout (all taps / all kernel offsets stacked into ONE elementwise PR call),
the pad-to-block plumbing the Pallas kernel requires, and a pure-jnp mirror
of the kernel's bit math so the ``xla`` backend is bit-identical to the
``pallas`` one (the same oracle contract the AXQ GEMMs satisfy).

Operand convention (weight-stationary accelerator): the *weights* (FIR taps,
conv kernel) are the rounded operand A, the *samples* (signal, pixels) the
perforated operand B — matching ``axmult_elem._pr_kernel``'s (a, b) roles
and the Ch. 7 datapath, where the configuration registers degrade the
stationary operand path and the streaming operand path independently.

Fixed-point safety: accumulation stays in int32 lanes (TPU-native), so
streaming entry points require the weight vector's l1 norm to fit
``2**shift`` — quantizing weights with ``quantize_weights`` guarantees
``|sum_i w_i * x_i| <= 2**shift * max|x|`` and the post-sum ``>> shift``
returns the result to the input's Q format.  The offline ``fir_valid`` entry
(benchmarks, arbitrary Q14 operands) accumulates host-side in int64 instead.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.axmult_elem import pr_multiply

Array = jnp.ndarray

#: the Pallas kernel's flat block size (axmult_elem contract: total % block == 0)
PR_BLOCK = 2048


def degree_to_pr(degree, n: int = 16):
    """Map an effective-bits degree (8 = exact, down the QoS ladder) to the
    DyFXU (p, r) configuration registers: each lost bit costs two rounding
    bits and every second lost bit one perforation step —
    ``e=8 -> (0,0), 7 -> (0,2), 6 -> (1,4), 5 -> (1,6), 4 -> (2,8)``.
    ``degree`` may be None (exact) or a traced int32 scalar (zero-recompile
    contract); returns traced (p, r) int32 scalars."""
    if degree is None:
        return jnp.int32(0), jnp.int32(0)
    d = jnp.maximum(8 - jnp.asarray(degree, jnp.int32), 0)
    return d // 2, 2 * d


def quantize_weights(w, shift: int):
    """Quantize a float weight vector/kernel so its l1 norm is <= 2**shift
    (int32-safe accumulation for Q-``shift`` samples): returns int32 weights
    whose product-sum dequantizes via ``>> shift``."""
    w = np.asarray(w, np.float64)
    scale = float(1 << shift) / max(float(np.abs(w).sum()), 1e-30)
    return np.round(w * scale).astype(np.int32)


def pr_multiply_ref(a: Array, b: Array, p, r, *, n: int = 16) -> Array:
    """Pure-jnp mirror of ``axmult_elem._pr_kernel`` — the xla-route twin,
    bit-identical to the Pallas kernel (integer bit math has no tolerance)."""
    p = jnp.asarray(p, jnp.int32)
    r = jnp.asarray(r, jnp.int32)
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    # rounding: A_r = (floor(A / 2^r) + a_{r-1}) * 2^r  (r = 0 -> identity)
    rbit = jnp.where(r > 0,
                     jnp.bitwise_and(jnp.right_shift(a, jnp.maximum(r - 1, 0)), 1),
                     0)
    a_r = jnp.where(r > 0, jnp.left_shift(jnp.right_shift(a, r) + rbit, r), a)
    # perforation: B' = B - (B mod 2^{2p}) + 2^{2p} * b_{2p-1}
    u = jnp.bitwise_and(b, (1 << n) - 1)
    two_p = jnp.left_shift(jnp.int32(1), 2 * p)
    low = jnp.bitwise_and(u, two_p - 1)
    cbit = jnp.bitwise_and(jnp.right_shift(u, jnp.maximum(2 * p - 1, 0)), 1)
    b_p = jnp.where(p > 0, b - low + cbit * two_p, b)
    return a_r * b_p


def pr_product(a: Array, b: Array, p, r, *, n: int = 16,
               backend: str = "xla", interpret: bool = True) -> Array:
    """One elementwise PR product through the selected backend.  Pallas route:
    flatten + zero-pad to the kernel's block multiple (zeros multiply to
    zeros, so padding never pollutes); jnp route: the bit-identical ref."""
    if backend != "pallas":
        return pr_multiply_ref(a, b, p, r, n=n)
    flat_a = jnp.asarray(a, jnp.int32).reshape(-1)
    flat_b = jnp.asarray(b, jnp.int32).reshape(-1)
    size = flat_a.shape[0]
    pad = (-size) % PR_BLOCK
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        flat_a = jnp.concatenate([flat_a, z])
        flat_b = jnp.concatenate([flat_b, z])
    out = pr_multiply(flat_a, flat_b, p, r, n=n, block=PR_BLOCK,
                      interpret=interpret)
    return out[:size].reshape(a.shape)


def fir_valid(sig, taps, p, r, *, n: int = 16, backend: str = "xla",
              interpret: bool = True) -> np.ndarray:
    """Valid-mode batched FIR (the Ch. 7 Tables 7.1/7.2 bench layout):
    ``y[j] = sum_i taps[i] * sig[i + j]`` for ``j < len(sig) - len(taps)``.

    All taps ride ONE PR call as stacked (T, L) operand planes; accumulation
    is host-side int64 (unbounded Q14 operands overflow int32 lanes).  NOT
    jit-traceable — the streaming/jit path is :func:`fir_frames`."""
    sig = np.asarray(sig, np.int32)
    taps = np.asarray(taps, np.int32)
    T = len(taps)
    L = len(sig) - T
    a = np.ascontiguousarray(np.broadcast_to(taps[:, None], (T, L)))
    b = np.ascontiguousarray(np.lib.stride_tricks.sliding_window_view(sig, L)[:T])
    prod = np.asarray(pr_product(jnp.asarray(a), jnp.asarray(b), p, r, n=n,
                                 backend=backend, interpret=interpret))
    return prod.astype(np.int64).sum(axis=0)


def fir_frames(frames: Array, tail: Array, taps: Array, p, r, *, n: int = 16,
               shift: int = 0, backend: str = "xla",
               interpret: bool = True):
    """Streaming FIR over one frame batch (jit-safe; the serve-engine step).

    frames (B, L) int32 samples, tail (B, T-1) the previous frame's carried
    history (zeros at stream start), taps (T,) int32 with l1 norm <=
    ``2**shift`` (int32-safe accumulation — see :func:`quantize_weights`).
    Returns ``(y (B, L) int32 >> shift, new_tail (B, T-1))`` — outputs are
    continuous across frames: frame-by-frame equals one whole-signal pass.
    """
    B, L = frames.shape
    T = taps.shape[0]
    ext = jnp.concatenate([jnp.asarray(tail, jnp.int32),
                           jnp.asarray(frames, jnp.int32)], axis=1)
    # static window slices (T is static): (T, B, L) operand planes
    win = jnp.stack([ext[:, i:i + L] for i in range(T)])
    a = jnp.broadcast_to(taps.astype(jnp.int32)[:, None, None], win.shape)
    prod = pr_product(a, win, p, r, n=n, backend=backend, interpret=interpret)
    acc = jnp.sum(prod, axis=0)
    y = jnp.right_shift(acc, shift) if shift else acc
    return y, ext[:, L:]


def conv2d_pr(img: Array, kern: Array, p, r, *, n: int = 16, shift: int = 0,
              pad: str = "zero", backend: str = "xla",
              interpret: bool = True) -> Array:
    """Same-size 2D correlation through the PR datapath (jit-safe).

    img (B, H, W) int32 pixels, kern (kh, kw) int32 weights with l1 norm <=
    ``2**shift``; all kh*kw offsets ride ONE PR call as stacked patch planes.
    ``pad``: "zero" | "edge" border handling.  Returns (B, H, W) int32
    ``>> shift``."""
    B, H, W = img.shape
    kh, kw = kern.shape
    ph, pw = kh // 2, kw // 2
    mode = "edge" if pad == "edge" else "constant"
    ext = jnp.pad(jnp.asarray(img, jnp.int32),
                  ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw)), mode=mode)
    patches = jnp.stack([ext[:, dy:dy + H, dx:dx + W]
                         for dy in range(kh) for dx in range(kw)])
    a = jnp.broadcast_to(kern.astype(jnp.int32).reshape(-1)[:, None, None, None],
                         patches.shape)
    prod = pr_product(a, patches, p, r, n=n, backend=backend,
                      interpret=interpret)
    acc = jnp.sum(prod, axis=0)
    return jnp.right_shift(acc, shift) if shift else acc
