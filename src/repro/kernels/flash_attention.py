"""Flash-style attention forward kernel with computation-skipping grids.

The dissertation's third pillar — *skipping of computations* — applied to the
attention block walk.  Three grid shapes (DESIGN.md §8):

  dense  (BH, n, n)         every (q, kv) block pair; non-causal layers and
                            the bit-identity oracle for the skip grids.
  tri    (BH, n(n+1)/2)     causal: only lower-triangular block pairs are
                            *scheduled* (vs. computed-then-masked) — ~2x
                            fewer block-steps.  The output write rides the
                            diagonal block, the last step of each q row.
  band   (BH, n, band)      causal + sliding window: each q block visits the
                            ceil((window-1)/b)+1 kv blocks its window can
                            reach => O(S*window) block-steps total.

All grids produce bit-identical outputs: a scheduled-but-masked entry
contributes an exact-zero term (exp underflows to 0.0 against a real running
max), and rows that have seen only masked entries are guarded (``p`` forced
to 0 while the running max is still NEG_INF), so never scheduling a fully
masked block leaves the online-softmax state untouched.

Layout: q, k, v as (BH, S, D) — batch*heads flattened; GQA groups are
expanded by the caller (kernels/dispatch.py flattens the model's grouped
(B, S, H, D) layout; models/attention.py keeps the grouped einsum path as
the XLA fallback).  S is zero-padded up to the block multiple and sliced
back — the axqmm M/N recipe — with padded kv columns masked via the static
``s_real`` bound, so non-power-of-two sequences take the kernel path instead
of driving the block-size loop to degenerate tiles.

``return_steps=True`` additionally returns the number of block-steps the
grid actually executed, counted *in-kernel*, so benchmarks and tests assert
the skip happened instead of trusting this docstring
(tests/test_kernels.py::test_flash_causal_skip_grid_*).

VMEM working set per step: blk*D q + 2*blk*D kv + blk*D f32 acc + softmax
scratch ~ 4*128*128*4 B = 256 KiB << 16 MiB.

Validated in interpret mode vs :func:`flash_attention_ref` and
models.attention (tests/test_kernels.py::test_flash_attention_*).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> auto: compiled path on TPU, interpreter elsewhere (the old
    hardcoded ``interpret=True`` kept real TPUs on the emulator)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _block_for(S: int, bq: int, bk: int) -> int:
    """One block size for q and kv (the triangular grid needs square blocks):
    the requested tile, shrunk to the next power of two >= S for short
    sequences so a 3-token prefill doesn't pad to 128."""
    b = min(bq, bk)
    if S < b:
        b = 1 << max(S - 1, 1).bit_length()
    return b


def _tri_ij(t):
    """Linear step t -> (i, j) in the row-major lower-triangular walk
    (row i holds i+1 steps at offset i(i+1)/2).  Closed form via isqrt with
    a +-1 fp-rounding correction; exact for any grid this kernel can run."""
    t = jnp.asarray(t, jnp.int32)
    i = ((jnp.sqrt(8.0 * t.astype(jnp.float32) + 1.0) - 1.0) * 0.5).astype(
        jnp.int32)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    i = jnp.where(i * (i + 1) // 2 > t, i - 1, i)
    return i, t - i * (i + 1) // 2


def _grid_plan(S: int, *, causal: bool, window: Optional[int],
               bq: int, bk: int, skip_grid: bool):
    """(kind, blk, n, band): the static schedule flash_attention will run."""
    blk = _block_for(S, bq, bk)
    n = -(-S // blk)
    if window is not None and window >= S:
        window = None  # window covers the whole sequence: plain causal
    if causal and window is not None and skip_grid:
        band = min(n, -(-(window - 1) // blk) + 1)
        return "band", blk, n, band, window
    if causal and skip_grid:
        return "tri", blk, n, 0, window
    return "dense", blk, n, 0, window


def planned_grid_steps(BH: int, S: int, *, causal: bool = True,
                       window: Optional[int] = None, bq: int = 128,
                       bk: int = 128, skip_grid: bool = True) -> int:
    """Static block-step count of the grid :func:`flash_attention` runs for
    these arguments (dense count: pass ``skip_grid=False``)."""
    kind, _, n, band, _ = _grid_plan(S, causal=causal, window=window,
                                     bq=bq, bk=bk, skip_grid=skip_grid)
    if kind == "tri":
        return BH * n * (n + 1) // 2
    if kind == "band":
        return BH * n * band
    return BH * n * n


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest, kind: str, n: int,
                  band: int, blk: int, s_real: int, causal: bool,
                  window: Optional[int], scale: float, count_steps: bool):
    # rest = (steps_ref?, acc_ref, m_ref, l_ref): the step counter output is
    # only compiled in when requested (tests/benchmarks), so the production
    # dispatch path never pays the per-step read-modify-write
    steps_ref = rest[0] if count_steps else None
    acc_ref, m_ref, l_ref = rest[-3:]
    if kind == "tri":
        t = pl.program_id(1)
        i, j = _tri_ij(t)
        first, last = j == 0, j == i
        grid_start = (pl.program_id(0) == 0) & (t == 0)
    elif kind == "band":
        i, jj = pl.program_id(1), pl.program_id(2)
        j = jnp.maximum(i - (band - 1), 0) + jj
        first, last = jj == 0, jj == band - 1
        grid_start = (pl.program_id(0) == 0) & (i == 0) & (jj == 0)
    else:
        i, j = pl.program_id(1), pl.program_id(2)
        first, last = j == 0, j == n - 1
        grid_start = (pl.program_id(0) == 0) & (i == 0) & (j == 0)

    if count_steps:
        @pl.when(grid_start)
        def _zero_steps():
            steps_ref[0, 0] = 0

        steps_ref[0, 0] += 1

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (blk, D)
    k = k_ref[0].astype(jnp.float32)                  # (blk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (blk, blk)

    rows = i * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    cols = j * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    conds = []
    if s_real < n * blk:
        conds.append(cols < s_real)        # zero-padded kv columns
    if causal:
        conds.append(cols <= rows)
    if window is not None:
        conds.append(cols > rows - window)
    masked = bool(conds)
    if masked:
        m = conds[0]
        for c in conds[1:]:
            m = m & c
        s = jnp.where(m, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    if masked:
        # rows that have seen only masked entries still carry m == NEG_INF,
        # where exp(s - m) would be exp(0) = 1: force those terms to zero so
        # a never-scheduled fully-masked block and a scheduled one leave the
        # same (untouched) state — the bit-identity contract of the skip grids
        p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new), 0.0)
    else:
        p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(last)
    def _done():
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret", "skip_grid", "return_steps"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None,
                    skip_grid: bool = True, return_steps: bool = False):
    """q, k, v: (BH, S, D) -> (BH, S, D); D should be 128-aligned on TPU.

    ``window`` (requires ``causal=True``) applies the sliding-window mask
    cols > rows - window and — with ``skip_grid`` — the banded grid.
    ``return_steps`` -> (out, block-steps executed (int32 scalar)).
    """
    interpret = _resolve_interpret(interpret)
    BH, S, D = q.shape
    if window is not None and not causal:
        raise NotImplementedError(
            "sliding-window flash attention requires causal=True "
            "(dispatch falls back to the jnp path)")
    kind, blk, n, band, window = _grid_plan(
        S, causal=causal, window=window, bq=bq, bk=bk, skip_grid=skip_grid)
    Sp = n * blk
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    scale = 1.0 / math.sqrt(D)

    if kind == "tri":
        grid = (BH, n * (n + 1) // 2)
        qmap = lambda b, t: (b, _tri_ij(t)[0], 0)
        kvmap = lambda b, t: (b, _tri_ij(t)[1], 0)
        smap = lambda b, t: (0, 0)
    elif kind == "band":
        grid = (BH, n, band)
        qmap = lambda b, i, jj: (b, i, 0)
        kvmap = lambda b, i, jj: (b, jnp.maximum(i - (band - 1), 0) + jj, 0)
        smap = lambda b, i, jj: (0, 0)
    else:
        grid = (BH, n, n)
        qmap = lambda b, i, j: (b, i, 0)
        kvmap = lambda b, i, j: (b, j, 0)
        smap = lambda b, i, j: (0, 0)

    kern = functools.partial(_flash_kernel, kind=kind, n=n, band=band,
                             blk=blk, s_real=S, causal=causal, window=window,
                             scale=scale, count_steps=return_steps)
    out_specs = [pl.BlockSpec((1, blk, D), qmap)]
    out_shape = [jax.ShapeDtypeStruct((BH, Sp, D), q.dtype)]
    if return_steps:
        out_specs.append(pl.BlockSpec((1, 1), smap))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
    res = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, D), qmap),
            pl.BlockSpec((1, blk, D), kvmap),
            pl.BlockSpec((1, blk, D), kvmap),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((blk, D), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    out = res[0][:, :S] if Sp != S else res[0]
    if return_steps:
        return out, res[1][0, 0]
    return out


# ---------------------------------------------------------------------------
# differentiable wrapper — forward through the kernel, backward through the
# jnp oracle (O(S^2) residuals: acceptable at smoke scale; a fused backward
# kernel is the natural follow-up once training moves to TPU)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_vjp(q: Array, k: Array, v: Array,
                        causal: bool, window: Optional[int]) -> Array:
    return flash_attention(q, k, v, causal=causal, window=window)


def _fa_fwd(q, k, v, causal, window):
    return flash_attention(q, k, v, causal=causal, window=window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(q, k, v, causal=causal,
                                            window=window), q, k, v)
    return vjp(g)


flash_attention_vjp.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_ref(q: Array, k: Array, v: Array, causal: bool = True,
                        window: Optional[int] = None) -> Array:
    """Pure-jnp oracle (same math as models.attention.attn_full, flat BH)."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= jj <= ii
    if window is not None:
        mask &= jj > ii - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
