"""Flash-style attention forward kernel (online softmax, VMEM-tiled).

The prefill_32k cells are the attention-heaviest workloads in the assigned
set; this kernel is their TPU hot-spot implementation: O(S) memory, tiles
sized for VMEM, MXU-aligned head dims.

Layout: q, k, v as (BH, S, D) — batch*heads flattened, GQA groups expanded by
the caller (models/attention.py keeps the grouped einsum path as the XLA
fallback; this kernel is the Pallas deployment path).

Grid (bh, i, j): j innermost walks KV blocks for a fixed q block with running
max/denominator scratch; causal blocks strictly above the diagonal are
masked (and skipped on TPU via the mask short-circuit).

VMEM working set per step: bq*D + 2*bk*D + bq*D f32 + softmax scratch
= (128 + 2*128 + 128)*128*4 B = 256 KiB << 16 MiB.

Validated in interpret mode vs models.attention.attn_full
(tests/test_kernels.py::test_flash_attention_*).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, n_k: int, bq: int, bk: int, causal: bool, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> Array:
    """q, k, v: (BH, S, D) -> (BH, S, D).  D should be 128-aligned on TPU."""
    BH, S, D = q.shape
    bq = min(bq, S)
    while S % bq:
        bq //= 2
    bk = min(bk, S)
    while S % bk:
        bk //= 2
    n_q, n_k = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_flash_kernel, n_k=n_k, bq=bq, bk=bk,
                             causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q: Array, k: Array, v: Array,
                        causal: bool = True) -> Array:
    """Pure-jnp oracle (same math as models.attention.attn_full, flat BH)."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
