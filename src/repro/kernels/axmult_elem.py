"""axmult_elem — the dissertation's PR (perforation+rounding) multiplier as a
vectorized Pallas kernel.

This is the Ch. 5 circuit itself, one lane per element: given int operand
arrays A, B (n-bit values in int32 lanes), compute
    round_r(A) * perforate_p(B)
entirely with the bit manipulations of the hardware (shift/mask/add), with
(p, r) as *runtime* scalar-prefetch arguments — the DyFXU configuration
registers.  Used by the approximate DSP accelerators (FIR / conv) benchmarks
to run the paper's arithmetic at array scale.

VPU mapping: pure element-wise integer ops on (8,128)-aligned tiles; VMEM
block of 16K lanes x 4 B x 2 operands = 128 KiB per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray


def _pr_kernel(pr_ref, a_ref, b_ref, out_ref, *, n: int):
    p = pr_ref[0]
    r = pr_ref[1]
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    # rounding: A_r = (floor(A / 2^r) + a_{r-1}) * 2^r  (r = 0 -> identity)
    rbit = jnp.where(r > 0,
                     jnp.bitwise_and(jnp.right_shift(a, jnp.maximum(r - 1, 0)), 1),
                     0)
    a_r = jnp.where(r > 0, jnp.left_shift(jnp.right_shift(a, r) + rbit, r), a)
    # perforation: B' = B - (B mod 2^{2p}) + 2^{2p} * b_{2p-1}
    u = jnp.bitwise_and(b, (1 << n) - 1)
    two_p = jnp.left_shift(jnp.int32(1), 2 * p)
    low = jnp.bitwise_and(u, two_p - 1)
    cbit = jnp.bitwise_and(jnp.right_shift(u, jnp.maximum(2 * p - 1, 0)), 1)
    b_p = jnp.where(p > 0, b - low + cbit * two_p, b)
    out_ref[...] = a_r * b_p


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def pr_multiply(a: Array, b: Array, p: Array | int, r: Array | int,
                *, n: int = 16, block: int = 2048,
                interpret: bool = True) -> Array:
    """Elementwise DyFXU product of int32 operand arrays (n-bit values).

    a, b: same shape, total size % block == 0 (callers pad); p, r runtime
    scalars.  N-D operands (e.g. a stacked (taps, L) FIR batch) are flattened
    for the kernel and restored on return.
    """
    shape = a.shape
    assert b.shape == shape, (shape, b.shape)
    a = a.reshape(-1)
    b = b.reshape(-1)
    (L,) = a.shape
    assert L % block == 0, (L, block)
    pr = jnp.stack([jnp.asarray(p, jnp.int32), jnp.asarray(r, jnp.int32)])
    grid = (L // block,)
    lanes = 128
    rows = block // lanes
    a2 = a.reshape(-1, lanes)
    b2 = b.reshape(-1, lanes)
    out = pl.pallas_call(
        functools.partial(_pr_kernel, n=n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows, lanes), lambda i, *_: (i, 0)),
                pl.BlockSpec((rows, lanes), lambda i, *_: (i, 0)),
            ],
            out_specs=pl.BlockSpec((rows, lanes), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(a2.shape, jnp.int32),
        interpret=interpret,
    )(pr, a2, b2)
    return out.reshape(shape)
