"""axqmm — block-quantized, effective-bits, runtime-degradable GEMM.

The TPU-native embodiment of the dissertation's perforation+rounding
multiplier (DESIGN.md §2.1): int8 operands with per-(row, k-block) scales; a
*runtime* effective-bits degree e <= 8 drops low operand bits by
round-and-shift exactly like DyFXU's runtime perforation registers — no
recompile, the degree is a scalar-prefetch argument (SMEM).

TPU mapping (VMEM/MXU co-design, the Ch. 9 scratchpad-scheduling insight):
  * tiles (bm, bk) x (bn, bk) -> (bm, bn), multiples of 128 so the MXU
    systolic array is fully utilized and int8 ingestion is 2x bf16 rate;
  * quantization block == bk so each grid step consumes exactly one scale
    column: scales ride along in VMEM, bk x smaller than the int tiles;
  * f32 accumulator tile lives in a VMEM scratch across the K grid walk
    (output tile revisited over k), written back once on the last k step;
  * working set per step: bm*bk + bn*bk int8 + 2*bm*bn f32
    = 2*128*512 + 2*128*128*4 bytes ~ 260 KiB << 16 MiB VMEM.

Weight residency (DESIGN.md §9): the weight operand arrives *prepacked* as a
:class:`~repro.kernels.qstore.PackedQWeight` — ``(N, K)`` int8 K-major plus
``(N, K//bk)`` f32 scales, quantized once at load time — so the per-call work
is activation quantization only.  The float-``w`` wrappers below pack
on-the-fly through the same code path (bit-identical by construction).

Fused epilogues ride the last k grid step while the output tile is still in
VMEM:
  * :func:`axqmm_packed` — optional bias (+b) and residual (+r) added in f32
    before the single writeback (down/out projections fuse the residual add);
  * :func:`axqmm_gated` / :func:`axqmm_gated_packed` — the gated-MLP first
    half ``act(x@w_gate) * (x@w_up)``: both GEMMs stream the *same* x tile
    (quantized and degraded once per step), keep two accumulators, and apply
    the gate in-VMEM — one HBM roundtrip instead of three.

Validated against core.quantization.qmm_packed_ref / qmm_gated_packed_ref
(pure-jnp oracles) in interpret mode on CPU (tests/test_kernels.py,
tests/test_qstore.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import quantize_block
from repro.kernels.qstore import PackedQWeight, prepack_weight, resolve_block

Array = jnp.ndarray

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def _resolve_interpret(interpret):
    if interpret is None:
        from repro.kernels.flash_attention import _resolve_interpret as r

        return r(None)
    return interpret


def _degrade_tile(q: Array, shift: Array) -> Array:
    """Round-to-nearest drop of `shift` low bits (int32 lanes), saturating —
    the runtime perforation knob.  shift is a traced int32 scalar."""
    half = jnp.where(shift > 0, jnp.left_shift(1, jnp.maximum(shift - 1, 0)), 0)
    down = jnp.right_shift(q + half, shift)
    out = jnp.left_shift(down, shift)
    return jnp.clip(out, -127, 127)


def _step_dot(ebits_ref, qx_ref, qw_ref, sx_ref, sw_ref):
    """One k-step partial product: degrade both int tiles to the runtime
    effective bits, s8 x s8 -> s32 dot, scale by the block scales."""
    shift = jnp.maximum(8 - ebits_ref[0], 0)
    qx = _degrade_tile(qx_ref[...].astype(jnp.int32), shift)
    qw = _degrade_tile(qw_ref[...].astype(jnp.int32), shift)
    # MXU int8 path: s8 x s8 -> s32 (int32 lanes under interpret mode)
    acc = jax.lax.dot_general(
        qx, qw,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale = sx_ref[...] * sw_ref[...].T          # (bm,1)*(1,bn) -> (bm,bn)
    return acc.astype(jnp.float32) * scale


def _axqmm_kernel(ebits_ref, qx_ref, sx_ref, qw_ref, sw_ref, *rest,
                  n_k: int, has_bias: bool, has_res: bool):
    idx = 0
    bias_ref = rest[idx] if has_bias else None
    idx += int(has_bias)
    res_ref = rest[idx] if has_res else None
    idx += int(has_res)
    out_ref, acc_ref = rest[idx], rest[idx + 1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _step_dot(ebits_ref, qx_ref, qw_ref, sx_ref, sw_ref)

    @pl.when(k == n_k - 1)
    def _done():
        # fused epilogue: the output tile is still in VMEM — bias and
        # residual are added in f32 before the one writeback
        y = acc_ref[...]
        if has_bias:
            y = y + bias_ref[...]                # (1,bn) broadcasts over bm
        if has_res:
            y = y + res_ref[...]
        out_ref[...] = y


def _axqmm_gated_kernel(ebits_ref, qx_ref, sx_ref, qu_ref, su_ref,
                        qg_ref, sg_ref, out_ref, accu_ref, accg_ref,
                        *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        accg_ref[...] = jnp.zeros_like(accg_ref)

    # the x tile is streamed (and degraded) ONCE per step for both GEMMs
    shift = jnp.maximum(8 - ebits_ref[0], 0)
    qx = _degrade_tile(qx_ref[...].astype(jnp.int32), shift)
    qu = _degrade_tile(qu_ref[...].astype(jnp.int32), shift)
    qg = _degrade_tile(qg_ref[...].astype(jnp.int32), shift)
    dn = (((1,), (1,)), ((), ()))
    up = jax.lax.dot_general(qx, qu, dimension_numbers=dn,
                             preferred_element_type=jnp.int32)
    gt = jax.lax.dot_general(qx, qg, dimension_numbers=dn,
                             preferred_element_type=jnp.int32)
    accu_ref[...] += up.astype(jnp.float32) * (sx_ref[...] * su_ref[...].T)
    accg_ref[...] += gt.astype(jnp.float32) * (sx_ref[...] * sg_ref[...].T)

    @pl.when(k == n_k - 1)
    def _done():
        # in-VMEM gate: act(gate) * up written back once — the intermediate
        # up/gate tensors never round-trip through HBM
        out_ref[...] = _ACTS[act](accg_ref[...]) * accu_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def axqmm_quantized(qx: Array, sx: Array, qwT: Array, sw: Array,
                    ebits: Array | int = 8, *, bm: int = 128, bn: int = 128,
                    bk: int = 512, interpret: bool = True) -> Array:
    """qx: (M, K) int8; sx: (M, K//bk) f32; qwT: (N, K) int8;
    sw: (N, K//bk) f32; ebits: runtime scalar.  Returns (M, N) f32."""
    return _axqmm_call(qx, sx, qwT, sw, ebits, None, None,
                       bm=bm, bn=bn, bk=bk, interpret=interpret)


def _axqmm_call(qx, sx, qwT, sw, ebits, bias, residual, *, bm, bn, bk,
                interpret):
    M, K = qx.shape
    N = qwT.shape[0]
    assert K % bk == 0 and M % bm == 0 and N % bn == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    ebits_arr = jnp.asarray(ebits, jnp.int32).reshape(1)
    grid = (M // bm, N // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k, *prefetch: (i, k)),   # qx
        pl.BlockSpec((bm, 1), lambda i, j, k, *prefetch: (i, k)),    # sx
        pl.BlockSpec((bn, bk), lambda i, j, k, *prefetch: (j, k)),   # qwT
        pl.BlockSpec((bn, 1), lambda i, j, k, *prefetch: (j, k)),    # sw
    ]
    args = [qx, sx, qwT, sw]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k, *prefetch: (0, j)))
        args.append(bias.reshape(1, N).astype(jnp.float32))
    if residual is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k, *prefetch: (i, j)))
        args.append(residual.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_axqmm_kernel, n_k=n_k, has_bias=bias is not None,
                          has_res=residual is not None),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *prefetch: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(ebits_arr, *args)


def _axqmm_gated_call(qx, sx, qu, su, qg, sg, ebits, *, act, bm, bn, bk,
                      interpret):
    M, K = qx.shape
    N = qu.shape[0]
    assert K % bk == 0 and M % bm == 0 and N % bn == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    ebits_arr = jnp.asarray(ebits, jnp.int32).reshape(1)
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_axqmm_gated_kernel, n_k=n_k, act=act),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, *prefetch: (i, k)),  # qx
                pl.BlockSpec((bm, 1), lambda i, j, k, *prefetch: (i, k)),   # sx
                pl.BlockSpec((bn, bk), lambda i, j, k, *prefetch: (j, k)),  # qu
                pl.BlockSpec((bn, 1), lambda i, j, k, *prefetch: (j, k)),   # su
                pl.BlockSpec((bn, bk), lambda i, j, k, *prefetch: (j, k)),  # qg
                pl.BlockSpec((bn, 1), lambda i, j, k, *prefetch: (j, k)),   # sg
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *prefetch: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                            pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(ebits_arr, qx, sx, qu, su, qg, sg)


def quantize_for_axqmm(x: Array, bk: int = 512):
    """Per-(row, k-block) symmetric int8 quantization. x: (M, K) float.
    Thin view over core.quantization.quantize_block — ONE quantizer shared by
    kernel, jnp oracle, and the prepack pass (the bit-identity contract)."""
    qt = quantize_block(x.astype(jnp.float32), bk)
    return qt.values, qt.scales


def _tile(dim: int) -> int:
    return 128 if dim % 128 == 0 else (64 if dim % 64 == 0 else 8)


def _pad0(a: Array, to: int) -> Array:
    return jnp.pad(a, ((0, to - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))


def axqmm_packed(x: Array, pw: PackedQWeight, ebits: Array | int = 8, *,
                 bias: Array | None = None, residual: Array | None = None,
                 interpret: bool | None = None) -> Array:
    """float x (M, K) @ prepacked weight through the quantized kernel.

    Per-call work is activation quantization only — the weight was encoded
    at load time (qstore).  M/N are zero-padded up to the tile multiple and
    the result sliced back, so decode-shaped inputs (M = serve slots) take
    the Pallas path.  Padding happens *after* quantization: scales are
    per-row, so real rows are unchanged and padded rows (zero operands)
    contribute exact zeros that the slice drops.

    ``bias`` (N,) and ``residual`` (M, N) fuse into the f32 epilogue on the
    last k step: ``out = acc + bias + residual`` before the one writeback.
    """
    M, K = x.shape
    N, bk = pw.n, pw.block
    assert pw.k == K, (pw.k, K)
    qx, sx = quantize_for_axqmm(x, bk)
    qw, sw = pw.qw, pw.scales
    bm, bn = _tile(M), _tile(N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        qx, sx = _pad0(qx, Mp), _pad0(sx, Mp)
        if residual is not None:
            residual = _pad0(residual, Mp)
    if Np != N:
        qw, sw = _pad0(qw, Np), _pad0(sw, Np)
        if bias is not None:
            bias = jnp.pad(bias, (0, Np - N))
        if residual is not None:
            residual = jnp.pad(residual, ((0, 0), (0, Np - N)))
    y = _axqmm_call(qx, sx, qw, sw, ebits, bias, residual, bm=bm, bn=bn,
                    bk=bk, interpret=_resolve_interpret(interpret))
    return y[:M, :N] if (Mp != M or Np != N) else y


def axqmm_gated_packed(x: Array, pw_up: PackedQWeight, pw_gate: PackedQWeight,
                       ebits: Array | int = 8, *, act: str = "silu",
                       interpret: bool | None = None) -> Array:
    """Fused gated-MLP first half against prepacked weights:
    ``act(x @ w_gate) * (x @ w_up)`` in one kernel — the shared x tile is
    quantized/degraded once per step, and the up/gate intermediates never
    leave VMEM (one HBM roundtrip instead of three)."""
    M, K = x.shape
    N, bk = pw_up.n, pw_up.block
    assert pw_up.k == K and pw_gate.k == K, (pw_up.k, pw_gate.k, K)
    assert pw_gate.n == N and pw_gate.block == bk, "up/gate packs must agree"
    qx, sx = quantize_for_axqmm(x, bk)
    qu, su = pw_up.qw, pw_up.scales
    qg, sg = pw_gate.qw, pw_gate.scales
    bm, bn = _tile(M), _tile(N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        qx, sx = _pad0(qx, Mp), _pad0(sx, Mp)
    if Np != N:
        qu, su = _pad0(qu, Np), _pad0(su, Np)
        qg, sg = _pad0(qg, Np), _pad0(sg, Np)
    y = _axqmm_gated_call(qx, sx, qu, su, qg, sg, ebits, act=act, bm=bm,
                          bn=bn, bk=bk, interpret=_resolve_interpret(interpret))
    return y[:M, :N] if (Mp != M or Np != N) else y


def axqmm(x: Array, w: Array, *, block: int = 512, ebits: Array | int = 8,
          interpret: bool | None = None, bias: Array | None = None,
          residual: Array | None = None) -> Array:
    """float x (M,K) @ float w (K,N): packs the weight on the fly (same
    quantizer as the prepack pass) and defers to :func:`axqmm_packed` —
    prepacked and on-the-fly execution share one kernel graph from the
    quantized operands on."""
    bk = resolve_block(x.shape[-1], block)
    return axqmm_packed(x, prepack_weight(w, bk), ebits, bias=bias,
                        residual=residual, interpret=interpret)


def axqmm_gated(x: Array, w_up: Array, w_gate: Array, *, block: int = 512,
                ebits: Array | int = 8, act: str = "silu",
                interpret: bool | None = None) -> Array:
    """On-the-fly-packed variant of :func:`axqmm_gated_packed`."""
    bk = resolve_block(x.shape[-1], block)
    return axqmm_gated_packed(x, prepack_weight(w_up, bk),
                              prepack_weight(w_gate, bk), ebits, act=act,
                              interpret=interpret)
