"""axqmm — block-quantized, effective-bits, runtime-degradable GEMM.

The TPU-native embodiment of the dissertation's perforation+rounding
multiplier (DESIGN.md §2.1): int8 operands with per-(row, k-block) scales; a
*runtime* effective-bits degree e <= 8 drops low operand bits by
round-and-shift exactly like DyFXU's runtime perforation registers — no
recompile, the degree is a scalar-prefetch argument (SMEM).

TPU mapping (VMEM/MXU co-design, the Ch. 9 scratchpad-scheduling insight):
  * tiles (bm, bk) x (bn, bk) -> (bm, bn), multiples of 128 so the MXU
    systolic array is fully utilized and int8 ingestion is 2x bf16 rate;
  * quantization block == bk so each grid step consumes exactly one scale
    column: scales ride along in VMEM, bk x smaller than the int tiles;
  * f32 accumulator tile lives in a VMEM scratch across the K grid walk
    (output tile revisited over k), written back once on the last k step;
  * working set per step: bm*bk + bn*bk int8 + 2*bm*bn f32
    = 2*128*512 + 2*128*128*4 bytes ~ 260 KiB << 16 MiB VMEM.

Layout contract: w is passed K-major as (N, K) ("wT") so both operands stream
contiguous k-blocks.  ops.py handles transpose + quantization.

Validated against kernels/ref.py (pure-jnp oracle) in interpret mode on CPU
across shape/degree sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray


def _degrade_tile(q: Array, shift: Array) -> Array:
    """Round-to-nearest drop of `shift` low bits (int32 lanes), saturating —
    the runtime perforation knob.  shift is a traced int32 scalar."""
    half = jnp.where(shift > 0, jnp.left_shift(1, jnp.maximum(shift - 1, 0)), 0)
    down = jnp.right_shift(q + half, shift)
    out = jnp.left_shift(down, shift)
    return jnp.clip(out, -127, 127)


def _axqmm_kernel(ebits_ref, qx_ref, sx_ref, qw_ref, sw_ref, out_ref, acc_ref,
                  *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shift = jnp.maximum(8 - ebits_ref[0], 0)
    qx = _degrade_tile(qx_ref[...].astype(jnp.int32), shift)
    qw = _degrade_tile(qw_ref[...].astype(jnp.int32), shift)
    # MXU int8 path: s8 x s8 -> s32 (int32 lanes under interpret mode)
    acc = jax.lax.dot_general(
        qx, qw,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale = sx_ref[...] * sw_ref[...].T          # (bm,1)*(1,bn) -> (bm,bn)
    acc_ref[...] += acc.astype(jnp.float32) * scale

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def axqmm_quantized(qx: Array, sx: Array, qwT: Array, sw: Array,
                    ebits: Array | int = 8, *, bm: int = 128, bn: int = 128,
                    bk: int = 512, interpret: bool = True) -> Array:
    """qx: (M, K) int8; sx: (M, K//bk) f32; qwT: (N, K) int8;
    sw: (N, K//bk) f32; ebits: runtime scalar.  Returns (M, N) f32."""
    M, K = qx.shape
    N = qwT.shape[0]
    assert K % bk == 0 and M % bm == 0 and N % bn == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    ebits_arr = jnp.asarray(ebits, jnp.int32).reshape(1)
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_axqmm_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, *prefetch: (i, k)),   # qx
                pl.BlockSpec((bm, 1), lambda i, j, k, *prefetch: (i, k)),    # sx
                pl.BlockSpec((bn, bk), lambda i, j, k, *prefetch: (j, k)),   # qwT
                pl.BlockSpec((bn, 1), lambda i, j, k, *prefetch: (j, k)),    # sw
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *prefetch: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(ebits_arr, qx, sx, qwT, sw)


def quantize_for_axqmm(x: Array, bk: int = 512):
    """Per-(row, k-block) symmetric int8 quantization. x: (M, K) float."""
    M, K = x.shape
    assert K % bk == 0
    xb = x.reshape(M, K // bk, bk).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(M, K), scale[..., 0]


def _tile(dim: int) -> int:
    return 128 if dim % 128 == 0 else (64 if dim % 64 == 0 else 8)


def axqmm(x: Array, w: Array, *, block: int = 512, ebits: Array | int = 8,
          interpret: bool = True) -> Array:
    """float x (M,K) @ float w (K,N) through the quantized kernel.

    M/N are zero-padded up to the tile multiple and the result sliced back,
    so decode-shaped inputs (M = serve slots, e.g. 4) take the Pallas path
    instead of raising.  Padding happens *after* quantization: scales are
    per-row / per-column, so real rows' values are unchanged and the padded
    rows (zero operands) contribute exact zeros that the slice drops.
    """
    M, K = x.shape
    N = w.shape[1]
    bk = block
    # shrink bk to a divisor of K if needed (kernel contract)
    while K % bk:
        bk //= 2
    qx, sx = quantize_for_axqmm(x, bk)
    qw, sw = quantize_for_axqmm(w.T, bk)
    bm, bn = _tile(M), _tile(N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        qx = jnp.pad(qx, ((0, Mp - M), (0, 0)))
        sx = jnp.pad(sx, ((0, Mp - M), (0, 0)))
    if Np != N:
        qw = jnp.pad(qw, ((0, Np - N), (0, 0)))
        sw = jnp.pad(sw, ((0, Np - N), (0, 0)))
    y = axqmm_quantized(qx, sx, qw, sw, ebits, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)
    return y[:M, :N] if (Mp != M or Np != N) else y
