"""Online quality telemetry: the serving-time twin of the calibration
prober (``tune.autotune._Prober``).

An :class:`~repro.tune.plan.ApproxPlan`'s per-rung error numbers are
measured once, offline, on a calibration batch.  Deployed behind a QoS
controller the plan serves live traffic at whatever rung load dictates —
and nothing checks that the calibrated error claims still hold on the
*production* distribution.  The quality tap closes that gap: every Nth
engine tick it re-runs the current decode inputs through the SAME
compiled forward twice — once at the live degree, once at the exact rung
(all sites at 8 effective bits) — and records the normalized RMS logit
deviation into a histogram labelled by the active rung.  Ladder drift
(a rung serving worse than it calibrated) becomes a visible histogram
shift instead of a silent quality regression.

Cost model: two extra jitted decode forwards per sample, compiled once
(the degree is a traced operand, so rung moves never retrace the probe).
At ``every=32`` on an 8-slot engine that is ~6% extra decode compute;
``every=0`` disables the tap entirely (the default).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dynamic import degree_record
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["QualityTap", "rung_label", "QUALITY_BUCKETS"]

#: relative-error flavored buckets (normalized RMS logit deviation)
QUALITY_BUCKETS = (1e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
                   0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0)


def rung_label(degree) -> str:
    """Stable label for a degree operand: ``"8"`` for the global scalar,
    ``"8.7.6"`` for a per-site vector (dots keep it one Prometheus label
    value)."""
    rec = degree_record(degree)
    if isinstance(rec, tuple):
        return ".".join(str(int(x)) for x in rec)
    return str(int(rec))


class QualityTap:
    """Per-rung quality histogram sampled from live serving traffic.

    Built by the serve workload when ``quality_every > 0``; `sample` is
    called with the tick's step inputs *before* the fused step runs
    (the probe never advances the state — both forwards discard their
    state update).

    The error metric is pluggable (ISSUE 7): by default the tap compares
    live-vs-exact *logits* of an LM ``model`` (normalized RMS deviation,
    the historical behavior, recorded as ``repro_quality_logit_rms``); a
    workload may instead pass its own jittable ``probe(params, state,
    feed, active, degree) -> scalar`` together with a ``metric_name``
    (histogram family ``repro_quality_{metric_name}``, matching trace-arg
    key) and ``buckets`` fitting the metric's range — e.g. the stream
    workload probes per-frame PSNR in dB."""

    def __init__(self, model=None, *, tp: int = 1, every: int = 32,
                 registry: Optional[obs_metrics.Registry] = None,
                 tracer: Optional[obs_trace.Tracer] = None,
                 probe=None, metric_name: str = "logit_rms",
                 buckets=QUALITY_BUCKETS):
        if every <= 0:
            raise ValueError(f"quality tap period must be > 0 (got {every})")
        if model is None and probe is None:
            raise ValueError("QualityTap needs a model or a custom probe")
        self.every = int(every)
        self.samples = 0
        self.metric_name = metric_name
        self.registry = registry if registry is not None else obs_metrics.Registry()
        self.tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self.hist = self.registry.histogram(
            f"repro_quality_{metric_name}",
            f"live-vs-exact {metric_name} by rung",
            labels=("rung",), buckets=tuple(buckets))
        self._probes = self.registry.counter(
            "repro_quality_probes_total", "quality-tap probe forwards run")

        if probe is None:
            def probe(p, cache, tokens, active, deg):
                # live-degree and exact-rung logits on identical inputs; the
                # cache updates are discarded — the tap is a pure observer
                approx, _ = model.decode_step(p, cache, tokens, tp=tp,
                                              degree=deg, active=active)
                exact_deg = jnp.full_like(deg, 8)
                exact, _ = model.decode_step(p, cache, tokens, tp=tp,
                                             degree=exact_deg, active=active)
                w = active.astype(jnp.float32)[:, None, None]
                n = jnp.maximum(
                    jnp.sum(w) * approx.shape[-2] * approx.shape[-1], 1.0)
                dev = jnp.sqrt(jnp.sum(((approx - exact) ** 2) * w) / n)
                ref = jnp.sqrt(jnp.sum((exact ** 2) * w) / n)
                return dev / jnp.maximum(ref, 1e-9)

        self._probe = jax.jit(probe)

    def due(self, tick: int) -> bool:
        return tick % self.every == 0

    def sample(self, tick: int, params, cache, tokens, active, degree) -> float:
        """Measure the live-vs-exact quality metric for this tick's inputs
        and record it under the active rung; returns the value."""
        err = float(self._probe(params, cache, jnp.asarray(tokens),
                                jnp.asarray(active), degree))
        rung = rung_label(degree)
        self.hist.labels(rung=rung).observe(err)
        self._probes.inc()
        self.samples += 1
        self.tracer.event("quality_probe", track="engine", tick=tick,
                          rung=rung, **{self.metric_name: err})
        return err
