"""Bench-record regression gate (``tools/check_bench.py`` backend).

The committed perf records — ``benchmarks/BENCH_kernels.json``,
``BENCH_serving.json``, ``BENCH_gemm.json``, ``BENCH_tune.json``,
``BENCH_stream.json``, ``BENCH_chaos.json``, ``BENCH_elastic.json`` — are
the repo's performance memory: every claim in CHANGES.md (skip-grid step
counts, fused-GEMM speedups, planned-rung dominance, stream-rung PSNR,
brownout goodput dominance, fleet goodput through replica loss) is
anchored in them.
Until now nothing machine-checked them, so a record could silently rot
(a bench renamed, a speedup regressed, a hand-edited number) and CI would
stay green.  This module makes each record's claims executable:

1. **meta integrity** — every record must carry the v2 stamp
   (``schema_version``, ``git_sha``, ``platform``, ``jax_backend``,
   ``kernels_backend``, ``tiny_shapes``) so records are attributable and
   comparable across machines.
2. **declared invariants** — per-bench checks with explicit tolerances on
   the *derived* (scale-invariant) columns: error envelopes, kernel-vs-ref
   max deviations, skip-grid step ratios, fused-GEMM speedups, planned-
   ladder dominance/ordering.  Perturbing a committed record beyond a
   tolerance fails the gate loudly.
3. **fresh diff** — re-run the benches (tiny shapes under
   ``REPRO_BENCH_TINY=1``) and require every fresh row name to exist in
   the committed record (coverage can only grow, never silently shrink)
   and the fresh record to satisfy the same invariants.  Raw timings are
   deliberately NOT diffed across machines/shapes — only the declared
   invariants are portable.

All tolerances live in this file, next to the checks that use them.
"""

from __future__ import annotations

import json
import pathlib
import re
from typing import Callable, Optional

__all__ = ["BENCH_RECORDS", "SCHEMA_VERSION", "load_record", "check_meta",
           "check_invariants", "check_record", "check_committed",
           "compare_fresh", "run_fresh_rows", "bench_dir"]

#: record files under benchmarks/ — the perf-tracked benches
BENCH_RECORDS = {
    "bench_kernels": "BENCH_kernels.json",
    "bench_serving": "BENCH_serving.json",
    "bench_gemm": "BENCH_gemm.json",
    "bench_tune": "BENCH_tune.json",
    "bench_stream": "BENCH_stream.json",
    "bench_chaos": "BENCH_chaos.json",
    "bench_elastic": "BENCH_elastic.json",
    "bench_admission": "BENCH_admission.json",
}

#: current record schema (benchmarks/run.py stamps this)
SCHEMA_VERSION = 2

_REQUIRED_META = ("bench", "schema_version", "unix_time", "git_sha",
                  "platform", "jax_backend", "kernels_backend",
                  "tiny_shapes", "columns", "rows")

# ---- declared tolerances (the contract the records must satisfy) ---------

#: AXQ relative error at 8 effective bits (committed: ~0.010)
AXQMM_E8_RELERR_MAX = 0.03
#: Pallas kernel vs jnp reference max absolute deviation (bit-closeness)
KERNEL_VS_REF_MAXDIFF = 1e-3
#: causal skip grid must run at most this fraction of the dense grid's steps
FLASH_SKIP_STEP_RATIO_MAX = 0.75
#: fused+prepacked GEMM speedup vs the three-call on-the-fly baseline
GEMM_PACKED_FUSED_SPEEDUP_MIN = 1.2
GEMM_PACKED_FUSED_SPEEDUP_MIN_TINY = 1.0


def bench_dir() -> pathlib.Path:
    """benchmarks/ directory (repo-root-relative, resolved from this file)."""
    return pathlib.Path(__file__).resolve().parents[3] / "benchmarks"


def load_record(bench: str, directory=None) -> dict:
    path = pathlib.Path(directory or bench_dir()) / BENCH_RECORDS[bench]
    return json.loads(path.read_text())


def rows_by_name(rec: dict) -> dict:
    """{row_name: (us_per_call, derived)} — duplicate names are an error."""
    out: dict = {}
    for r in rec.get("rows", []):
        name, us, derived = r[0], r[1], r[2]
        if name in out:
            raise ValueError(f"duplicate bench row {name!r}")
        out[name] = (float(us), str(derived))
    return out


def _derived_float(rows: dict, name: str) -> Optional[float]:
    if name not in rows:
        return None
    try:
        return float(rows[name][1])
    except ValueError:
        return None


# ---------------------------------------------------------------------------
# checks — each returns a list of violation strings (empty == pass)
# ---------------------------------------------------------------------------


def check_meta(rec: dict) -> list:
    errs = []
    for k in _REQUIRED_META:
        if k not in rec:
            errs.append(f"missing meta field {k!r} "
                        f"(schema v{SCHEMA_VERSION} stamp)")
    if errs:
        return errs
    if int(rec["schema_version"]) < SCHEMA_VERSION:
        errs.append(f"schema_version {rec['schema_version']} < "
                    f"{SCHEMA_VERSION} — regenerate via benchmarks/run.py")
    if not rec["git_sha"] or rec["git_sha"] == "unknown":
        errs.append("git_sha not stamped (record not attributable)")
    if rec["kernels_backend"] not in ("pallas", "xla"):
        errs.append(f"kernels_backend {rec['kernels_backend']!r} not in "
                    f"('pallas', 'xla')")
    if rec["columns"] != ["name", "us_per_call", "derived"]:
        errs.append(f"unexpected columns {rec['columns']}")
    if not rec["rows"]:
        errs.append("record has no rows")
    return errs


def _check_kernels(rec: dict, tiny: bool) -> list:
    errs = []
    rows = rows_by_name(rec)
    # degree scaling: error grows monotonically as effective bits drop
    relerr = {e: _derived_float(rows, f"kern.axqmm_e{e}_relerr")
              for e in (8, 6, 4)}
    for e, v in relerr.items():
        if v is None:
            errs.append(f"missing row kern.axqmm_e{e}_relerr")
    if None not in relerr.values():
        if relerr[8] > AXQMM_E8_RELERR_MAX:
            errs.append(f"axqmm e8 relerr {relerr[8]} > "
                        f"{AXQMM_E8_RELERR_MAX} (tolerance)")
        if not (relerr[8] < relerr[6] < relerr[4]):
            errs.append(f"axqmm relerr not monotone in degree: {relerr}")
    for e in (8, 6, 4):
        v = _derived_float(rows, f"kern.axqmm_e{e}_vs_ref_maxdiff")
        if v is None:
            errs.append(f"missing row kern.axqmm_e{e}_vs_ref_maxdiff")
        elif v > KERNEL_VS_REF_MAXDIFF:
            errs.append(f"axqmm e{e} kernel-vs-ref maxdiff {v} > "
                        f"{KERNEL_VS_REF_MAXDIFF} (tolerance)")
    # skip grid: parse "steps A/B (skip/dense)"
    if "kern.flash_causal_skip_us" not in rows:
        errs.append("missing row kern.flash_causal_skip_us")
    else:
        m = re.search(r"steps (\d+)/(\d+)",
                      rows["kern.flash_causal_skip_us"][1])
        if not m:
            errs.append("flash_causal_skip_us derived lost its "
                        "'steps A/B' accounting")
        else:
            skip, dense = int(m.group(1)), int(m.group(2))
            if not skip < dense:
                errs.append(f"skip grid did not skip: {skip}/{dense} steps")
            elif skip / dense > FLASH_SKIP_STEP_RATIO_MAX:
                errs.append(f"skip/dense step ratio {skip}/{dense} = "
                            f"{skip / dense:.2f} > "
                            f"{FLASH_SKIP_STEP_RATIO_MAX} (tolerance)")
    # fused decode: parse "maxdiff 1.23e-05 vs jnp"
    if "kern.decode_flash_us" not in rows:
        errs.append("missing row kern.decode_flash_us")
    else:
        m = re.search(r"maxdiff ([0-9.e+-]+)", rows["kern.decode_flash_us"][1])
        if not m:
            errs.append("decode_flash_us derived lost its maxdiff")
        elif float(m.group(1)) > KERNEL_VS_REF_MAXDIFF:
            errs.append(f"flash_decode vs jnp maxdiff {m.group(1)} > "
                        f"{KERNEL_VS_REF_MAXDIFF} (tolerance)")
    return errs


def _check_gemm(rec: dict, tiny: bool) -> list:
    errs = []
    rows = rows_by_name(rec)
    variants = ["fly_unfused", "fly_fused", "packed_unfused", "packed_fused"]
    for v in variants:
        if f"gemm.mlp_{v}_us" not in rows:
            errs.append(f"missing row gemm.mlp_{v}_us")
    if errs:
        return errs
    base = rows["gemm.mlp_fly_unfused_us"][0]
    fused = rows["gemm.mlp_packed_fused_us"][0]
    floor = (GEMM_PACKED_FUSED_SPEEDUP_MIN_TINY if tiny
             else GEMM_PACKED_FUSED_SPEEDUP_MIN)
    if fused <= 0 or base / fused < floor:
        errs.append(f"packed_fused speedup {base / max(fused, 1e-9):.2f}x < "
                    f"{floor}x vs fly_unfused (tolerance)")
    m = re.match(r"([0-9.]+)x vs fly_unfused",
                 rows["gemm.mlp_packed_fused_us"][1])
    if not m:
        errs.append("packed_fused derived lost its speedup annotation")
    elif abs(float(m.group(1)) - base / fused) > 0.05 * (base / fused) + 0.02:
        errs.append(f"packed_fused derived speedup {m.group(1)}x "
                    f"inconsistent with us columns ({base / fused:.2f}x)")
    return errs


def _check_serving(rec: dict, tiny: bool) -> list:
    errs = []
    rows = rows_by_name(rec)
    groups = sorted({m.group(1) for name in rows
                     if (m := re.match(r"serve\.((?:\w+_)?slots\d+)_", name))})
    if not groups:
        return ["no serve.slots rows found"]
    for g in groups:
        tps = _derived_float(rows, f"serve.{g}_gen_tok_per_s")
        if tps is None:
            errs.append(f"missing row serve.{g}_gen_tok_per_s")
        elif tps <= 0:
            errs.append(f"serve.{g} generated throughput {tps} <= 0")
        pd = rows.get(f"serve.{g}_prefill_vs_decode_tok")
        if pd is None:
            errs.append(f"missing row serve.{g}_prefill_vs_decode_tok")
        else:
            m = re.match(r"(\d+)/(\d+)", pd[1])
            if not m or int(m.group(2)) <= 0:
                errs.append(f"serve.{g} prefill/decode accounting "
                            f"malformed: {pd[1]!r}")
    return errs


_ERRCOST = re.compile(r"err=([0-9.e+-]+),cost=([0-9.e+-]+)")


def _check_tune(rec: dict, tiny: bool) -> list:
    errs = []
    rows = rows_by_name(rec)
    n_rungs = _derived_float(rows, "tune.plan_rungs")
    if n_rungs is None or n_rungs < 1:
        errs.append(f"tune.plan_rungs missing or < 1 ({n_rungs})")
    # uniform-e8 must be the most accurate uniform assignment
    uni = {}
    for name, (_, derived) in rows.items():
        m = re.match(r"tune\.uniform_e(\d+)$", name)
        if m and (ec := _ERRCOST.search(derived)):
            uni[int(m.group(1))] = (float(ec.group(1)), float(ec.group(2)))
    if 8 not in uni:
        errs.append("missing row tune.uniform_e8")
    elif uni[8][0] > min(e for e, _ in uni.values()) + 1e-12:
        errs.append(f"uniform_e8 is not the most accurate uniform rung: {uni}")
    # ladder: most accurate first => cost non-increasing, error non-decreasing
    ladder = []
    for name, (_, derived) in rows.items():
        m = re.match(r"tune\.rung_(\d+)$", name)
        if m and (ec := _ERRCOST.search(derived)):
            ladder.append((int(m.group(1)), float(ec.group(1)),
                           float(ec.group(2))))
    ladder.sort()
    for (r0, e0, c0), (r1, e1, c1) in zip(ladder, ladder[1:]):
        if c1 > c0 + 1e-9 or e1 < e0 - 1e-9:
            errs.append(f"ladder rung_{r1} (err={e1}, cost={c1}) breaks "
                        f"Pareto order vs rung_{r0} (err={e0}, cost={c0})")
    dom = rows.get("tune.dominated_uniform_rungs")
    if dom is None:
        errs.append("missing row tune.dominated_uniform_rungs")
    elif dom[1] == "none":
        errs.append("planned ladder dominates no uniform rung — the "
                    "per-layer tuning claim regressed")
    return errs


def _check_stream(rec: dict, tiny: bool) -> list:
    """Stream-serving invariants (ISSUE 7) — all scale-invariant:
    positive steady-state throughput, a Pareto-ordered PSNR-calibrated
    ladder whose per-rung PSNR is monotone non-increasing down the rungs,
    mixed-plan dominance over at least one uniform rung, and the QoS rung
    walk staying at ONE compiled step executable."""
    errs = []
    rows = rows_by_name(rec)
    slot_rows = [n for n in rows
                 if re.match(r"stream\.slots\d+_frames_per_s$", n)]
    if not slot_rows:
        errs.append("no stream.slotsN_frames_per_s rows found")
    for name in slot_rows:
        fps = _derived_float(rows, name)
        if fps is None or fps <= 0:
            errs.append(f"{name} throughput {fps} <= 0")
    # ladder: most accurate first => cost non-increasing, error (neg-PSNR)
    # non-decreasing — and the per-rung PSNR rows must tell the same story
    ladder = []
    for name, (_, derived) in rows.items():
        m = re.match(r"stream\.rung_(\d+)$", name)
        if m and (ec := _ERRCOST.search(derived)):
            ladder.append((int(m.group(1)), float(ec.group(1)),
                           float(ec.group(2))))
    if not ladder:
        errs.append("no stream.rung_N rows found")
    ladder.sort()
    for (r0, e0, c0), (r1, e1, c1) in zip(ladder, ladder[1:]):
        if c1 > c0 + 1e-9 or e1 < e0 - 1e-9:
            errs.append(f"stream ladder rung_{r1} (err={e1}, cost={c1}) "
                        f"breaks Pareto order vs rung_{r0} "
                        f"(err={e0}, cost={c0})")
    psnr = sorted((int(m.group(1)), _derived_float(rows, name))
                  for name in rows
                  if (m := re.match(r"stream\.rung_(\d+)_psnr_db$", name)))
    if len(psnr) != len(ladder):
        errs.append(f"{len(psnr)} rung PSNR rows for {len(ladder)} rungs")
    for (r0, p0), (r1, p1) in zip(psnr, psnr[1:]):
        if p0 is None or p1 is None:
            errs.append(f"stream.rung_{r1}_psnr_db not a number")
        elif p1 > p0 + 1e-6:
            errs.append(f"rung PSNR not monotone down the ladder: "
                        f"rung_{r1}={p1} dB > rung_{r0}={p0} dB")
    dom = rows.get("stream.dominated_uniform_rungs")
    if dom is None:
        errs.append("missing row stream.dominated_uniform_rungs")
    elif dom[1] == "none":
        errs.append("stream plan dominates no uniform rung — the PSNR "
                    "per-site calibration claim regressed")
    compiles = _derived_float(rows, "stream.qos_walk_compiles")
    if compiles is None:
        errs.append("missing row stream.qos_walk_compiles")
    elif compiles != 1:
        errs.append(f"QoS rung walk compiled {compiles} step executables "
                    f"(expected exactly 1 — degree operand shape-stability)")
    return errs


def _kv_ints(text: str) -> dict:
    """Parse a ``k=v`` mix string (``ok=3,shed=9``; ``;`` separates runs)
    into {key: int}; repeated keys accumulate across runs."""
    out: dict = {}
    for part in re.split(r"[;,]", text):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k.strip()] = out.get(k.strip(), 0) + int(v)
            except ValueError:
                pass
    return out


def _check_chaos(rec: dict, tiny: bool) -> list:
    """Resilience invariants (ISSUE 8) — all scale-invariant:

    * **brownout dominance** — goodput (in-deadline completions per virtual
      second) under ladder degradation must be >= the shed-only policy at
      the same overload burst; this is the graceful-degradation headline.
    * **containment** — ``chaos.storm_corrupt_payloads`` must be 0: no
      injected SEU/NaN ever reaches an emitted payload.
    * **accounting** — every ``lost= / dup= / short=`` counter in every
      accounting row must be 0 (requests terminate exactly once).
    * **non-vacuity** — the storm injected >= 1 fault and tripped >= 1
      guard, so the containment claim is load-bearing.
    * **deadline classes** — the loose class misses no more than the tight.
    * **determinism** — same fault seed reproduced schedule, recovery
      trace, and payload bits (``chaos.determinism == "identical"``).
    """
    errs = []
    rows = rows_by_name(rec)
    gp_shed = _derived_float(rows, "chaos.overload_shed_goodput")
    gp_brown = _derived_float(rows, "chaos.overload_brownout_goodput")
    if gp_shed is None or gp_brown is None:
        errs.append("missing chaos.overload_*_goodput rows")
    else:
        if gp_shed <= 0 or gp_brown <= 0:
            errs.append(f"overload goodput not positive "
                        f"(shed={gp_shed}, brownout={gp_brown})")
        if gp_brown < gp_shed - 1e-9:
            errs.append(f"brownout goodput {gp_brown}/s < shed-only "
                        f"{gp_shed}/s — graceful degradation no longer "
                        f"dominates availability-by-shedding")
    rungs = _derived_float(rows, "chaos.overload_brownout_rungs")
    if rungs is None or rungs < 1:
        errs.append(f"chaos.overload_brownout_rungs missing or < 1 "
                    f"({rungs}) — the brownout run never browned out")
    corrupt = _derived_float(rows, "chaos.storm_corrupt_payloads")
    if corrupt is None:
        errs.append("missing row chaos.storm_corrupt_payloads")
    elif corrupt != 0:
        errs.append(f"{int(corrupt)} corrupt payloads escaped the guards")
    for name in ("chaos.storm_injected", "chaos.storm_recovery"):
        if name not in rows:
            errs.append(f"missing row {name}")
    if "chaos.storm_injected" in rows:
        inj = _kv_ints(rows["chaos.storm_injected"][1])
        if sum(inj.values()) < 1:
            errs.append("fault storm injected nothing — containment claim "
                        "is vacuous")
    if "chaos.storm_recovery" in rows:
        recov = _kv_ints(rows["chaos.storm_recovery"][1])
        if recov.get("trips", 0) < 1:
            errs.append("fault storm tripped no guard — recovery claim is "
                        "vacuous")
    for name in ("chaos.overload_accounting", "chaos.storm_accounting",
                 "chaos.mixed_accounting"):
        if name not in rows:
            errs.append(f"missing row {name}")
            continue
        acct = _kv_ints(rows[name][1])
        bad = {k: v for k, v in acct.items() if v != 0}
        if bad:
            errs.append(f"{name} nonzero: {bad} (lost/duplicated/"
                        f"short-changed requests)")
    miss = rows.get("chaos.mixed_deadline_miss")
    if miss is None:
        errs.append("missing row chaos.mixed_deadline_miss")
    else:
        m = re.match(r"tight=([0-9.]+),loose=([0-9.]+)", miss[1])
        if not m:
            errs.append(f"chaos.mixed_deadline_miss malformed: {miss[1]!r}")
        elif float(m.group(2)) > float(m.group(1)) + 1e-9:
            errs.append(f"loose-deadline class missed more than tight "
                        f"({miss[1]})")
    det = rows.get("chaos.determinism")
    if det is None:
        errs.append("missing row chaos.determinism")
    elif det[1] != "identical":
        errs.append(f"chaos.determinism = {det[1]!r} — same fault seed "
                    f"no longer reproduces the run")
    return errs


def _check_elastic(rec: dict, tiny: bool) -> list:
    """Elastic fleet-serving invariants (ISSUE 9) — all scale-invariant:

    * **goodput through the kill** — ok completions per virtual second
      must be positive both before the replica loss and after the rescale
      on the survivor mesh (the fleet kept serving through the event).
    * **replica arithmetic** — ``elastic.fleet_replicas`` reads ``A->B``
      with ``B == A - 1``: exactly one replica died, the rest survived.
    * **exactly-once accounting** — every ``lost= / dup= / short=``
      counter must be 0 fleet-wide, and
      ``elastic.fleet_corrupt_payloads`` must be 0: rewound requests
      re-decode bit-identically to the clean reference.
    * **ragged planning** — the 7-survivor plan factors
      (``pods*data*model + idle == devices``) with surplus devices parked
      idle instead of the recovery path raising.
    * **determinism** — same loss seed reproduced the kill schedule, the
      fleet recovery trace, and every payload bit
      (``elastic.determinism == "identical"``).
    * **collective budget** — the int8 ring decode step moves at most
      half the exact-f32 collective wire bytes (both measured > 0 from
      compiled HLO).
    """
    errs = []
    rows = rows_by_name(rec)
    gp_before = _derived_float(rows, "elastic.fleet_goodput_before")
    gp_after = _derived_float(rows, "elastic.fleet_goodput_after")
    if gp_before is None or gp_after is None:
        errs.append("missing elastic.fleet_goodput_before/after rows")
    else:
        if gp_before <= 0:
            errs.append(f"pre-kill goodput not positive ({gp_before})")
        if gp_after <= 0:
            errs.append(f"post-rescale goodput not positive ({gp_after}) — "
                        f"the survivor mesh never resumed serving")
    reps = rows.get("elastic.fleet_replicas")
    if reps is None:
        errs.append("missing row elastic.fleet_replicas")
    else:
        m = re.match(r"(\d+)->(\d+)$", reps[1])
        if not m:
            errs.append(f"elastic.fleet_replicas malformed: {reps[1]!r}")
        elif int(m.group(2)) != int(m.group(1)) - 1:
            errs.append(f"replica count {reps[1]} is not a kill-one event")
    acct = rows.get("elastic.fleet_accounting")
    if acct is None:
        errs.append("missing row elastic.fleet_accounting")
    else:
        bad = {k: v for k, v in _kv_ints(acct[1]).items() if v != 0}
        if bad:
            errs.append(f"fleet accounting nonzero: {bad} (lost/duplicated/"
                        f"short-changed requests)")
    corrupt = _derived_float(rows, "elastic.fleet_corrupt_payloads")
    if corrupt is None:
        errs.append("missing row elastic.fleet_corrupt_payloads")
    elif corrupt != 0:
        errs.append(f"{int(corrupt)} payloads diverged from the clean "
                    f"reference across the replica loss")
    ragged = rows.get("elastic.ragged_plan")
    if ragged is None:
        errs.append("missing row elastic.ragged_plan")
    else:
        kv = _kv_ints(ragged[1])
        used = kv.get("data", 0) * kv.get("model", 0)
        if used + kv.get("idle", -1) != kv.get("devices", 0):
            errs.append(f"ragged plan does not account for every survivor: "
                        f"{ragged[1]!r}")
        elif kv.get("idle", 0) < 1:
            errs.append(f"ragged plan reports no idle devices ({ragged[1]!r})"
                        f" — the case stopped being ragged")
    det = rows.get("elastic.determinism")
    if det is None:
        errs.append("missing row elastic.determinism")
    elif det[1] != "identical":
        errs.append(f"elastic.determinism = {det[1]!r} — same loss seed no "
                    f"longer reproduces the recovery")
    cb = rows.get("elastic.decode_collective_bytes")
    if cb is None:
        errs.append("missing row elastic.decode_collective_bytes")
    else:
        kv = _kv_ints(cb[1])
        ring, f32 = kv.get("ring", 0), kv.get("f32", 0)
        if ring <= 0 or f32 <= 0:
            errs.append(f"collective byte counts not positive ({cb[1]!r})")
        elif ring > 0.5 * f32:
            errs.append(f"int8 ring decode bytes {ring} exceed half the "
                        f"f32 budget {f32} — collective compression "
                        f"regressed")
    return errs


def _check_admission(rec: dict, tiny: bool) -> list:
    """Admission-pipeline invariants (ISSUE 10) — all scale-invariant:

    * **closed executable set** — ``post_warmup_traces`` must be 0 over the
      bursty mixed-length prompt run: the bucket ladder + warmup traced
      every prefill/chunk/step executable at startup, so no live request
      ever compiles.  Non-vacuity: >= 2 buckets warmed, >= 1 prompt served.
    * **packed throughput** — admitted-requests/s via pack=4 bucketed
      prefill calls must be >= 1.5x the one-row-at-a-time baseline
      (>= 1.0x on tiny CI shapes, where iteration counts are too small to
      pin a ratio).
    * **chunked TTFT** — under the modeled-cost virtual clock, the
      short-request TTFT p99 behind a long arrival must be strictly lower
      with chunked admission than with the monolithic-prefill baseline.
    * **exactly-once accounting** — every ``lost= / dup= / short=``
      counter across both TTFT runs must be 0.
    """
    errs = []
    rows = rows_by_name(rec)
    zr = rows.get("adm.zero_recompile")
    if zr is None:
        errs.append("missing row adm.zero_recompile")
    else:
        kv = _kv_ints(zr[1])
        if kv.get("post_warmup_traces", -1) != 0:
            errs.append(f"post-warmup recompiles: {zr[1]!r} — the bucket "
                        f"ladder no longer closes the executable set")
        if kv.get("buckets", 0) < 2:
            errs.append(f"fewer than 2 buckets warmed ({zr[1]!r}) — the "
                        f"zero-recompile claim is vacuous")
        if kv.get("prompts", 0) < 1 or kv.get("ok", 0) < 1:
            errs.append(f"no prompts served ok in the recompile probe "
                        f"({zr[1]!r})")
    sp = rows.get("adm.packed_speedup")
    if sp is None:
        errs.append("missing row adm.packed_speedup")
    else:
        x100 = _kv_ints(sp[1]).get("speedup_x100", 0)
        floor = 100 if tiny else 150
        if x100 < floor:
            errs.append(f"packed admission speedup {x100 / 100:.2f}x < "
                        f"{floor / 100:.1f}x sequential — prompt packing "
                        f"regressed")
    tt = rows.get("adm.chunked_ttft")
    if tt is None:
        errs.append("missing row adm.chunked_ttft")
    else:
        kv = _kv_ints(tt[1])
        c, u = kv.get("chunked_p99_us", -1), kv.get("unchunked_p99_us", 0)
        if c < 0 or u <= 0:
            errs.append(f"TTFT p99s not positive ({tt[1]!r})")
        elif c >= u:
            errs.append(f"chunked TTFT p99 {c}us >= unchunked {u}us — "
                        f"chunked prefill no longer bounds short-request "
                        f"latency")
    acct = rows.get("adm.chunked_accounting")
    if acct is None:
        errs.append("missing row adm.chunked_accounting")
    else:
        bad = {k: v for k, v in _kv_ints(acct[1]).items() if v != 0}
        if bad:
            errs.append(f"admission accounting nonzero: {bad} (lost/"
                        f"duplicated/short-changed requests)")
    return errs


_CHECKS: dict = {
    "bench_kernels": _check_kernels,
    "bench_serving": _check_serving,
    "bench_gemm": _check_gemm,
    "bench_tune": _check_tune,
    "bench_stream": _check_stream,
    "bench_chaos": _check_chaos,
    "bench_elastic": _check_elastic,
    "bench_admission": _check_admission,
}


def check_invariants(rec: dict, tiny: Optional[bool] = None) -> list:
    bench = rec.get("bench")
    fn: Optional[Callable] = _CHECKS.get(bench)
    if fn is None:
        return [f"unknown bench {bench!r} (no declared invariants)"]
    if tiny is None:
        tiny = bool(rec.get("tiny_shapes", False))
    try:
        return [f"{bench}: {e}" for e in fn(rec, tiny)]
    except Exception as e:               # malformed rows fail loudly, not raise
        return [f"{bench}: invariant check crashed: {e!r}"]


def check_record(rec: dict, tiny: Optional[bool] = None) -> list:
    """Meta integrity + declared invariants for one record."""
    errs = [f"{rec.get('bench', '?')}: {e}" for e in check_meta(rec)]
    return errs + check_invariants(rec, tiny)


def check_committed(directory=None, benches=None) -> list:
    """Check every committed BENCH record; returns all violations."""
    errs = []
    for bench in benches or sorted(BENCH_RECORDS):
        try:
            rec = load_record(bench, directory)
        except FileNotFoundError:
            errs.append(f"{bench}: committed record "
                        f"{BENCH_RECORDS[bench]} missing")
            continue
        except json.JSONDecodeError as e:
            errs.append(f"{bench}: committed record unparseable: {e}")
            continue
        errs.extend(check_record(rec))
    return errs


# ---------------------------------------------------------------------------
# fresh-run diff
# ---------------------------------------------------------------------------


def run_fresh_rows(bench: str) -> list:
    """Run one bench module in-process and return its rows.  Honors
    ``REPRO_BENCH_TINY`` (set it to "1" before first import for the tiny
    CI shapes).  Requires the repo root on sys.path (benchmarks/ package)."""
    import importlib

    mod = importlib.import_module(f"benchmarks.{bench}")
    return list(mod.rows())


def compare_fresh(committed: dict, fresh: dict) -> list:
    """Diff a fresh record against the committed one.

    Coverage: every fresh row name must exist in the committed record —
    a row that vanished from the committed side means the record rotted
    behind the bench.  (Committed-only rows are fine: full-shape runs emit
    supersets of the tiny CI shapes.)  The fresh record must also satisfy
    the same declared invariants, at tiny tolerances when applicable."""
    bench = committed.get("bench")
    errs = []
    if fresh.get("bench") != bench:
        return [f"bench mismatch: committed {bench!r} vs "
                f"fresh {fresh.get('bench')!r}"]
    try:
        cnames = set(rows_by_name(committed))
        fnames = set(rows_by_name(fresh))
    except ValueError as e:
        return [f"{bench}: {e}"]
    missing = sorted(fnames - cnames)
    if missing:
        errs.append(f"{bench}: fresh rows missing from the committed "
                    f"record (regenerate it via benchmarks/run.py): "
                    f"{missing}")
    errs.extend(check_invariants(fresh))
    return errs
