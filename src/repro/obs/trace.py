"""Process-global structured tracing with Chrome ``trace_event`` export.

The runtime-adjustable approximation scheme is only trustworthy if the
system can *show* which degree served which request and what it cost
(DESIGN.md §11).  This tracer is the zero-dependency substrate: bounded
ring buffers of span / instant / counter events, nestable via context
manager, exportable as Chrome ``trace_event`` JSON — the file loads
directly in ``chrome://tracing`` / Perfetto.

Contract:

  * **disabled is free** — the global tracer starts disabled; ``span()``
    returns a shared no-op context manager and ``event()`` returns
    immediately, so instrumented hot paths (the serve tick, the train
    step) pay one predicate per call site.
  * **bounded** — events land in a ``deque(maxlen=capacity)``; overflow
    evicts the oldest and increments ``dropped`` (long-lived engines never
    leak).
  * **tracks** — every event carries a ``track`` (engine / train / a
    request id); tracks become Chrome thread lanes with ``thread_name``
    metadata so the viewer groups the timeline sensibly.

Usage::

    from repro.obs import trace
    trace.enable()
    with trace.span("prefill", rid=3, tokens=17):
        ...
    trace.event("qos_rung", degrees=[8, 7, 6])
    trace.get_tracer().write("trace.json")      # open in chrome://tracing
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

__all__ = ["Tracer", "get_tracer", "set_tracer", "enable", "disable",
           "span", "event", "counter"]


class _NullSpan:
    """Shared no-op context manager handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a Chrome complete event ('X') on exit."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._emit({
            "name": self._name, "ph": "X", "ts": self._tracer._us(self._t0),
            "dur": round((t1 - self._t0) * 1e6, 3),
            "pid": self._tracer.pid, "tid": self._tracer._tid(self._track),
            "cat": "repro", "args": self._args,
        })
        return False


class Tracer:
    """Bounded ring buffer of Chrome ``trace_event`` dicts.

    ``enabled`` gates every recording call; flip it with
    :meth:`enable` / :meth:`disable` (also settable at construction).  The
    buffer holds at most ``capacity`` events — old events are evicted and
    counted in :attr:`dropped`.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.pid = os.getpid()
        self.dropped = 0
        self._events: deque = deque(maxlen=self.capacity)
        self._tracks: dict = {}          # track name -> tid int
        self._meta: list = []            # thread_name metadata events
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    # ---- control -----------------------------------------------------

    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    # ---- recording ---------------------------------------------------

    def _us(self, t: float) -> float:
        return round((t - self._epoch) * 1e6, 3)

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(track, len(self._tracks) + 1)
                self._meta.append({
                    "name": "thread_name", "ph": "M", "pid": self.pid,
                    "tid": tid, "args": {"name": track},
                })
        return tid

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    def span(self, name: str, track: str = "main", **args):
        """Context manager timing a nested region; a no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, track, args)

    def event(self, name: str, track: str = "main", **args) -> None:
        """Instant event ('i') — a point-in-time marker with payload."""
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "i", "s": "t",
                    "ts": self._us(time.perf_counter()), "pid": self.pid,
                    "tid": self._tid(track), "cat": "repro", "args": args})

    def counter(self, name: str, track: str = "main", **values) -> None:
        """Counter event ('C') — plotted as a stacked series in the viewer."""
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "C",
                    "ts": self._us(time.perf_counter()), "pid": self.pid,
                    "tid": self._tid(track), "args": values})

    # ---- export ------------------------------------------------------

    @property
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """The ``chrome://tracing`` / Perfetto JSON object."""
        return {"traceEvents": self._meta + self.events,
                "displayTimeUnit": "ms",
                "otherData": {"tracer": "repro.obs", "dropped": self.dropped}}

    def write(self, path) -> str:
        """Serialize to ``path``; returns the path written."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return str(path)


# ---------------------------------------------------------------------------
# process-global tracer (the one the engine / trainer / dispatch instrument)
# ---------------------------------------------------------------------------

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Swap the process-global tracer (tests); None installs a fresh
    disabled one.  Returns the installed tracer."""
    global _GLOBAL
    _GLOBAL = tracer if tracer is not None else Tracer()
    return _GLOBAL


def enable(capacity: Optional[int] = None) -> Tracer:
    """Enable the global tracer (optionally resizing its ring buffer)."""
    global _GLOBAL
    if capacity is not None and capacity != _GLOBAL.capacity:
        _GLOBAL = Tracer(capacity=capacity)
    return _GLOBAL.enable()


def disable() -> Tracer:
    return _GLOBAL.disable()


def span(name: str, track: str = "main", **args):
    return _GLOBAL.span(name, track=track, **args)


def event(name: str, track: str = "main", **args) -> None:
    _GLOBAL.event(name, track=track, **args)


def counter(name: str, track: str = "main", **values) -> None:
    _GLOBAL.counter(name, track=track, **values)
