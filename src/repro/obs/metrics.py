"""Typed metric registry with a Prometheus text-format exporter.

One registry is the single source for every counter the stack maintains:
the serve engine's token/step counters and latency histograms
(``serve/metrics.py::EngineStats`` is a thin view over one of these),
per-backend kernel-route counters (``kernels/dispatch.py``), the
``repro_degree_ebits{site=..}`` gauge family, the trainer's step/loss
series, and the online quality telemetry (``obs/quality.py``).

Zero dependencies: the exporter emits the Prometheus text exposition
format (``# HELP`` / ``# TYPE`` + samples; histograms as cumulative
``_bucket{le=..}`` + ``_sum`` + ``_count``) and :func:`parse_text` parses
it back — the round-trip is under test, so ``--metrics-out`` artifacts
are guaranteed scrapeable.

  reg = Registry()
  c = reg.counter("repro_decode_steps_total", "engine ticks")
  c.inc()
  h = reg.histogram("repro_ttft_seconds", "enqueue->first token")
  h.observe(0.031)
  routes = reg.counter("repro_kernel_route_steps_total", "ticks by backend",
                       labels=("site", "backend"))
  routes.labels(site="decode", backend="pallas").inc()
  text = reg.to_prometheus()          # scrape / --metrics-out artifact
  snap = reg.snapshot()               # JSON-able dict
"""

from __future__ import annotations

import math
import re
import threading
from typing import Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "get_registry",
           "set_registry", "parse_text", "DEFAULT_BUCKETS"]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram bucket upper bounds (seconds-flavored, latency-friendly)
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    """Prometheus sample value formatting: integers stay integral."""
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Counter:
    """Monotone float counter."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0 (got {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Settable instantaneous value."""

    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics) that also keeps
    exact count/sum; ``observe`` is O(#buckets)."""

    __slots__ = ("buckets", "_counts", "_sum", "_count", "_lock")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bs
        self._counts = [0] * len(bs)      # per-bucket (non-cumulative)
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._sum += v
            self._count += 1
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self._counts[i] += 1
                    break

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative(self) -> list:
        """[(le, cumulative_count)] + implicit +Inf == count."""
        out, acc = [], 0
        for b, c in zip(self.buckets, self._counts):
            acc += c
            out.append((b, acc))
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric family; labelless families hold a single child
    (``.inc`` / ``.set`` / ``.observe`` proxy straight through), labelled
    families intern children per label-value tuple via :meth:`labels`."""

    def __init__(self, name: str, help_: str, kind: str,
                 labelnames: Sequence[str] = (), **kwargs):
        self.name = name
        self.help = help_
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self._kwargs = kwargs
        self._children: dict = {}
        if not self.labelnames:
            self._children[()] = _KINDS[kind](**kwargs)

    def labels(self, **labelvalues):
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labelvalues)}")
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._children.setdefault(key, _KINDS[self.kind](**self._kwargs))
        return child

    @property
    def children(self) -> dict:
        return dict(self._children)

    # labelless convenience proxies
    def _solo(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labelled; use .labels(...)")
        return self._children[()]

    def inc(self, n: float = 1.0):
        self._solo().inc(n)

    def set(self, v: float):
        self._solo().set(v)

    def observe(self, v: float):
        self._solo().observe(v)

    @property
    def value(self) -> float:
        return self._solo().value

    @property
    def count(self) -> int:
        return self._solo().count

    @property
    def sum(self) -> float:
        return self._solo().sum


class Registry:
    """Metric family registry.  Registration is idempotent: re-declaring a
    family with the same (kind, labelnames) returns the existing one, so
    module-level call sites (kernel dispatch) and object call sites (the
    engine) can share families without import-order coupling."""

    def __init__(self):
        self._families: dict = {}
        self._lock = threading.Lock()

    def _register(self, name: str, help_: str, kind: str,
                  labels: Sequence[str], **kwargs) -> Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind} "
                        f"with labels {fam.labelnames}")
                return fam
            fam = Family(name, help_, kind, labels, **kwargs)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._register(name, help_, "counter", labels)

    def gauge(self, name: str, help_: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._register(name, help_, "gauge", labels)

    def histogram(self, name: str, help_: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        return self._register(name, help_, "histogram", labels,
                              buckets=buckets)

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    @property
    def families(self) -> dict:
        return dict(self._families)

    # ---- export ------------------------------------------------------

    @staticmethod
    def _labelstr(names: tuple, values: tuple, extra: str = "") -> str:
        parts = [f'{k}="{v}"' for k, v in zip(names, values)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (round-trips :func:`parse_text`)."""
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam.children):
                child = fam.children[key]
                if fam.kind == "histogram":
                    for le, acc in child.cumulative():
                        ls = self._labelstr(fam.labelnames, key,
                                            f'le="{_fmt(le)}"')
                        lines.append(f"{name}_bucket{ls} {acc}")
                    ls = self._labelstr(fam.labelnames, key, 'le="+Inf"')
                    lines.append(f"{name}_bucket{ls} {child.count}")
                    ls = self._labelstr(fam.labelnames, key)
                    lines.append(f"{name}_sum{ls} {_fmt(child.sum)}")
                    lines.append(f"{name}_count{ls} {child.count}")
                else:
                    ls = self._labelstr(fam.labelnames, key)
                    lines.append(f"{name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able nested dict of every family/child (``--metrics-out``
        twin artifact; also the programmatic read API)."""
        out: dict = {}
        for name, fam in sorted(self._families.items()):
            children = {}
            for key, child in sorted(fam.children.items()):
                lk = ",".join(f"{k}={v}" for k, v in zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    children[lk] = {"count": child.count, "sum": child.sum,
                                    "buckets": {_fmt(le): acc for le, acc
                                                in child.cumulative()}}
                else:
                    children[lk] = child.value
            out[name] = {"type": fam.kind, "help": fam.help,
                         "values": children}
        return out

    def write(self, path) -> str:
        with open(path, "w") as f:
            f.write(self.to_prometheus())
        return str(path)


# ---------------------------------------------------------------------------
# text-format parser (round-trip tests; tools that read --metrics-out)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_text(text: str) -> dict:
    """Parse Prometheus exposition text into
    ``{(name, ((label, value), ...)): float}`` — histogram series appear
    under their ``_bucket`` / ``_sum`` / ``_count`` sample names, exactly
    as a scraper sees them."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        labels = tuple(sorted(_LABEL_PAIR_RE.findall(m.group("labels") or "")))
        raw = m.group("value")
        val = math.inf if raw == "+Inf" else float(raw)
        out[(m.group("name"), labels)] = val
    return out


# ---------------------------------------------------------------------------
# process-global registry (kernel dispatch counters; launch exporters)
# ---------------------------------------------------------------------------

_GLOBAL = Registry()


def get_registry() -> Registry:
    return _GLOBAL


def set_registry(registry: Optional[Registry]) -> Registry:
    """Swap the process-global registry (tests); None installs a fresh one."""
    global _GLOBAL
    _GLOBAL = registry if registry is not None else Registry()
    return _GLOBAL
