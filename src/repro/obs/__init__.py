"""repro.obs — end-to-end observability for the approximate serving stack.

Zero-dependency tracing + metrics + quality telemetry (DESIGN.md §11):

  * :mod:`repro.obs.trace` — process-global span/instant tracer with
    bounded ring buffers and Chrome ``trace_event`` export
    (``chrome://tracing`` / Perfetto).
  * :mod:`repro.obs.metrics` — typed counter/gauge/histogram registry
    with a Prometheus text exporter and a JSON snapshot.
  * :mod:`repro.obs.quality` — online per-rung logit-error telemetry
    (the serving-time twin of the calibration prober).
  * :mod:`repro.obs.regress` — the bench-record regression gate behind
    ``tools/check_bench.py``.

The runtime-adjustable approximation scheme is only trustworthy if the
system can show which degree served which request and what it cost; this
package is that evidence layer.
"""

from repro.obs.metrics import Registry, get_registry, parse_text
from repro.obs.quality import QualityTap
from repro.obs.trace import Tracer, get_tracer

__all__ = ["Registry", "get_registry", "parse_text", "QualityTap",
           "Tracer", "get_tracer", "trace", "metrics"]

from repro.obs import metrics, trace  # noqa: E402  (re-export modules)
