"""Slot-lifecycle primitives over decode caches (serving subsystem).

All cache types (``LMCache``/``LMCacheQ``/``SSMCache``/``HybridCache``) are
NamedTuples that share one layout convention: the per-slot ``length`` vector
has the batch (= slot) dim at axis 0, every other field carries a leading
stack axis (layers / groups / recurrent-blocks) with batch at axis 1.  These
helpers exploit that convention generically, so the serve engine never
special-cases a model family:

  * :func:`cache_reset_slot` — rewind one slot's region (KV, recurrent state,
    conv tail, length) to the init state.  Called on admission so a reused
    slot is indistinguishable from a fresh one (the stale-slot pollution fix).
  * :func:`cache_mask_update` — freeze free slots' ``length`` at its
    pre-step value inside the fused serve step, masking them out of the
    batch: a pinned length pins both the slot's KV write position and its
    valid-range read mask, so the region never advances.

Both are pure functions of arrays and trace cleanly under ``jax.jit`` with
``slot`` / ``active`` as traced arguments (no recompile per slot).
"""

from __future__ import annotations

import jax.numpy as jnp


def cache_reset_slot(cache, slot):
    """Zero slot ``slot``'s region in every field and rewind its length.

    ``slot`` may be a traced int32 scalar.  Returns a new cache NamedTuple.
    """
    out = []
    for name in cache._fields:
        o = getattr(cache, name)
        if name == "length":
            out.append(o.at[slot].set(0))
        else:
            out.append(o.at[:, slot].set(jnp.zeros_like(o[:, 0])))
    return type(cache)(*out)


def cache_mask_update(old_cache, new_cache, active):
    """Mask free slots out of a decode-step cache update: slots where
    ``active`` (bool (B,)) is False keep their pre-step ``length``.

    Freezing length is sufficient — and O(slots) instead of an O(cache)
    per-field select: position-gated caches (KV rings) then rewrite one
    fixed position with values the valid-range mask never exposes, state
    caches (SSM/RG-LRU h, conv) may accumulate garbage in free slots, and
    :func:`cache_reset_slot` rewinds the whole region on admission before
    any of it can be read.  Reuse-after-free bit-identity is asserted per
    family by ``test_slot_reuse_after_free``.
    """
    length = jnp.where(active, new_cache.length, old_cache.length)
    return new_cache._replace(length=length)


def ring_write_indices(prompt_len: int, capacity: int):
    """Static index plan for writing a ``prompt_len`` prefix into a cache
    ring of ``capacity`` positions: keep the last ``n = min(P, T)`` tokens,
    mapped to ring positions ``src % T`` (identity while P <= T).  Returns
    (src_idx (n,), dst_idx (n,)) as numpy-backed jnp arrays."""
    n = min(prompt_len, capacity)
    src = jnp.arange(prompt_len - n, prompt_len, dtype=jnp.int32)
    dst = jnp.mod(src, capacity)
    return src, dst
