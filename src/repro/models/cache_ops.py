"""Slot-lifecycle primitives over decode caches (serving subsystem).

All cache types (``LMCache``/``LMCacheQ``/``SSMCache``/``HybridCache``) are
NamedTuples that share one layout convention: the per-slot ``length`` vector
has the batch (= slot) dim at axis 0, every other field carries a leading
stack axis (layers / groups / recurrent-blocks) with batch at axis 1.  These
helpers exploit that convention generically, so the serve engine never
special-cases a model family:

  * :func:`cache_reset_slot` — rewind one slot's region (KV, recurrent state,
    conv tail, length) to the init state.  Called on admission so a reused
    slot is indistinguishable from a fresh one (the stale-slot pollution fix).
  * :func:`cache_mask_update` — freeze free slots' ``length`` at its
    pre-step value inside the fused serve step, masking them out of the
    batch: a pinned length pins both the slot's KV write position and its
    valid-range read mask, so the region never advances.

Both are pure functions of arrays and trace cleanly under ``jax.jit`` with
``slot`` / ``active`` as traced arguments (no recompile per slot).

The same layout convention makes SEU-style fault injection generic too
(``repro.resil.faults``): :func:`bit_flip` flips one bit of one element of
any array (floats via ``lax.bitcast_convert_type`` — jit-safe, no host
round-trip), and :func:`cache_bit_flip` targets the flip at one slot's
region of one cache field, so the fault injector never special-cases a
model family either.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_FLOAT_BITS = {2: jnp.uint16, 4: jnp.uint32}


def cache_reset_slot(cache, slot):
    """Zero slot ``slot``'s region in every field and rewind its length.

    ``slot`` may be a traced int32 scalar.  Returns a new cache NamedTuple.
    """
    out = []
    for name in cache._fields:
        o = getattr(cache, name)
        if name == "length":
            out.append(o.at[slot].set(0))
        else:
            out.append(o.at[:, slot].set(jnp.zeros_like(o[:, 0])))
    return type(cache)(*out)


def cache_mask_update(old_cache, new_cache, active):
    """Mask free slots out of a decode-step cache update: slots where
    ``active`` (bool (B,)) is False keep their pre-step ``length``.

    Freezing length is sufficient — and O(slots) instead of an O(cache)
    per-field select: position-gated caches (KV rings) then rewrite one
    fixed position with values the valid-range mask never exposes, state
    caches (SSM/RG-LRU h, conv) may accumulate garbage in free slots, and
    :func:`cache_reset_slot` rewinds the whole region on admission before
    any of it can be read.  Reuse-after-free bit-identity is asserted per
    family by ``test_slot_reuse_after_free``.
    """
    length = jnp.where(active, new_cache.length, old_cache.length)
    return new_cache._replace(length=length)


def bit_flip(arr, index, bit):
    """Flip bit ``bit`` of the ``index``-th element of ``arr`` (flattened
    order); returns a new array, same shape/dtype.  Floats (f32/bf16/f16)
    are flipped through an unsigned bitcast view so the operation is exact
    bit manipulation, not arithmetic; ``index``/``bit`` may be traced.
    Host (numpy) arrays — e.g. prepacked weight leaves — are coerced to
    device arrays, so the result type is uniformly jax."""
    arr = jnp.asarray(arr)
    flat = arr.reshape(-1)
    if jnp.issubdtype(arr.dtype, jnp.floating):
        bits_ty = _FLOAT_BITS[arr.dtype.itemsize]
        u = jax.lax.bitcast_convert_type(flat, bits_ty)
        mask = jnp.left_shift(jnp.asarray(1, bits_ty),
                              jnp.asarray(bit, bits_ty))
        u = u.at[index].set(u[index] ^ mask)
        flat = jax.lax.bitcast_convert_type(u, arr.dtype)
    else:
        mask = jnp.left_shift(jnp.asarray(1, arr.dtype),
                              jnp.asarray(bit, arr.dtype))
        flat = flat.at[index].set(flat[index] ^ mask)
    return flat.reshape(arr.shape)


def cache_bit_flip(cache, name: str, slot, index, bit):
    """SEU injection primitive (repro.resil.faults): flip one bit at flat
    offset ``index`` inside slot ``slot``'s region of cache field ``name``.
    ``length`` is excluded — corrupting the slot cursor is a scheduler
    fault, not a memory upset.  Returns a new cache NamedTuple; only the
    named slot region changes."""
    if name == "length":
        raise ValueError("cache_bit_flip targets state regions, not length")
    o = getattr(cache, name)
    region = o[:, slot]
    flipped = bit_flip(region, index, bit)
    return cache._replace(**{name: o.at[:, slot].set(flipped)})


def ring_write_indices(prompt_len: int, capacity: int):
    """Static index plan for writing a ``prompt_len`` prefix into a cache
    ring of ``capacity`` positions: keep the last ``n = min(P, T)`` tokens,
    mapped to ring positions ``src % T`` (identity while P <= T).  Returns
    (src_idx (n,), dst_idx (n,)) as numpy-backed jnp arrays."""
    n = min(prompt_len, capacity)
    src = jnp.arange(prompt_len - n, prompt_len, dtype=jnp.int32)
    dst = jnp.mod(src, capacity)
    return src, dst
