"""Per-layer approximation degrees — the runtime half of an ApproxPlan.

Scan-over-layers models share one parameter *path* across every stacked
layer, so the path-keyed ``ApproxPolicy`` (DESIGN.md §2.3) cannot assign a
different degree per layer.  This module defines the convention that can:
the runtime ``degree`` argument of every model entry point
(``Model.forward`` / ``loss`` / ``prefill`` / ``decode_step``) accepts

  * ``None``        — static policy degrees only (no traced knob);
  * a scalar        — one global DyFXU degree, broadcast to every site
                      (the pre-plan behavior, still bit-identical);
  * a ``(n_layers + 1,)`` int32 vector — one degree per *site*: entry ``i``
    drives layer ``i``'s projections (attention, MLP, MoE experts, SSM /
    RG-LRU projections), entry ``n_layers`` drives the head sites (tied /
    dense unembedding and the vision/audio frontend projections).

The vector is a **traced** operand: the model scan consumes it as a scan
input alongside the stacked layer params, so each layer's kernels receive a
scalar slice (the scalar-prefetch DyFXU knob of kernels/axqmm.py and
kernels/flash_decode.py) and moving any entry never recompiles the
executable.  Layer order is the architecture's stacking order; for the
hybrid (RG-LRU) family that is group-major — layer ``g * len(pattern) + i``
is block ``i`` of group ``g`` — followed by the tail blocks.

``repro.tune`` emits plans whose ladder points are exactly these vectors.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

Array = jnp.ndarray


def num_sites(cfg) -> int:
    """Number of degree sites for an architecture: one per layer plus one
    shared head site (unembedding + frontend projections)."""
    return cfg.n_layers + 1


def split_degree(degree, n_layers: int) -> tuple[Optional[Array], Optional[Array]]:
    """Normalize a runtime ``degree`` into (per-layer vector, head scalar).

    ``None`` passes through as ``(None, None)``; a scalar is broadcast to an
    ``(n_layers,)`` vector plus itself (so scalar and uniform-vector calls
    trace to the identical computation); an ``(n_layers + 1,)`` vector is
    split into its layer part and head entry.  Anything else is a loud error
    — a silently mis-sized plan must not run.
    """
    if degree is None:
        return None, None
    d = jnp.asarray(degree, jnp.int32)
    if d.ndim == 0:
        return jnp.broadcast_to(d, (n_layers,)), d
    if d.ndim != 1 or d.shape[0] != n_layers + 1:
        raise ValueError(
            f"per-layer degree must have shape ({n_layers + 1},) — one entry "
            f"per layer plus the head site — got shape {tuple(d.shape)}")
    return d[:-1], d[-1]
