"""Decoder / encoder transformer LM, config-driven, scan-over-layers.

Covers the dense, MoE, VLM (stub vision frontend) and audio (encoder-only,
stub frame frontend) families.  Hybrid (RG-LRU) and SSM live in rglru.py /
ssm.py.  All matmuls dispatch through the approximation layer.

Head/vocab/expert padding: physical dims come from ``cfg.padded(tp)``
(DESIGN.md §3); padded q heads are extra parameters whose outputs are simply
summed by the out-projection (initialized like any head; harmless for the
compile-only full configs, absent for smoke configs where tp=1).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.approx import ApproxPolicy
from repro.dist import meshctx
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models.degrees import split_degree

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, tp: int):
    pd = cfg.padded(tp)
    d = cfg.d_model
    H, KVr, D = pd.n_heads, pd.n_kv_rep, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln1": L.init_rmsnorm(d),
        "ln2": L.init_rmsnorm(d),
        "wq": L.init_dense(ks[0], d, H * D, bias=cfg.qkv_bias),
        "wk": L.init_dense(ks[1], d, cfg.n_kv_heads * D, bias=cfg.qkv_bias),
        "wv": L.init_dense(ks[2], d, cfg.n_kv_heads * D, bias=cfg.qkv_bias),
        "wo": L.init_dense(ks[3], H * D, d, scale=1.0 / math.sqrt(H * D)),
    }
    if cfg.moe:
        p["moe"] = moe_mod.init_moe(ks[4], cfg, tp)
    else:
        p["mlp"] = L.init_gated_mlp(ks[4], d, cfg.d_ff)
    return p


def init_lm(key, cfg: ArchConfig, tp: int):
    ks = jax.random.split(key, 4)
    pd = cfg.padded(tp)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, tp))(layer_keys)
    params = {
        "embed": L.init_embedding(ks[1], pd.vocab, cfg.d_model),
        "layers": layers,
        "ln_f": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_dense(
            ks[2], cfg.d_model, pd.vocab, scale=1.0 / math.sqrt(cfg.d_model))
    if cfg.frontend == "vision":
        params["v_proj"] = {
            "fc1": L.init_dense(ks[3], cfg.frontend_dim, cfg.d_model, bias=True),
            "fc2": L.init_dense(jax.random.fold_in(ks[3], 1), cfg.d_model,
                                cfg.d_model, bias=True),
        }
    elif cfg.frontend == "audio":
        params["a_proj"] = {
            "fc1": L.init_dense(ks[3], cfg.frontend_dim, cfg.d_model, bias=True),
        }
    return params


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _qkv(bp, x, cfg: ArchConfig, pd, policy, path, positions, degree):
    B, S, d = x.shape
    H, KVr, D = pd.n_heads, pd.n_kv_rep, cfg.head_dim
    q = L.dense_apply(bp["wq"], x, policy, path + "/wq", degree).reshape(B, S, H, D)
    k = L.dense_apply(bp["wk"], x, policy, path + "/wk", degree).reshape(
        B, S, cfg.n_kv_heads, D)
    v = L.dense_apply(bp["wv"], x, policy, path + "/wv", degree).reshape(
        B, S, cfg.n_kv_heads, D)
    if cfg.rope_theta and cfg.causal:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    k = attn.repeat_kv(k, KVr)
    v = attn.repeat_kv(v, KVr)
    q = L.shard_activation(q, meshctx.bspec(None, "model", None))
    k = L.shard_activation(k, meshctx.bspec(None, "model", None))
    v = L.shard_activation(v, meshctx.bspec(None, "model", None))
    return q, k, v


def block_apply(bp, x: Array, cfg: ArchConfig, tp: int, policy: ApproxPolicy,
                path: str, positions: Array, degree=None,
                return_kv: bool = False):
    """Returns (x_out, aux_loss), or (x_out, aux_loss, (k, v)) with
    ``return_kv`` — the post-rope KV the prefill path writes into a slot's
    cache region, so prefill and decode share one block forward."""
    pd = cfg.padded(tp)
    h = L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps)
    q, k, v = _qkv(bp, h, cfg, pd, policy, path, positions, degree)
    o = kdispatch.prefill_attention(q, k, v, causal=cfg.causal,
                                    window=cfg.swa_window)
    o = o.reshape(x.shape[0], x.shape[1], pd.n_heads * cfg.head_dim)
    # residual adds ride the projection epilogues (fused in-kernel on AXQ)
    x = L.dense_apply(bp["wo"], o, policy, path + "/wo", degree, residual=x)
    h = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
    if cfg.moe:
        f, aux = moe_mod.moe_apply(bp["moe"], h, cfg, policy, path + "/moe", degree)
        out = x + f
    else:
        out = L.gated_mlp_apply(bp["mlp"], h, policy, path + "/mlp", cfg.act,
                                degree, residual=x)
        aux = jnp.zeros((), jnp.float32)
    out = L.shard_activation(out, meshctx.bspec(None, None))
    if return_kv:
        return out, aux, (k, v)
    return out, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _sinusoidal(S: int, d: int) -> Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_inputs(params, cfg: ArchConfig, batch: dict, dtype, policy, degree):
    """Token (+frontend stub) embedding.  Returns (x, positions)."""
    if cfg.frontend == "audio":
        # encoder-only: precomputed frame features (stub conv frontend) +
        # absolute sinusoidal positions (stands in for HuBERT's conv pos-emb)
        fe = batch["frame_feats"].astype(dtype)   # (B, S, frontend_dim)
        x = L.dense_apply(params["a_proj"]["fc1"], fe, policy, "a_proj/fc1", degree)
        x = x + _sinusoidal(x.shape[1], x.shape[2]).astype(dtype)[None]
    else:
        tokens = batch["tokens"]
        x = L.embed_apply(params["embed"], tokens, dtype)
        if cfg.frontend == "vision":
            pe = batch["patch_embeds"].astype(dtype)  # (B, S_img, frontend_dim)
            h = L.dense_apply(params["v_proj"]["fc1"], pe, policy, "v_proj/fc1", degree)
            h = jax.nn.gelu(h)
            h = L.dense_apply(params["v_proj"]["fc2"], h, policy, "v_proj/fc2", degree)
            x = jnp.concatenate([h, x], axis=1)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def lm_forward(params, cfg: ArchConfig, policy: ApproxPolicy, batch: dict,
               tp: int = 1, degree=None, remat: str = "dots") -> tuple[Array, Array]:
    """Returns (logits (B, S, vocab_padded), aux_loss).  ``degree`` is the
    runtime DyFXU knob: None, a global scalar, or an (n_layers + 1,) per-site
    vector consumed as a scan input (models/degrees.py)."""
    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x, positions = embed_inputs(params, cfg, batch, dtype, policy, hdeg)
    x = L.shard_activation(x, meshctx.bspec(None, None))

    def body(carry, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h, aux = carry
        h2, a = block_apply(lp, h, cfg, tp, policy, "layer", positions, dg)
        return (h2, aux + a), None

    body_fn = body
    if remat == "full":
        body_fn = jax.checkpoint(body)
    elif remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), xs)
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, policy, "unembed", hdeg)
    else:
        logits = L.dense_apply(params["unembed"], x, policy, "unembed", hdeg)
        logits = logits.astype(jnp.float32)
    logits = L.shard_activation(logits, meshctx.bspec(None, "model"))
    return logits, aux


def lm_loss(params, cfg: ArchConfig, policy: ApproxPolicy, batch: dict,
            tp: int = 1, degree=None, remat: str = "dots") -> tuple[Array, dict]:
    logits, aux = lm_forward(params, cfg, policy, batch, tp, degree, remat)
    labels = batch["labels"]  # (B, S_text) int32, -1 = ignore
    if cfg.frontend == "vision":
        # logits cover [img tokens | text tokens]; loss only on text part
        logits = logits[:, -labels.shape[1]:, :]
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux, "ntokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


class LMCache(NamedTuple):
    k: Array       # (L, B, T, KVr, D)
    v: Array
    length: Array  # (B,)


class LMCacheQ(NamedTuple):
    """int8 cache stack (§Perf hillclimb B2)."""

    k: Array       # (L, B, T, KVr, D) int8
    v: Array
    ks: Array      # (L, B, T, KVr) f32
    vs: Array
    length: Array


def init_lm_cache(cfg: ArchConfig, tp: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16, quant: bool = False):
    pd = cfg.padded(tp)
    T = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    shape = (cfg.n_layers, batch, T, pd.n_kv_rep, cfg.head_dim)
    if quant:
        return LMCacheQ(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                        jnp.zeros(shape[:4], jnp.float32),
                        jnp.zeros(shape[:4], jnp.float32),
                        jnp.zeros((batch,), jnp.int32))
    return LMCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def lm_prefill(params, cfg: ArchConfig, policy: ApproxPolicy, cache,
               tokens: Array, slot, tp: int = 1, degree=None):
    """Fused prefill: run the whole prompt through one full forward pass and
    write its KV into ``slot``'s cache region (positions ``0..P-1``, ring-
    wrapped for sliding-window caches).  ``slot`` may be a traced scalar;
    compilation is per prompt length only.

    tokens: (P,) int32, P >= 1.  Returns (last-position logits (1, V) f32,
    new cache with ``length[slot] = P``).  The slot's region is reset first,
    so admission into a previously-used slot is equivalent to a fresh slot.
    """
    from repro.models.cache_ops import cache_reset_slot, ring_write_indices

    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    P = tokens.shape[0]
    quant = isinstance(cache, LMCacheQ)
    T = cache.k.shape[2]
    # ring writes are only valid when decode also ring-wraps (window <= T);
    # a capacity-truncated window cache saturates instead (attention.py)
    ring = cfg.swa_window is not None and cfg.swa_window <= T
    if P > T and not ring:
        raise ValueError(f"prompt ({P}) exceeds cache capacity ({T})")
    cache = cache_reset_slot(cache, slot)
    x = L.embed_apply(params["embed"], tokens[None], dtype)       # (1, P, d)
    positions = jnp.arange(P, dtype=jnp.int32)[None]              # (1, P)

    def body(h, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h2, _, kv = block_apply(lp, h, cfg, tp, policy, "layer", positions,
                                dg, return_kv=True)
        return h2, kv

    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    x, (ks, vs) = jax.lax.scan(body, x, xs)                # (Lyr, 1, P, KVr, D)
    src, dst = ring_write_indices(P, T)
    k_sel, v_sel = ks[:, 0, src], vs[:, 0, src]            # (Lyr, n, KVr, D)
    if quant:
        kq, ksc = attn._q8(k_sel)
        vq, vsc = attn._q8(v_sel)
        new_cache = LMCacheQ(
            cache.k.at[:, slot, dst].set(kq),
            cache.v.at[:, slot, dst].set(vq),
            cache.ks.at[:, slot, dst].set(ksc),
            cache.vs.at[:, slot, dst].set(vsc),
            cache.length.at[slot].set(P),
        )
    else:
        new_cache = LMCache(
            cache.k.at[:, slot, dst].set(k_sel.astype(cache.k.dtype)),
            cache.v.at[:, slot, dst].set(v_sel.astype(cache.v.dtype)),
            cache.length.at[slot].set(P),
        )
    xl = L.rmsnorm_apply(params["ln_f"], x[:, -1:], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], xl, policy, "unembed", hdeg)
    else:
        logits = L.dense_apply(params["unembed"], xl, policy, "unembed", hdeg)
    return logits.astype(jnp.float32)[:, 0], new_cache


def lm_prefill_batch(params, cfg: ArchConfig, policy: ApproxPolicy, cache,
                     tokens: Array, slots: Array, lengths: Array,
                     tp: int = 1, degree=None):
    """Bucketed/packed prefill: ``tokens`` is (N, Pb) — N prompt rows padded
    to one bucket length Pb — written into ``slots`` (N,) with true lengths
    ``lengths`` (N,).  Compilation is per (N, Pb) only, so a fixed bucket
    ladder gives a fixed executable set (DESIGN.md §15).

    Per-row results are bit-identical to ``lm_prefill`` at the exact length:
    every op below attention is position-local, and causal/windowed attention
    over a padded suffix leaves prefix rows untouched.  (MoE layers are the
    exception — capacity routing couples tokens — so the adapter keeps MoE
    on the exact-length path.)

    Rows may be dummies: ``slot >= B`` scatters are dropped by JAX semantics,
    and ``length == 0`` rows only reset their slot.  Returns the new cache
    (no logits — admission feeds the last prompt token through decode).
    """
    ldeg, _ = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    N, Pb = tokens.shape
    quant = isinstance(cache, LMCacheQ)
    T = cache.k.shape[2]
    ring = cfg.swa_window is not None and cfg.swa_window <= T
    if Pb > T and not ring:
        raise ValueError(f"bucket ({Pb}) exceeds cache capacity ({T})")
    x = L.embed_apply(params["embed"], tokens, dtype)             # (N, Pb, d)
    positions = jnp.broadcast_to(jnp.arange(Pb, dtype=jnp.int32)[None], (N, Pb))

    def body(h, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h2, _, kv = block_apply(lp, h, cfg, tp, policy, "layer", positions,
                                dg, return_kv=True)
        return h2, kv

    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    _, (ks, vs) = jax.lax.scan(body, x, xs)              # (Lyr, N, Pb, KVr, D)
    Lyr, _, _, KVr, D = ks.shape
    # masked tail scatter: keep the last min(len, T) tokens of each row at
    # position j % T; everything else lands at T and is dropped (OOB).
    j = jnp.arange(Pb, dtype=jnp.int32)[None]                     # (1, Pb)
    ln = lengths[:, None]                                         # (N, 1)
    valid = (j < ln) & (j >= ln - T)
    dst = jnp.where(valid, j % T, T)                              # (N, Pb)
    rows = jnp.arange(N)[:, None]
    if quant:
        kq, ksc = attn._q8(ks)
        vq, vsc = attn._q8(vs)
        regk = jnp.zeros((Lyr, N, T, KVr, D), jnp.int8).at[:, rows, dst].set(kq)
        regv = jnp.zeros((Lyr, N, T, KVr, D), jnp.int8).at[:, rows, dst].set(vq)
        regks = jnp.zeros((Lyr, N, T, KVr), jnp.float32).at[:, rows, dst].set(ksc)
        regvs = jnp.zeros((Lyr, N, T, KVr), jnp.float32).at[:, rows, dst].set(vsc)
        return LMCacheQ(
            cache.k.at[:, slots].set(regk),
            cache.v.at[:, slots].set(regv),
            cache.ks.at[:, slots].set(regks),
            cache.vs.at[:, slots].set(regvs),
            cache.length.at[slots].set(lengths),
        )
    cdt = cache.k.dtype
    regk = jnp.zeros((Lyr, N, T, KVr, D), cdt).at[:, rows, dst].set(ks.astype(cdt))
    regv = jnp.zeros((Lyr, N, T, KVr, D), cdt).at[:, rows, dst].set(vs.astype(cdt))
    return LMCache(
        cache.k.at[:, slots].set(regk),
        cache.v.at[:, slots].set(regv),
        cache.length.at[slots].set(lengths),
    )


def lm_prefill_chunk(params, cfg: ArchConfig, policy: ApproxPolicy,
                     cache: LMCache, tokens: Array, slot, offset, clen,
                     tp: int = 1, degree=None) -> LMCache:
    """Incremental prefill of one chunk: ``tokens`` (C,) continues ``slot``'s
    prompt at position ``offset`` (traced), with ``clen <= C`` real tokens.
    Chunk KV is written at ``offset + j`` (pad tail dropped OOB) and each
    chunk position attends over the slot's cache rows — so long prompts can
    be admitted across ticks, interleaved with decode, at one executable per
    chunk size.  Dense full-attention caches only (no ring, no quant, no
    MoE); the adapter gates eligibility.  Deterministic, but not bit-exact
    vs one-shot prefill (cache-precision attention, T-length reductions).
    Updates ``length[slot] = offset + clen``; returns the cache only.
    """
    ldeg, _ = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pd = cfg.padded(tp)
    C = tokens.shape[0]
    T = cache.k.shape[2]
    kvh = cache.k.shape[3]
    x = L.embed_apply(params["embed"], tokens[None], dtype)       # (1, C, d)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = (offset + j)[None]                                # (1, C)
    dst = jnp.where(j < clen, offset + j, T)                      # (C,)
    qmask = (jnp.arange(T, dtype=jnp.int32)[None, :] <= (offset + j)[:, None])

    def body(h, xs):
        if ldeg is None:
            lp, ck, cv = xs
            dg = None
        else:
            lp, ck, cv, dg = xs
        hn = L.rmsnorm_apply(lp["ln1"], h, cfg.norm_eps)
        q, k, v = _qkv(lp, hn, cfg, pd, policy, "layer", positions, dg)
        ck2 = ck.at[slot, dst].set(k[0].astype(ck.dtype))
        cv2 = cv.at[slot, dst].set(v[0].astype(cv.dtype))
        keys = ck2[slot]                                          # (T, KVr, D)
        vals = cv2[slot]
        qg = attn._group_q(q, kvh)                                # (1,C,KV,G,D)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32),
                       keys[None].astype(jnp.float32)) / math.sqrt(cfg.head_dim)
        s = jnp.where(qmask[None, None, None], s, attn.NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", p, vals[None].astype(jnp.float32))
        o = o.reshape(1, C, pd.n_heads * cfg.head_dim).astype(h.dtype)
        h = L.dense_apply(lp["wo"], o, policy, "layer/wo", dg, residual=h)
        hn = L.rmsnorm_apply(lp["ln2"], h, cfg.norm_eps)
        h = L.gated_mlp_apply(lp["mlp"], hn, policy, "layer/mlp", cfg.act,
                              dg, residual=h)
        return h, (ck2, cv2)

    xs = (params["layers"], cache.k, cache.v)
    if ldeg is not None:
        xs = xs + (ldeg,)
    _, (nk, nv) = jax.lax.scan(body, x, xs)
    return LMCache(nk, nv, cache.length.at[slot].set(offset + clen))


def lm_decode_step(params, cfg: ArchConfig, policy: ApproxPolicy, cache: LMCache,
                   tokens: Array, tp: int = 1, degree=None,
                   active=None) -> tuple[Array, LMCache]:
    """tokens: (B, 1).  One decode step; returns (logits (B, 1, V), cache).
    ``active`` (B,) bool: free-slot mask forwarded to the kernel dispatch.
    ``degree``: None, a global scalar, or an (n_layers + 1,) per-site vector
    scanned alongside the layer stack (models/degrees.py)."""
    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pd = cfg.padded(tp)
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, dtype)
    positions = cache.length[:, None]  # (B,1)
    quant = isinstance(cache, LMCacheQ)

    def body(carry, xs):
        h = carry
        if quant:
            lp, ck, cv, cks, cvs, *rest = xs
        else:
            lp, ck, cv, *rest = xs
        dg = rest[0] if rest else None
        hn = L.rmsnorm_apply(lp["ln1"], h, cfg.norm_eps)
        q, k, v = _qkv(lp, hn, cfg, pd, policy, "layer", positions, dg)
        if quant:
            lc = attn.QuantKVCache(ck, cv, cks, cvs, cache.length)
        else:
            lc = attn.KVCache(ck, cv, cache.length)
        o, lc2 = kdispatch.decode_attention(q, k, v, lc, window=cfg.swa_window,
                                            degree=dg, active=active)
        new = (lc2.k, lc2.v, lc2.ks, lc2.vs) if quant else (lc2.k, lc2.v)
        o = o.reshape(B, 1, pd.n_heads * cfg.head_dim)
        h = L.dense_apply(lp["wo"], o, policy, "layer/wo", dg, residual=h)
        hn = L.rmsnorm_apply(lp["ln2"], h, cfg.norm_eps)
        if cfg.moe:
            f, _ = moe_mod.moe_apply(lp["moe"], hn, cfg, policy, "layer/moe", dg)
            h = h + f
        else:
            h = L.gated_mlp_apply(lp["mlp"], hn, policy, "layer/mlp", cfg.act,
                                  dg, residual=h)
        return h, new

    xs = (params["layers"], cache.k, cache.v)
    if quant:
        xs = xs + (cache.ks, cache.vs)
    if ldeg is not None:
        xs = xs + (ldeg,)
    if quant:
        x, (nk, nv, nks, nvs) = jax.lax.scan(body, x, xs)
        new_cache = LMCacheQ(nk, nv, nks, nvs, cache.length + 1)
    else:
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        new_cache = LMCache(nk, nv, cache.length + 1)
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, policy, "unembed", hdeg)
    else:
        logits = L.dense_apply(params["unembed"], x, policy, "unembed", hdeg)
    return logits.astype(jnp.float32), new_cache
