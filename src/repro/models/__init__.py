from .registry import Model, build_model, concrete_batch, input_specs  # noqa: F401
