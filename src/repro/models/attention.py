"""GQA/MQA attention with blockwise online-softmax (memory-bounded), sliding
windows, and single-token decode against a KV cache.

These are the pure-jnp (XLA) paths.  Model call sites route through
``kernels/dispatch.py``, which picks between these and the Pallas kernels
(``kernels/flash_attention.py`` skip grids for prefill,
``kernels/flash_decode.py`` for the fused decode step) per backend /
``REPRO_KERNELS``; everything here doubles as the dispatch fallback and the
correctness oracle for the kernels (DESIGN.md §8).

Layout conventions:
  q        (B, S, H, D)        H = padded q heads (config.padded(tp))
  k, v     (B, S, KVr, D)      KVr = kv heads repeated/padded to TP degree
  cache    (B, T_max, KVr, D)  per layer, bf16 (quantizable — beyond-paper opt)

GQA is computed grouped — q reshaped to (B, S, KVr, G, D) — so repeated KV is
never materialized beyond the KVr layout chosen for sharding (DESIGN.md §3).

The blockwise paths bound peak memory to O(S x blk) per head group instead of
O(S^2): prefill_32k would otherwise show multi-TB temporaries in the dry-run
memory analysis.  Sliding-window attention (danube, recurrentgemma local
attn) only *computes* the in-window KV blocks => sub-quadratic HLO FLOPs.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG_INF = -1e30


def repeat_kv(k: Array, target_heads: int) -> Array:
    """(B, S, KV, D) -> (B, S, target, D) by head repetition (cheap gather)."""
    kv = k.shape[2]
    if kv == target_heads:
        return k
    assert target_heads % kv == 0
    return jnp.repeat(k, target_heads // kv, axis=2)


def _group_q(q: Array, kv_heads: int) -> Array:
    """(B, S, H, D) -> (B, S, KVr, G, D)."""
    B, S, H, D = q.shape
    assert H % kv_heads == 0
    return q.reshape(B, S, kv_heads, H // kv_heads, D)


# ---------------------------------------------------------------------------
# Full (small-seq / smoke) attention
# ---------------------------------------------------------------------------


def attn_full(q: Array, k: Array, v: Array, *, causal: bool,
              window: Optional[int] = None) -> Array:
    B, S, H, D = q.shape
    kvh = k.shape[2]
    qg = _group_q(q, kvh)  # (B,S,KV,G,D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= jj <= ii
    if window is not None:
        mask &= jj > ii - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# Blockwise online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------


def attn_blockwise(q: Array, k: Array, v: Array, *, causal: bool,
                   window: Optional[int] = None,
                   q_block: int = 512, kv_block: int = 512) -> Array:
    """Memory-bounded attention.  When `window` is set and smaller than the
    sequence, each q block only visits ceil(window/kv_block)+1 kv blocks via
    dynamic slicing => O(S*window) compute (sub-quadratic path)."""
    B, S, H, D = q.shape
    kvh = k.shape[2]
    if S <= max(q_block, 256):
        return attn_full(q, k, v, causal=causal, window=window)
    q_block = min(q_block, S)
    while S % q_block:
        q_block //= 2
    kv_block = min(kv_block, S)
    while S % kv_block:
        kv_block //= 2
    nq = S // q_block
    scale = 1.0 / math.sqrt(D)
    qg = _group_q(q, kvh)  # (B,S,KV,G,D)
    G = qg.shape[3]

    windowed = window is not None and window < S
    if windowed:
        # kv span visited per q block: window + q_block, rounded to kv_block
        span = ((window + q_block + kv_block - 1) // kv_block) * kv_block
        span = min(span, S)

    def q_step(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, axis=1)
        qb = qb.astype(jnp.float32) * scale
        q_pos = qi * q_block + jnp.arange(q_block)

        if windowed:
            start = jnp.clip((qi + 1) * q_block - span, 0, S - span)
            kspan = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vspan = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            k_pos = start + jnp.arange(span)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qb, kspan.astype(jnp.float32))
            m = jnp.ones((q_block, span), bool)
            if causal:
                m &= k_pos[None, :] <= q_pos[:, None]
            m &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(m[None, None, None], s, NEG_INF)
            mx = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - mx)
            den = jnp.sum(p, axis=-1, keepdims=True)
            ob = jnp.einsum("bkgqt,btkd->bqkgd", p / jnp.maximum(den, 1e-30),
                            vspan.astype(jnp.float32))
            return None, ob.astype(q.dtype)

        # full causal: online softmax over kv blocks
        nk = S // kv_block

        def kv_step(carry, ki):
            acc, mx, den = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, axis=1)
            k_pos = ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qb, kb.astype(jnp.float32))
            if causal:
                m = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(m[None, None, None], s, NEG_INF)
            new_mx = jnp.maximum(mx, jnp.max(s, axis=-1, keepdims=True))
            corr = jnp.exp(mx - new_mx)
            p = jnp.exp(s - new_mx)
            new_den = den * corr + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bkgqt,btkd->bqkgd", p, vb.astype(jnp.float32))
            # acc layout (B,q,K,G,D): corr layout (B,K,G,q,1) -> move axes
            corr_a = jnp.moveaxis(corr, 3, 1)  # (B,q,K,G,1)
            new_acc = acc * corr_a + pv
            return (new_acc, new_mx, new_den), None

        acc0 = jnp.zeros((B, q_block, kvh, G, D), jnp.float32)
        mx0 = jnp.full((B, kvh, G, q_block, 1), NEG_INF, jnp.float32)
        den0 = jnp.zeros((B, kvh, G, q_block, 1), jnp.float32)
        (acc, mx, den), _ = jax.lax.scan(
            kv_step, (acc0, mx0, den0), jnp.arange(nk))
        den_a = jnp.moveaxis(den, 3, 1)
        ob = acc / jnp.maximum(den_a, 1e-30)
        return None, ob.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    # blocks: (nq, B, q_block, KV, G, D) -> (B, S, H, D)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, S, kvh, G, D)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# Decode (one new token against the cache)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: Array          # (B, T, KVr, D)
    v: Array          # (B, T, KVr, D)
    length: Array     # (B,) int32 — tokens currently in cache


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, head) scales — §Perf hillclimb B2:
    halves decode HBM residency/reads vs bf16 (the paper's operand-width
    trade applied to the cache)."""

    k: Array          # (B, T, KVr, D) int8
    v: Array
    ks: Array         # (B, T, KVr) f32
    vs: Array
    length: Array


def _q8(x: Array):
    """Per-(token, head) symmetric int8 quantization of (B, 1, KV, D)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def init_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_len, kv_heads, head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def init_quant_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int
                        ) -> QuantKVCache:
    shape = (batch, max_len, kv_heads, head_dim)
    return QuantKVCache(
        k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
        ks=jnp.zeros(shape[:3], jnp.float32),
        vs=jnp.zeros(shape[:3], jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def decode_attn_quant(q1: Array, knew: Array, vnew: Array,
                      cache: QuantKVCache, *, window: Optional[int] = None
                      ) -> tuple[Array, QuantKVCache]:
    """Decode against the int8 cache: quantize the new KV, dequantize tiles
    at attention time (HBM holds int8; dequant lives in registers/VMEM)."""
    B, _, H, D = q1.shape
    T = cache.k.shape[1]
    kvh = cache.k.shape[2]
    pos = cache.length
    slot = jnp.mod(pos, T) if (window is not None and window <= T) \
        else jnp.minimum(pos, T - 1)
    bidx = jnp.arange(B)
    kq, ksn = _q8(knew)
    vq, vsn = _q8(vnew)
    k = cache.k.at[bidx, slot].set(kq[:, 0])
    v = cache.v.at[bidx, slot].set(vq[:, 0])
    ks = cache.ks.at[bidx, slot].set(ksn[:, 0])
    vs = cache.vs.at[bidx, slot].set(vsn[:, 0])
    qg = _group_q(q1, kvh)[:, 0]
    kf = k.astype(jnp.float32) * ks[..., None]
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32), kf) / math.sqrt(D)
    n_valid = jnp.minimum(pos + 1, T)
    valid = jnp.arange(T)[None, :] < n_valid[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vf = v.astype(jnp.float32) * vs[..., None]
    out = jnp.einsum("bkgt,btkd->bkgd", p, vf)
    out = out.reshape(B, 1, H, D).astype(q1.dtype)
    return out, QuantKVCache(k, v, ks, vs, pos + 1)


def decode_attn(q1: Array, knew: Array, vnew: Array, cache: KVCache,
                *, window: Optional[int] = None) -> tuple[Array, KVCache]:
    """q1: (B, 1, H, D); knew/vnew: (B, 1, KVr, D).

    For windowed layers the cache is a ring buffer of size window; otherwise
    writes at `length`.  Returns (out (B,1,H,D), new cache).
    """
    B, _, H, D = q1.shape
    T = cache.k.shape[1]
    kvh = cache.k.shape[2]
    pos = cache.length  # (B,)
    slot = jnp.mod(pos, T) if (window is not None and window <= T) else jnp.minimum(pos, T - 1)
    bidx = jnp.arange(B)
    k = cache.k.at[bidx, slot].set(knew[:, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(vnew[:, 0].astype(cache.v.dtype))
    qg = _group_q(q1, kvh)[:, 0]  # (B,KV,G,D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    # valid positions: for ring buffer all slots < min(len+1, T); else <= pos
    n_valid = jnp.minimum(pos + 1, T)
    valid = jnp.arange(T)[None, :] < n_valid[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    out = out.reshape(B, 1, H, D).astype(q1.dtype)
    return out, KVCache(k=k, v=v, length=pos + 1)
