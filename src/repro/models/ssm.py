"""Mamba-2 (SSD, state-space duality) — chunked dual form (arXiv:2405.21060).

GEMM-dominated by construction (the point of SSD), so the paper's
approximate-multiplier technique applies to this attention-free arch through
the same approx_matmul dispatch (DESIGN.md §4).

Chunked algorithm (chunk length Q):
  h_t = exp(A dt_t) h_{t-1} + dt_t B_t (x) X_t        (state (H, P, N))
  y_t = C_t . h_t + D * X_t
  intra-chunk: Y[s] += sum_{t<=s} (C_s.B_t) exp(cum_s - cum_t) dt_t X_t
  inter-chunk: lax.scan over chunk summaries.

Decode is the O(1) recurrent update — long_500k runs.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.approx import ApproxPolicy
from repro.dist import meshctx
from repro.models import layers as L
from repro.models.degrees import split_degree

Array = jnp.ndarray


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.headdim
    return d_in, H, s.headdim, s.d_state


def init_ssm_block(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    s = cfg.ssm
    ks = jax.random.split(key, 6)
    dt = jnp.exp(
        jax.random.uniform(ks[2], (H,), jnp.float32)
        * (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    return {
        "ln": L.init_rmsnorm(d),
        # fused input projection: [z, x, B, C, dt]
        "in_proj": L.init_dense(ks[0], d, 2 * d_in + 2 * N + H),
        "conv": L.init_conv1d(ks[1], d_in + 2 * N, s.conv_width),
        "dt_bias": jnp.log(jnp.expm1(dt)),               # softplus^-1(dt)
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "gnorm": L.init_rmsnorm(d_in),
        "out_proj": L.init_dense(ks[3], d_in, d, scale=1.0 / math.sqrt(d_in)),
    }


def _split_proj(proj: Array, cfg: ArchConfig):
    d_in, H, P, N = _dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in : 2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N :]
    return z, xBC, dt


def _segsum_decay(dtA: Array) -> tuple[Array, Array]:
    """dtA: (..., Q, H) negative log-decays.  Returns (cum inclusive (...,Q,H),
    L (..., H, Q, Q) lower-triangular exp(cum_s - cum_t))."""
    cum = jnp.cumsum(dtA, axis=-2)                       # (..., Q, H)
    diff = cum[..., :, None, :] - cum[..., None, :, :]   # (..., Q, Q, H) s,t
    Q = dtA.shape[-2]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(mask[..., None], diff, -jnp.inf)
    Lmat = jnp.exp(diff)                                 # (..., Q, Q, H)
    return cum, jnp.moveaxis(Lmat, -1, -3)               # (..., H, Q, Q)


def _conv_tail(ci: Array, lengths: Array, width: int) -> Array:
    """Per-row causal-conv state: the ``width - 1`` inputs ending at position
    ``length - 1`` (zeros where the row is shorter).  Matches the tail slice
    ``conv1d_apply`` keeps when every row spans the full sequence."""
    B = ci.shape[0]
    pad = jnp.zeros((B, width - 1, ci.shape[2]), ci.dtype)
    xp = jnp.concatenate([pad, ci], axis=1)               # xp[t + w - 1] = ci[t]
    idx = lengths[:, None] + jnp.arange(width - 1, dtype=jnp.int32)[None]
    return jnp.take_along_axis(xp, idx[..., None], axis=1)


def ssm_block_apply(bp, x_res: Array, cfg: ArchConfig, policy: ApproxPolicy,
                    path: str, degree=None,
                    state: tuple[Array, Array] | None = None,
                    return_state: bool = False, lengths: Array | None = None):
    """x_res: (B, S, d).  state = (h (B,H,P,N), conv (B,w-1,C)) for decode.
    Returns (out, new_state).  With ``return_state`` the chunked (train /
    prefill) path also returns the post-sequence (h, conv) state so decode
    can continue from a fused prefill.

    The chunked path always uses the configured chunk length and pads the
    tail internally with zero-dt steps (exp(0) = 1 decay, zero input — an
    identity state update), so the chunk decomposition depends only on the
    padded length, never on S.  With ``lengths`` (B,) the same dt masking is
    applied per row, making a bucket-padded prefill bit-identical to the
    exact-length one (states gathered at each row's true length)."""
    d_in, H, P, N = _dims(cfg)
    s = cfg.ssm
    B_, S, _ = x_res.shape
    xln = L.rmsnorm_apply(bp["ln"], x_res, cfg.norm_eps)
    proj = L.dense_apply(bp["in_proj"], xln, policy, path + "/in_proj", degree)
    z, xBC, dt_raw = _split_proj(proj, cfg)
    conv_state = state[1] if state is not None else None
    ci = jax.nn.silu(xBC)
    xBC, new_conv = L.conv1d_apply(bp["conv"], ci, conv_state)
    X = xBC[..., :d_in].reshape(B_, S, H, P)
    Bm = xBC[..., d_in : d_in + N].astype(jnp.float32)
    Cm = xBC[..., d_in + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])  # (B,S,H)
    A = -jnp.exp(bp["a_log"])                                          # (H,)
    Xf = X.astype(jnp.float32)

    if state is not None:
        # decode: one step, recurrent update
        h_prev = state[0]                                 # (B,H,P,N)
        a = jnp.exp(dt[:, 0] * A)                         # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0], Xf[:, 0])
        h = a[..., None, None] * h_prev + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)
        y = y + bp["D"][None, :, None] * Xf[:, 0]
        y = y.reshape(B_, 1, d_in)
        new_state = (h, new_conv)
    else:
        Q = s.chunk
        S_pad = -(-S // Q) * Q
        nc = S_pad // Q
        if lengths is not None:
            vmask = jnp.arange(S, dtype=jnp.int32)[None] < lengths[:, None]
            dt = jnp.where(vmask[..., None], dt, 0.0)
        if S_pad != S:
            Xf = jnp.pad(Xf, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, S_pad - S), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, S_pad - S), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, S_pad - S), (0, 0)))
        Xc = Xf.reshape(B_, nc, Q, H, P)
        Bc = Bm.reshape(B_, nc, Q, N)
        Cc = Cm.reshape(B_, nc, Q, N)
        dtc = dt.reshape(B_, nc, Q, H)
        dtA = dtc * A                                     # (B,nc,Q,H)
        cum, Lmat = _segsum_decay(dtA)                    # cum (B,nc,Q,H)
        # intra-chunk
        CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # (B,nc,Q,Q) s,t
        scores = CB[:, :, None] * Lmat                    # (B,nc,H,Q,Q)
        dtX = dtc[..., None] * Xc                         # (B,nc,Q,H,P)
        Y = jnp.einsum("bchst,bcthp->bcshp", scores, dtX)
        # chunk summaries
        decay_out = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,Q,H)
        states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_out * dtc, Xc, Bc)
        chunk_decay = jnp.exp(cum[:, :, -1])              # (B,nc,H)

        def chunk_scan(h, xs):
            st, cd = xs                                   # (B,H,P,N), (B,H)
            h_new = cd[..., None, None] * h + st
            return h_new, h                                # emit h_prev

        h0 = jnp.zeros((B_, H, P, N), jnp.float32)
        h_last, h_prevs = jax.lax.scan(
            chunk_scan, h0,
            (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
        h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # (B,nc,H,P,N)
        decay_in = jnp.exp(cum)                           # (B,nc,Q,H)
        Y = Y + jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prevs, decay_in)
        Y = Y + bp["D"][None, None, None, :, None] * Xc
        y = Y.reshape(B_, S_pad, d_in)[:, :S]
        if return_state:
            if lengths is not None:
                new_conv = _conv_tail(ci, lengths, s.conv_width)
            new_state = (h_last, new_conv)
        else:
            new_state = None

    y = y.astype(x_res.dtype) * jax.nn.silu(z)
    y = L.rmsnorm_apply(bp["gnorm"], y, cfg.norm_eps)
    # residual fuses into the out-projection epilogue (in-kernel on AXQ)
    y = L.dense_apply(bp["out_proj"], y, policy, path + "/out_proj", degree,
                      residual=x_res)
    return y, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_ssm_lm(key, cfg: ArchConfig, tp: int):
    ks = jax.random.split(key, 3)
    lkeys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.init_embedding(ks[1], cfg.padded(tp).vocab, cfg.d_model),
        "layers": jax.vmap(lambda k: init_ssm_block(k, cfg))(lkeys),
        "ln_f": L.init_rmsnorm(cfg.d_model),
    }


def ssm_forward(params, cfg: ArchConfig, policy: ApproxPolicy, batch: dict,
                tp: int = 1, degree=None, remat: str = "dots"):
    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed_apply(params["embed"], batch["tokens"], dtype)

    def body(h, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h2, _ = ssm_block_apply(lp, h, cfg, policy, "layer", dg)
        return h2, None

    fn = body
    if remat != "none":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    x, _ = jax.lax.scan(fn, x, xs)
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x, policy, "unembed", hdeg)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


class SSMCache(NamedTuple):
    h: Array      # (L, B, H, P, N) f32
    conv: Array   # (L, B, w-1, C)
    length: Array


def init_ssm_cache(cfg: ArchConfig, tp: int, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> SSMCache:
    d_in, H, P, N = _dims(cfg)
    C = d_in + 2 * N
    w = cfg.ssm.conv_width
    return SSMCache(
        h=jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, w - 1, C), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def ssm_prefill(params, cfg: ArchConfig, policy: ApproxPolicy,
                cache: SSMCache, tokens: Array, slot, tp: int = 1, degree=None):
    """Fused prefill: one chunked-dual-form forward over the whole prompt,
    final recurrent/conv state written into ``slot``'s cache region.

    tokens: (P,) int32.  Returns (last-position logits (1, V) f32, cache with
    ``length[slot] = P``).  The slot region is reset first (reuse == fresh).

    The prompt is padded to the chunk multiple at the TOKEN level and the true
    length passed down as a mask, so this builds the same masked-graph program
    shape as ``ssm_prefill_batch`` — XLA then compiles the identical chunk-scan
    reduction for both, which is what makes bucket-padded admission bit-exact
    against this path (a pad-shaped graph and a mask-shaped graph of the same
    math may otherwise associate reductions differently, drifting by 1 ulp).
    """
    from repro.models.cache_ops import cache_reset_slot

    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = cache_reset_slot(cache, slot)
    P = tokens.shape[0]
    Q = cfg.ssm.chunk
    S_pad = -(-P // Q) * Q
    if S_pad != P:
        tokens = jnp.pad(tokens, (0, S_pad - P))
    lengths = jnp.full((1,), P, jnp.int32)
    x = L.embed_apply(params["embed"], tokens[None], dtype)   # (1, S_pad, d)

    def body(h, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h2, st = ssm_block_apply(lp, h, cfg, policy, "layer", dg,
                                 return_state=True, lengths=lengths)
        return h2, st

    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    x, (nh, nc) = jax.lax.scan(body, x, xs)
    new_cache = SSMCache(
        h=cache.h.at[:, slot].set(nh[:, 0]),
        conv=cache.conv.at[:, slot].set(nc[:, 0].astype(cache.conv.dtype)),
        length=cache.length.at[slot].set(P),
    )
    xl = L.rmsnorm_apply(params["ln_f"], x[:, P - 1:P], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], xl, policy, "unembed", hdeg)
    return logits.astype(jnp.float32)[:, 0], new_cache


def ssm_prefill_batch(params, cfg: ArchConfig, policy: ApproxPolicy,
                      cache: SSMCache, tokens: Array, slots: Array,
                      lengths: Array, tp: int = 1, degree=None) -> SSMCache:
    """Bucketed/packed prefill: rows (N, Pb) padded to one bucket length,
    written into ``slots`` with true ``lengths``.  Zero-dt tail masking in
    ``ssm_block_apply`` makes each row's final (h, conv) state bit-identical
    to ``ssm_prefill`` at the exact length whenever both pad to the same
    chunk-aligned sequence (ssm_prefill pads n -> ceil(n/Q)*Q; here Pb ->
    ceil(Pb/Q)*Q — equal for every n whose chunk count matches the bucket's,
    and always numerically equivalent otherwise).  Dummy rows (slot >= B) and
    empty rows (length 0, which write a reset state) are dropped/benign.
    Returns the cache only."""
    ldeg, _ = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed_apply(params["embed"], tokens, dtype)     # (N, Pb, d)

    def body(h, xs):
        lp, dg = (xs, None) if ldeg is None else xs
        h2, st = ssm_block_apply(lp, h, cfg, policy, "layer", dg,
                                 return_state=True, lengths=lengths)
        return h2, st

    xs = params["layers"] if ldeg is None else (params["layers"], ldeg)
    _, (nh, nc) = jax.lax.scan(body, x, xs)          # (Lyr, N, ...)
    return SSMCache(
        h=cache.h.at[:, slots].set(nh),
        conv=cache.conv.at[:, slots].set(nc.astype(cache.conv.dtype)),
        length=cache.length.at[slots].set(lengths),
    )


def ssm_decode_step(params, cfg: ArchConfig, policy: ApproxPolicy,
                    cache: SSMCache, tokens: Array, tp: int = 1, degree=None):
    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed_apply(params["embed"], tokens, dtype)

    def body(h, xs):
        lp, hc, cc, *rest = xs
        dg = rest[0] if rest else None
        h2, (hn, cn) = ssm_block_apply(lp, h, cfg, policy, "layer", dg,
                                       state=(hc, cc))
        return h2, (hn, cn)

    xs = (params["layers"], cache.h, cache.conv)
    if ldeg is not None:
        xs = xs + (ldeg,)
    x, (nh, nc) = jax.lax.scan(body, x, xs)
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x, policy, "unembed", hdeg)
    return logits.astype(jnp.float32), SSMCache(nh, nc, cache.length + 1)
