"""Mixture-of-Experts block with expert parallelism over the `model` mesh axis.

Routing strategy (DESIGN.md §3): inside a shard_map region, every model shard
holds E/TP experts (weights sharded on the expert dim) and the *full* router
(replicated weights).  Each shard gathers the tokens routed to its local
experts into a capacity-bounded (E_local, C, d) buffer (sort-free rank-by-
cumsum dispatch, all static shapes), runs the expert FFNs as batched GEMMs,
scatter-adds gated outputs, and a psum over `model` combines the partial
outputs — the same collective TP would pay for a dense FFN.  No all_to_all is
needed because activations are replicated across `model` under TP.

Compute cost therefore matches the *active* parameter count (top-k experts per
token + shared experts), which is what the roofline's 6*N_active*D model FLOPs
expects — a dense one-hot dispatch einsum would have inflated HLO FLOPs by
O(E/topk).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.dist import meshctx
from repro.models.layers import act_fn, init_dense, truncated_normal

Array = jnp.ndarray


def init_moe(key, cfg: ArchConfig, tp: int):
    m = cfg.moe
    pd = cfg.padded(tp)
    E = pd.n_experts
    d = cfg.d_model
    f = m.d_expert
    ks = jax.random.split(key, 6)
    params = {
        "router": {"w": truncated_normal(ks[0], (d, E), 1.0 / math.sqrt(d))},
        "experts": {
            "up": truncated_normal(ks[1], (E, d, f), 1.0 / math.sqrt(d)),
            "gate": truncated_normal(ks[2], (E, d, f), 1.0 / math.sqrt(d)),
            "down": truncated_normal(ks[3], (E, f, d), 1.0 / math.sqrt(f)),
        },
    }
    if m.n_shared:
        fs = m.d_shared * m.n_shared
        params["shared"] = {
            "up": truncated_normal(ks[4], (d, fs), 1.0 / math.sqrt(d)),
            "gate": truncated_normal(ks[5], (d, fs), 1.0 / math.sqrt(d)),
            "down": truncated_normal(ks[0], (fs, d), 1.0 / math.sqrt(fs)),
        }
    return params


import os

# legacy toggle: pre-dispatch int8 expert lever (§Perf C1).  Now an alias
# for an AXQ expert spec routed through the shared GEMM dispatch — the old
# parallel `_int8_einsum` path (its own per-tensor quantizer + einsum +
# custom VJP) is retired in favor of kernels/dispatch.axq_gated/axq_matmul
# with the STE backward, so experts share quantizer, kernels, prepacked
# residency, and the runtime ebits degree with every other projection.
_MOE_INT8 = os.environ.get("REPRO_MOE_INT8", "0") == "1"
# §Perf: combine-psum through the int8 ring (straight-through backward —
# the VJP of a psum with replicated output is the identity on the cotangent)
_MOE_RING = os.environ.get("REPRO_RING_TP", "0") == "1"


@jax.custom_vjp
def _ring_psum_model(x):
    from repro.dist.collectives import ring_allreduce_int8_local

    return ring_allreduce_int8_local(x, "model")


def _rp_fwd(x):
    return _ring_psum_model(x), None


def _rp_bwd(_, g):
    return (g,)


_ring_psum_model.defvjp(_rp_fwd, _rp_bwd)


def expert_spec(policy: ApproxPolicy, path: str) -> ApproxSpec:
    """Expert GEMM spec: policy-resolved at ``<path>/experts``; the legacy
    REPRO_MOE_INT8 env promotes an EXACT spec to AXQ-8 (shared dispatch).
    Single source for moe_apply AND the qstore prepack walker — the prepack
    decision must match the apply-time route."""
    spec = policy.spec_for(path + "/experts")
    if _MOE_INT8 and spec.mode == ApproxMode.EXACT:
        spec = ApproxSpec(mode=ApproxMode.AXQ, ebits=8)
    return spec


def _local_expert_ffn(w, x, act, spec=None, ebits=None):
    """x: (E_l, C, d); w[up/gate/down]: (E_l, d, f)/(E_l, f, d) — float or
    prepacked (:class:`~repro.kernels.qstore.PackedQWeight`, expert-batched).

    AXQ specs route through the shared GEMM dispatch, vmapped over the local
    experts: the fused gated kernel for up/gate (one shared x stream per
    expert) and the plain axqmm for down, with the STE backward so the
    experts stay trainable.  ``ebits`` is the runtime degree scalar (already
    resolved against the spec by the caller)."""
    if spec is not None and spec.mode == ApproxMode.AXQ:
        from repro.kernels import dispatch as kdispatch

        h = jax.vmap(lambda xe, wu, wg: kdispatch.axq_gated(
            xe, wu, wg, act=act, block=spec.block, ebits=ebits, ste=True)
        )(x.astype(jnp.float32), w["up"], w["gate"])
        return jax.vmap(lambda he, wd: kdispatch.axq_matmul(
            he, wd, block=spec.block, ebits=ebits, ste=True)
        )(h.astype(x.dtype).astype(jnp.float32), w["down"])
    up = jnp.einsum("ecd,edf->ecf", x, w["up"], preferred_element_type=jnp.float32)
    gate = jnp.einsum("ecd,edf->ecf", x, w["gate"], preferred_element_type=jnp.float32)
    h = (act_fn(act)(gate) * up).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w["down"], preferred_element_type=jnp.float32)


def moe_apply(params, x: Array, cfg: ArchConfig, policy: ApproxPolicy, path: str,
              degree=None) -> tuple[Array, Array]:
    """x: (B, S, d) -> (y (B, S, d), aux load-balance loss (scalar))."""
    mesh = meshctx.get_mesh()
    m = cfg.moe
    tp = mesh.shape["model"]
    pd = cfg.padded(tp)
    E = pd.n_experts
    E_local = E // tp
    topk = m.top_k
    bdims = meshctx.batch_axes(mesh)
    d = cfg.d_model
    act = cfg.act

    dp = 1
    for a in bdims:
        dp *= mesh.shape[a]
    B, S, _ = x.shape
    T_local = (B // dp) * S
    capacity = int(math.ceil(T_local * topk / E * m.capacity_factor))
    capacity = max(capacity, 4)

    # mask logits of padded experts so the router never selects them
    n_pad = E - m.n_experts
    pad_mask = jnp.where(jnp.arange(E) < m.n_experts, 0.0, -1e9)

    espec = expert_spec(policy, path)
    e_run = (degree if (espec.dynamic and degree is not None) else espec.ebits)
    # the runtime degree enters shard_map as an explicit replicated scalar
    # (closed-over tracers don't cross the shard_map boundary)
    e_arr = jnp.asarray(e_run, jnp.int32)

    def body(xs, router_w, expert_w, e_deg):
        # xs: (B_local, S, d) — replicated over model axis
        bl, s, _ = xs.shape
        t = bl * s
        xt = xs.reshape(t, d)
        logits = (xt.astype(jnp.float32) @ router_w.astype(jnp.float32)) + pad_mask
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, ids = jax.lax.top_k(probs, topk)          # (t, topk)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
            jnp.ones((t * topk,), jnp.float32)) / (t * topk)
        aux = E * jnp.sum(me * ce)

        # --- local dispatch --------------------------------------------
        axis_idx = jax.lax.axis_index("model")
        e0 = axis_idx * E_local
        flat_ids = ids.reshape(-1)                           # (t*topk,)
        flat_gate = gate_vals.reshape(-1)
        local_e = flat_ids - e0                              # local expert idx
        is_local = (local_e >= 0) & (local_e < E_local)
        onehot = jax.nn.one_hot(jnp.where(is_local, local_e, E_local),
                                E_local + 1, dtype=jnp.int32)[:, :E_local]
        ranks = jnp.cumsum(onehot, axis=0) - onehot          # rank within expert
        slot = jnp.sum(ranks * onehot, axis=-1)              # (t*topk,)
        keep = is_local & (slot < capacity)
        tok_idx = jnp.arange(t * topk) // topk

        # scatter token rows into (E_local, C, d)
        e_idx = jnp.where(keep, local_e, 0)
        s_idx = jnp.where(keep, slot, 0)
        buf = jnp.zeros((E_local, capacity, d), xt.dtype)
        rows = jnp.where(keep[:, None], xt[tok_idx], 0).astype(xt.dtype)
        buf = buf.at[e_idx, s_idx].add(jnp.where(keep[:, None], rows, 0))

        w_local = expert_w  # already sliced by shard_map: (E_local, d, f)
        y_buf = _local_expert_ffn(w_local, buf, act, espec, e_deg).astype(xt.dtype)

        # gather back + gate + combine
        y_rows = y_buf[e_idx, s_idx]                         # (t*topk, d)
        y_rows = jnp.where(keep[:, None], y_rows, 0) * flat_gate[:, None].astype(xt.dtype)
        yt = jnp.zeros((t, d), xt.dtype).at[tok_idx].add(y_rows)
        if _MOE_RING:
            yt = _ring_psum_model(yt)
        else:
            yt = jax.lax.psum(yt, "model")
        aux = jax.lax.pmean(aux, ("model",) + tuple(bdims))
        return yt.reshape(bl, s, d), aux

    in_specs = (
        P(bdims if bdims else None, None, None),
        P(None, None),
        # exact-structure spec tree: prepacked experts carry (qw, scales)
        # leaves; every leaf is expert-major on the model axis
        jax.tree.map(lambda _: P("model", None, None), params["experts"]),
        P(),
    )
    out_specs = (P(bdims if bdims else None, None, None), P())
    y, aux = jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x, params["router"]["w"], params["experts"], e_arr)

    if "shared" in params:
        from repro.models.layers import gated_mlp_apply

        shared = gated_mlp_apply(
            {"up": {"w": params["shared"]["up"]},
             "gate": {"w": params["shared"]["gate"]},
             "down": {"w": params["shared"]["down"]}},
            x, policy, path + "/shared", act=act, degree=degree)
        y = y + shared
    return y, aux
