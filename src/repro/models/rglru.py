"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention,
stacked in the (rec, rec, attn) pattern.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)                      recurrence gate
    i_t = sigmoid(W_x x_t)                      input gate
    a_t = exp(-c * softplus(Lambda) * r_t)      per-channel decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` (log-depth, O(S) memory);
decode is an O(1) state update — which is why long_500k runs for this arch.
The layer stack scans over pattern *groups* (homogeneous params) plus an
explicit tail for n_layers % len(pattern).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.approx import ApproxPolicy
from repro.dist import meshctx
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.degrees import split_degree

Array = jnp.ndarray
_C = 8.0


def _group_degrees(degree, cfg: ArchConfig):
    """Split a runtime degree into (per-group (n_groups, len(pat)) matrix,
    per-tail-block vector, head scalar) following the hybrid's group-major
    layer order (models/degrees.py): layer ``g * len(pat) + i`` is block
    ``i`` of group ``g``; tail blocks come last."""
    ldeg, hdeg = split_degree(degree, cfg.n_layers)
    if ldeg is None:
        return None, None, None
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    gdeg = ldeg[: n_groups * len(pat)].reshape(n_groups, len(pat))
    tdeg = ldeg[n_groups * len(pat):]
    return gdeg, tdeg, hdeg


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------


def init_rec_block(key, cfg: ArchConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    lam = jax.random.uniform(ks[5], (d,), jnp.float32, 0.9**2, 0.999**2)
    # Lambda parameterized so that a = lam^(c*r) at r=1: softplus(L) = -log(lam)/c
    lam_param = jnp.log(jnp.expm1(-jnp.log(lam) / _C))
    return {
        "ln1": L.init_rmsnorm(d),
        "ln2": L.init_rmsnorm(d),
        "wx": L.init_dense(ks[0], d, d),          # input branch
        "wg": L.init_dense(ks[1], d, d),          # gate branch (GeLU)
        "conv": L.init_conv1d(ks[2], d, 4),
        "wa": L.init_dense(ks[3], d, d),          # recurrence gate
        "wi": L.init_dense(ks[4], d, d),          # input gate
        "lam": lam_param,
        "wo": L.init_dense(ks[6], d, d, scale=1.0 / math.sqrt(d)),
        "mlp": L.init_gated_mlp(jax.random.fold_in(key, 9), d, cfg.d_ff),
    }


def _rglru_scan(x: Array, a: Array, h0: Array | None = None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.
    x (= b_t), a: (B, S, d) f32.  Returns all h_t (B, S, d)."""
    if h0 is not None:
        # fold initial state into the first step
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h


def rec_block_apply(bp, x: Array, cfg: ArchConfig, policy: ApproxPolicy,
                    path: str, degree=None,
                    state: tuple[Array, Array] | None = None,
                    lengths: Array | None = None):
    """Pre-norm residual recurrent block.  state = (h (B,d), conv (B,3,d)) for
    decode; None for train/prefill.  Returns (x_out, new_state_or_None).

    ``lengths`` (B,) gathers the returned recurrent/conv state at each row's
    true length instead of the last position — the bucket-padded prefill path
    (prefix results of the associative scan and causal conv are untouched by
    a padded tail, so the gathered state is bit-identical to exact-length)."""
    h_in = L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps)
    xb = L.dense_apply(bp["wx"], h_in, policy, path + "/wx", degree)
    gb = L.dense_apply(bp["wg"], h_in, policy, path + "/wg", degree)
    conv_state = state[1] if state is not None else None
    conv_in = xb
    xb, new_conv = L.conv1d_apply(bp["conv"], xb, conv_state)
    r = jax.nn.sigmoid(
        L.dense_apply(bp["wa"], h_in, policy, path + "/wa", degree).astype(jnp.float32))
    i = jax.nn.sigmoid(
        L.dense_apply(bp["wi"], h_in, policy, path + "/wi", degree).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(bp["lam"]) * r          # (B,S,d) f32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb.astype(jnp.float32))
    if state is None:
        hseq = _rglru_scan(gated_in, a)
        if lengths is None:
            new_h = hseq[:, -1]
        else:
            from repro.models.ssm import _conv_tail

            idx = jnp.maximum(lengths - 1, 0)[:, None, None]
            new_h = jnp.take_along_axis(hseq, idx, axis=1)[:, 0]
            new_h = jnp.where(lengths[:, None] > 0, new_h, 0.0)
            width = bp["conv"]["w"].shape[0]
            new_conv = _conv_tail(conv_in, lengths, width)
    else:
        h_prev = state[0]
        hseq = (a[:, 0] * h_prev + gated_in[:, 0])[:, None]
        new_h = hseq[:, 0]
    y = hseq.astype(x.dtype) * jax.nn.gelu(gb)
    # residual adds ride the projection epilogues (fused in-kernel on AXQ)
    x = L.dense_apply(bp["wo"], y, policy, path + "/wo", degree, residual=x)
    h2 = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
    out = L.gated_mlp_apply(bp["mlp"], h2, policy, path + "/mlp", cfg.act,
                            degree, residual=x)
    return out, (new_h, new_conv)


# ---------------------------------------------------------------------------
# local-attention block (window = cfg.local_window)
# ---------------------------------------------------------------------------


def init_attn_block(key, cfg: ArchConfig, tp: int):
    from repro.models.transformer import init_block

    return init_block(key, cfg, tp)


def attn_block_apply(bp, x, cfg: ArchConfig, tp, policy, path, positions,
                     degree=None, return_kv: bool = False):
    from repro.models.transformer import block_apply
    import dataclasses

    cfg_local = dataclasses.replace(cfg, swa_window=cfg.local_window, moe=None)
    return block_apply(bp, x, cfg_local, tp, policy, path, positions, degree,
                       return_kv=return_kv)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_hybrid(key, cfg: ArchConfig, tp: int):
    pat = cfg.block_pattern
    n_groups, tail = divmod(cfg.n_layers, len(pat))
    ks = jax.random.split(key, 5)
    gkeys = jax.random.split(ks[0], n_groups)

    def init_group(k):
        kk = jax.random.split(k, len(pat))
        return {
            f"{name}{i}": (
                init_rec_block(kk[i], cfg) if name == "rec"
                else init_attn_block(kk[i], cfg, tp)
            )
            for i, name in enumerate(pat)
        }

    params = {
        "embed": L.init_embedding(ks[1], cfg.padded(tp).vocab, cfg.d_model),
        "groups": jax.vmap(init_group)(gkeys),
        "ln_f": L.init_rmsnorm(cfg.d_model),
        "unembed": L.init_dense(ks[2], cfg.d_model, cfg.padded(tp).vocab,
                                scale=1.0 / math.sqrt(cfg.d_model)),
    }
    tkeys = jax.random.split(ks[3], max(tail, 1))
    params["tail"] = [init_rec_block(tkeys[i], cfg) for i in range(tail)]
    return params


def hybrid_forward(params, cfg: ArchConfig, policy: ApproxPolicy, batch: dict,
                   tp: int = 1, degree=None, remat: str = "dots"):
    gdeg, tdeg, hdeg = _group_degrees(degree, cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens, dtype)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pat = cfg.block_pattern

    def group_body(h, xs):
        gp, dg = (xs, None) if gdeg is None else xs
        for i, name in enumerate(pat):
            bp = gp[f"{name}{i}"]
            di = None if dg is None else dg[i]
            if name == "rec":
                h, _ = rec_block_apply(bp, h, cfg, policy, f"g/{name}{i}", di)
            else:
                h, _ = attn_block_apply(bp, h, cfg, tp, policy, f"g/{name}{i}",
                                        positions, di)
        return h, None

    body = group_body
    if remat != "none":
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    xs = params["groups"] if gdeg is None else (params["groups"], gdeg)
    x, _ = jax.lax.scan(body, x, xs)
    for i, bp in enumerate(params["tail"]):
        x, _ = rec_block_apply(bp, x, cfg, policy, f"tail/{i}",
                               kdispatch.site_degree(tdeg, i))
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = L.dense_apply(params["unembed"], x, policy, "unembed", hdeg)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


class HybridCache(NamedTuple):
    # attention caches: one per group's attn layer (+0 for tail)
    k: Array          # (n_groups, B, W, KVr, D)
    v: Array
    # recurrent states: (n_rec_total, B, d) and conv tails (n_rec_total, B, 3, d)
    h: Array
    conv: Array
    length: Array     # (B,)


def init_hybrid_cache(cfg: ArchConfig, tp: int, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> HybridCache:
    pat = cfg.block_pattern
    n_groups, tail = divmod(cfg.n_layers, len(pat))
    n_rec = n_groups * sum(1 for p in pat if p == "rec") + tail
    pd = cfg.padded(tp)
    W = min(cfg.local_window or max_len, max_len)
    return HybridCache(
        k=jnp.zeros((n_groups, batch, W, pd.n_kv_rep, cfg.head_dim), dtype),
        v=jnp.zeros((n_groups, batch, W, pd.n_kv_rep, cfg.head_dim), dtype),
        h=jnp.zeros((n_rec, batch, cfg.d_model), jnp.float32),
        conv=jnp.zeros((n_rec, batch, 3, cfg.d_model), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def hybrid_prefill(params, cfg: ArchConfig, policy: ApproxPolicy,
                   cache: HybridCache, tokens: Array, slot, tp: int = 1,
                   degree=None):
    """Fused prefill: one full forward over the prompt; recurrent/conv states
    (associative-scan path) and local-attention KV (ring-wrapped to the
    window) are written into ``slot``'s cache region.

    tokens: (P,) int32.  Returns (last-position logits (1, V) f32, cache with
    ``length[slot] = P``).  The slot region is reset first (reuse == fresh).
    """
    from repro.models.cache_ops import cache_reset_slot, ring_write_indices

    gdeg, tdeg, hdeg = _group_degrees(degree, cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    rec_per_group = sum(1 for p in pat if p == "rec")
    cache = cache_reset_slot(cache, slot)
    P = tokens.shape[0]
    W = cache.k.shape[2]
    # ring writes are only valid when decode also ring-wraps (window <= W);
    # a capacity-truncated window cache saturates instead (attention.py)
    ring = cfg.local_window is not None and cfg.local_window <= W
    if P > W and not ring:
        raise ValueError(f"prompt ({P}) exceeds cache capacity ({W})")
    x = L.embed_apply(params["embed"], tokens[None], dtype)   # (1, P, d)
    positions = jnp.arange(P, dtype=jnp.int32)[None]

    def group_body(h, xs):
        gp, dg = (xs, None) if gdeg is None else xs
        nh, nc = [], []
        gk = gv = None
        for i, name in enumerate(pat):
            bp = gp[f"{name}{i}"]
            di = None if dg is None else dg[i]
            if name == "rec":
                h, (h_new, conv_new) = rec_block_apply(
                    bp, h, cfg, policy, "g", di)
                nh.append(h_new)
                nc.append(conv_new)
            else:
                h, _, (gk, gv) = attn_block_apply(
                    bp, h, cfg, tp, policy, "g", positions, di,
                    return_kv=True)                        # k/v: (1, P, KVr, D)
        return h, (gk, gv, jnp.stack(nh), jnp.stack(nc))

    xs = params["groups"] if gdeg is None else (params["groups"], gdeg)
    x, (ks, vs, nhs, ncs) = jax.lax.scan(group_body, x, xs)
    # ks: (n_groups, 1, P, KVr, D); nhs: (n_groups, rec_per_group, 1, d)
    new_h = [nhs.reshape(n_groups * rec_per_group, cfg.d_model)]
    new_c = [ncs.reshape(n_groups * rec_per_group, 3, cfg.d_model)]
    for i, bp in enumerate(params["tail"]):
        # path "tail" matches hybrid_decode_step: a path-keyed policy must
        # resolve identically in prefill and teacher-forced decode
        x, (h_new, conv_new) = rec_block_apply(
            bp, x, cfg, policy, "tail", kdispatch.site_degree(tdeg, i))
        new_h.append(h_new)
        new_c.append(conv_new)
    src, dst = ring_write_indices(P, W)
    new_cache = HybridCache(
        k=cache.k.at[:, slot, dst].set(ks[:, 0, src].astype(cache.k.dtype)),
        v=cache.v.at[:, slot, dst].set(vs[:, 0, src].astype(cache.v.dtype)),
        h=cache.h.at[:, slot].set(jnp.concatenate(new_h, axis=0)),
        conv=cache.conv.at[:, slot].set(
            jnp.concatenate(new_c, axis=0).astype(cache.conv.dtype)),
        length=cache.length.at[slot].set(P),
    )
    xl = L.rmsnorm_apply(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.dense_apply(params["unembed"], xl, policy, "unembed", hdeg)
    return logits.astype(jnp.float32)[:, 0], new_cache


def hybrid_prefill_batch(params, cfg: ArchConfig, policy: ApproxPolicy,
                         cache: HybridCache, tokens: Array, slots: Array,
                         lengths: Array, tp: int = 1, degree=None) -> HybridCache:
    """Bucketed/packed prefill: rows (N, Pb) padded to one bucket length,
    written into ``slots`` with true ``lengths``.  Recurrent/conv states are
    gathered at each row's length (associative-scan prefixes are padding-
    independent) and local-attention KV lands via a masked tail scatter —
    per-row results are bit-identical to ``hybrid_prefill`` at the exact
    length.  Dummy rows (slot >= B) are dropped.  Returns the cache only."""
    gdeg, tdeg, _ = _group_degrees(degree, cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    rec_per_group = sum(1 for p in pat if p == "rec")
    N, Pb = tokens.shape
    W = cache.k.shape[2]
    ring = cfg.local_window is not None and cfg.local_window <= W
    if Pb > W and not ring:
        raise ValueError(f"bucket ({Pb}) exceeds cache capacity ({W})")
    x = L.embed_apply(params["embed"], tokens, dtype)         # (N, Pb, d)
    positions = jnp.broadcast_to(jnp.arange(Pb, dtype=jnp.int32)[None], (N, Pb))

    def group_body(h, xs):
        gp, dg = (xs, None) if gdeg is None else xs
        nh, nc = [], []
        gk = gv = None
        for i, name in enumerate(pat):
            bp = gp[f"{name}{i}"]
            di = None if dg is None else dg[i]
            if name == "rec":
                h, (h_new, conv_new) = rec_block_apply(
                    bp, h, cfg, policy, "g", di, lengths=lengths)
                nh.append(h_new)
                nc.append(conv_new)
            else:
                h, _, (gk, gv) = attn_block_apply(
                    bp, h, cfg, tp, policy, "g", positions, di,
                    return_kv=True)                        # k/v: (N, Pb, KVr, D)
        return h, (gk, gv, jnp.stack(nh), jnp.stack(nc))

    xs = params["groups"] if gdeg is None else (params["groups"], gdeg)
    x, (ks, vs, nhs, ncs) = jax.lax.scan(group_body, x, xs)
    # ks: (n_groups, N, Pb, KVr, D); nhs: (n_groups, rec_per_group, N, d)
    new_h = [nhs.reshape(n_groups * rec_per_group, N, cfg.d_model)]
    new_c = [ncs.reshape(n_groups * rec_per_group, N, 3, cfg.d_model)]
    for i, bp in enumerate(params["tail"]):
        x, (h_new, conv_new) = rec_block_apply(
            bp, x, cfg, policy, "tail", kdispatch.site_degree(tdeg, i),
            lengths=lengths)
        new_h.append(h_new[None])
        new_c.append(conv_new[None])
    # masked tail scatter: last min(len, W) tokens at j % W, rest dropped OOB
    j = jnp.arange(Pb, dtype=jnp.int32)[None]
    ln = lengths[:, None]
    valid = (j < ln) & (j >= ln - W)
    dst = jnp.where(valid, j % W, W)                          # (N, Pb)
    rows = jnp.arange(N)[:, None]
    KVr, D = ks.shape[3], ks.shape[4]
    cdt = cache.k.dtype
    regk = jnp.zeros((n_groups, N, W, KVr, D), cdt).at[:, rows, dst].set(
        ks.astype(cdt))
    regv = jnp.zeros((n_groups, N, W, KVr, D), cdt).at[:, rows, dst].set(
        vs.astype(cdt))
    return HybridCache(
        k=cache.k.at[:, slots].set(regk),
        v=cache.v.at[:, slots].set(regv),
        h=cache.h.at[:, slots].set(jnp.concatenate(new_h, axis=0)),
        conv=cache.conv.at[:, slots].set(
            jnp.concatenate(new_c, axis=0).astype(cache.conv.dtype)),
        length=cache.length.at[slots].set(lengths),
    )


def hybrid_decode_step(params, cfg: ArchConfig, policy: ApproxPolicy,
                       cache: HybridCache, tokens: Array, tp: int = 1,
                       degree=None, active=None):
    from repro.models.transformer import _qkv

    gdeg, tdeg, hdeg = _group_degrees(degree, cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pd = cfg.padded(tp)
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, dtype)
    positions = cache.length[:, None]
    rec_per_group = sum(1 for p in pat if p == "rec")

    def group_body(carry, xs):
        h = carry
        gp, ck, cv, hs, cs, *rest = xs  # hs: (rec_per_group, B, d)
        dg = rest[0] if rest else None
        ri = 0
        nh, nc = [], []
        for i, name in enumerate(pat):
            bp = gp[f"{name}{i}"]
            di = None if dg is None else dg[i]
            if name == "rec":
                h, (h_new, conv_new) = rec_block_apply(
                    bp, h, cfg, policy, "g", di,
                    state=(hs[ri], cs[ri]))
                nh.append(h_new)
                nc.append(conv_new)
                ri += 1
            else:
                hn = L.rmsnorm_apply(bp["ln1"], h, cfg.norm_eps)
                import dataclasses

                cfg_l = dataclasses.replace(cfg, swa_window=cfg.local_window)
                q, k, v = _qkv(bp, hn, cfg_l, pd, policy, "g", positions, di)
                lc = attn.KVCache(ck, cv, cache.length)
                o, lc2 = kdispatch.decode_attention(
                    q, k, v, lc, window=cfg.local_window, degree=di,
                    active=active)
                o = o.reshape(B, 1, pd.n_heads * cfg.head_dim)
                h = L.dense_apply(bp["wo"], o, policy, "g/wo", di,
                                  residual=h)
                hn = L.rmsnorm_apply(bp["ln2"], h, cfg.norm_eps)
                h = L.gated_mlp_apply(bp["mlp"], hn, policy, "g/mlp", cfg.act,
                                      di, residual=h)
                ck, cv = lc2.k, lc2.v
        return h, (ck, cv, jnp.stack(nh), jnp.stack(nc))

    n_tail = len(params["tail"])
    hs_groups = cache.h[: n_groups * rec_per_group].reshape(
        n_groups, rec_per_group, B, cfg.d_model)
    cs_groups = cache.conv[: n_groups * rec_per_group].reshape(
        n_groups, rec_per_group, B, 3, cfg.d_model)
    xs = (params["groups"], cache.k, cache.v, hs_groups, cs_groups)
    if gdeg is not None:
        xs = xs + (gdeg,)
    x, (nk, nv, nhs, ncs) = jax.lax.scan(group_body, x, xs)
    new_h = [nhs.reshape(-1, B, cfg.d_model)]
    new_c = [ncs.reshape(-1, B, 3, cfg.d_model)]
    for i, bp in enumerate(params["tail"]):
        idx = n_groups * rec_per_group + i
        x, (h_new, conv_new) = rec_block_apply(
            bp, x, cfg, policy, "tail", kdispatch.site_degree(tdeg, i),
            state=(cache.h[idx], cache.conv[idx]))
        new_h.append(h_new[None])
        new_c.append(conv_new[None])
    x = L.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = L.dense_apply(params["unembed"], x, policy, "unembed", hdeg)
    new_cache = HybridCache(
        k=nk, v=nv,
        h=jnp.concatenate(new_h, axis=0),
        conv=jnp.concatenate(new_c, axis=0),
        length=cache.length + 1,
    )
    return logits.astype(jnp.float32), new_cache
