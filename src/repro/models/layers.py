"""Approximation-aware building blocks (pure-JAX, param-dict style).

Parameters are nested dicts of jnp arrays; ``init_*`` builds them, ``*_apply``
consumes them.  Every matmul goes through :func:`repro.kernels.ops.approx_matmul`
with the ApproxSpec resolved from the model's ApproxPolicy by parameter path —
the MAx-DNN-style fine-grained approximation hook (DESIGN.md §2.3).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec
from repro.kernels.ops import approx_gated_matmul, approx_matmul

Array = jnp.ndarray


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, bias: bool = False, scale: float | None = None):
    w_key, _ = jax.random.split(key)
    stddev = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(w_key, (d_in, d_out), stddev)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_apply(p, x: Array, policy: ApproxPolicy, path: str,
                degree: Optional[Array] = None,
                residual: Optional[Array] = None) -> Array:
    """``x @ w (+ b) (+ residual)``.  On the AXQ route bias and residual ride
    the kernel's fused f32 epilogue (one writeback, DESIGN.md §9); elsewhere
    they are the same post-cast adds the call sites used to do inline."""
    spec = policy.spec_for(path)
    if spec.mode == ApproxMode.AXQ:
        return approx_matmul(x, p["w"], spec, degree=degree, out_dtype=x.dtype,
                             path=path, bias=p.get("b"), residual=residual)
    y = approx_matmul(x, p["w"], spec, degree=degree, out_dtype=x.dtype,
                      path=path)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    if residual is not None:
        y = residual + y
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(p, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(dt)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(p, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int):
    # 1/sqrt(d) keeps tied-unembedding logits at unit variance
    return {"emb": truncated_normal(key, (vocab, d), 1.0 / math.sqrt(d))}


def embed_apply(p, tokens: Array, dtype=jnp.bfloat16) -> Array:
    return jnp.take(p["emb"], tokens, axis=0).astype(dtype)


def unembed_apply(p, x: Array, policy: ApproxPolicy, path: str,
                  degree=None) -> Array:
    """logits = x @ emb.T (tied) — routed through the approx dispatch.
    A prepacked tied unembedding rides the embed dict as ``unembed_q``
    (kernels/qstore.py); the token-lookup ``emb`` stays float."""
    spec = policy.spec_for(path)
    w = p.get("unembed_q")
    if w is None:
        w = p["emb"].T
    return approx_matmul(x, w, spec, degree=degree, out_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_gated_mlp(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": init_dense(k1, d, d_ff),
        "gate": init_dense(k2, d, d_ff),
        "down": init_dense(k3, d_ff, d, scale=1.0 / math.sqrt(d_ff)),
    }


def gated_mlp_apply(p, x: Array, policy: ApproxPolicy, path: str, act: str = "silu",
                    degree=None, residual: Optional[Array] = None) -> Array:
    """up/gate/act(gate)*up/down.  When up and gate share one AXQ spec the
    first half runs as ONE fused kernel (shared x stream, gate applied
    in-VMEM — one HBM roundtrip instead of three); the down projection fuses
    ``residual`` into its epilogue (DESIGN.md §9)."""
    spec_up = policy.spec_for(path + "/up")
    spec_gate = policy.spec_for(path + "/gate")
    if (spec_up.mode == ApproxMode.AXQ and spec_gate == spec_up
            and "b" not in p["up"] and "b" not in p["gate"]):
        h = approx_gated_matmul(x, p["up"]["w"], p["gate"]["w"], spec_up,
                                act=act, degree=degree, out_dtype=x.dtype)
    else:
        up = dense_apply(p["up"], x, policy, path + "/up", degree)
        gate = dense_apply(p["gate"], x, policy, path + "/gate", degree)
        h = act_fn(act)(gate) * up
    return dense_apply(p["down"], h, policy, path + "/down", degree,
                       residual=residual)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (RG-LRU / Mamba front conv)
# ---------------------------------------------------------------------------


def init_conv1d(key, channels: int, width: int):
    return {"w": truncated_normal(key, (width, channels), 1.0 / math.sqrt(width)),
            "b": jnp.zeros((channels,), jnp.float32)}


def conv1d_apply(p, x: Array, state: Optional[Array] = None):
    """Causal depthwise conv. x: (B, S, C).  If `state` (B, width-1, C) is
    given (decode), it is prepended and the new state returned."""
    width = p["w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * p["w"][i]
    out = (out + p["b"]).astype(x.dtype)
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return out, new_state


# ---------------------------------------------------------------------------
# Sharding-constraint helper (activation partitioning)
# ---------------------------------------------------------------------------


def shard_activation(x: Array, spec) -> Array:
    """Apply a with_sharding_constraint if a mesh context is active and the
    array rank matches; no-op on single-device tests."""
    try:
        from jax.sharding import NamedSharding

        from repro.dist.meshctx import get_mesh

        mesh = get_mesh()
        if mesh.size == 1:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x
