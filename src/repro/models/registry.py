"""Uniform model API over all families.

    model = build_model(cfg)
    params = model.init(key, tp)
    loss, metrics = model.loss(params, batch, tp=tp)
    logits, aux = model.forward(params, batch, tp=tp)
    cache = model.init_cache(tp, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens, tp=tp)

Every compute entry point takes a runtime ``degree``: None (static policy
specs), a global scalar, or an ``(n_layers + 1,)`` per-site vector — an
ApproxPlan rung (models/degrees.py).  All three are traced operands; moving
a degree never recompiles.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.approx import ApproxPolicy
from repro.models import rglru, ssm, transformer

Array = jnp.ndarray


@dataclass
class Model:
    cfg: ArchConfig
    policy: ApproxPolicy = field(default_factory=ApproxPolicy)

    # ---- init ----
    def init(self, key, tp: int = 1):
        if self.cfg.family == "hybrid":
            return rglru.init_hybrid(key, self.cfg, tp)
        if self.cfg.family == "ssm":
            return ssm.init_ssm_lm(key, self.cfg, tp)
        return transformer.init_lm(key, self.cfg, tp)

    # ---- forward ----
    def forward(self, params, batch, tp: int = 1, degree=None, remat="dots"):
        if self.cfg.family == "hybrid":
            return rglru.hybrid_forward(params, self.cfg, self.policy, batch,
                                        tp, degree, remat)
        if self.cfg.family == "ssm":
            return ssm.ssm_forward(params, self.cfg, self.policy, batch,
                                   tp, degree, remat)
        return transformer.lm_forward(params, self.cfg, self.policy, batch,
                                      tp, degree, remat)

    # ---- loss ----
    def loss(self, params, batch, tp: int = 1, degree=None, remat="dots"):
        if self.cfg.family in ("hybrid", "ssm"):
            logits, aux = self.forward(params, batch, tp, degree, remat)
            labels = batch["labels"]
            mask = (labels >= 0).astype(jnp.float32)
            lc = jnp.maximum(labels, 0)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
            ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return ce, {"ce": ce, "aux": aux, "ntokens": jnp.sum(mask)}
        return transformer.lm_loss(params, self.cfg, self.policy, batch,
                                   tp, degree, remat)

    # ---- decode ----
    def init_cache(self, tp: int, batch: int, max_len: int, dtype=jnp.bfloat16,
                   quant: Optional[bool] = None):
        if self.cfg.encoder_only:
            raise ValueError("encoder-only arch has no decode step")
        if self.cfg.family == "hybrid":
            return rglru.init_hybrid_cache(self.cfg, tp, batch, max_len, dtype)
        if self.cfg.family == "ssm":
            return ssm.init_ssm_cache(self.cfg, tp, batch, max_len, dtype)
        if quant is None:
            import os

            quant = os.environ.get("REPRO_KV_INT8", "0") == "1"
        return transformer.init_lm_cache(self.cfg, tp, batch, max_len, dtype,
                                         quant=quant)

    def decode_step(self, params, cache, tokens, tp: int = 1, degree=None,
                    active=None):
        """``active`` (B,) bool: free-slot mask forwarded to the attention
        kernel dispatch (SSM decode has no attention; it ignores it)."""
        if self.cfg.family == "hybrid":
            return rglru.hybrid_decode_step(params, self.cfg, self.policy,
                                            cache, tokens, tp, degree, active)
        if self.cfg.family == "ssm":
            return ssm.ssm_decode_step(params, self.cfg, self.policy,
                                       cache, tokens, tp, degree)
        return transformer.lm_decode_step(params, self.cfg, self.policy,
                                          cache, tokens, tp, degree, active)

    def prefill(self, params, cache, tokens, slot, tp: int = 1, degree=None):
        """Fused prefill: write prompt ``tokens`` (P,) into ``slot``'s cache
        region in ONE forward call (serve-engine admission path).  The slot
        region is reset first, so reuse-after-free equals a fresh slot.
        Returns (last-position logits (1, V) f32, new cache)."""
        if self.cfg.family == "hybrid":
            return rglru.hybrid_prefill(params, self.cfg, self.policy,
                                        cache, tokens, slot, tp, degree)
        if self.cfg.family == "ssm":
            return ssm.ssm_prefill(params, self.cfg, self.policy,
                                   cache, tokens, slot, tp, degree)
        return transformer.lm_prefill(params, self.cfg, self.policy,
                                      cache, tokens, slot, tp, degree)

    def prefill_batch(self, params, cache, tokens, slots, lengths,
                      tp: int = 1, degree=None):
        """Bucketed/packed prefill (serve admission pipeline, DESIGN.md §15):
        ``tokens`` (N, Pb) rows padded to one bucket length, written into
        ``slots`` (N,) with true ``lengths`` (N,).  Per-row bit-identical to
        ``prefill`` at the exact length (MoE excluded — capacity routing
        couples rows).  Dummy rows use slot >= B (scatters drop out-of-bounds
        indices).  Returns the new cache only."""
        if self.cfg.moe:
            raise ValueError("bucketed prefill is exact-only for MoE "
                             "(capacity routing couples packed rows)")
        if self.cfg.family == "hybrid":
            return rglru.hybrid_prefill_batch(params, self.cfg, self.policy,
                                              cache, tokens, slots, lengths,
                                              tp, degree)
        if self.cfg.family == "ssm":
            return ssm.ssm_prefill_batch(params, self.cfg, self.policy,
                                         cache, tokens, slots, lengths,
                                         tp, degree)
        return transformer.lm_prefill_batch(params, self.cfg, self.policy,
                                            cache, tokens, slots, lengths,
                                            tp, degree)

    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill is implemented for dense full-attention
        transformers (no MoE, no sliding window, float KV cache)."""
        return (self.cfg.family not in ("hybrid", "ssm")
                and not self.cfg.moe and self.cfg.swa_window is None
                and self.cfg.causal and self.cfg.frontend is None)

    def prefill_chunk(self, params, cache, tokens, slot, offset, clen,
                      tp: int = 1, degree=None):
        """Incremental prefill of one chunk (``tokens`` (C,), ``clen`` real)
        at position ``offset`` of ``slot``'s prompt.  Dense transformer
        caches only — see ``supports_chunked_prefill``.  Returns the cache."""
        if not self.supports_chunked_prefill():
            raise ValueError(f"chunked prefill unsupported for {self.cfg.name}")
        return transformer.lm_prefill_chunk(params, self.cfg, self.policy,
                                            cache, tokens, slot, offset, clen,
                                            tp, degree)

    def reset_slot(self, cache, slot):
        """Rewind one slot's cache region (KV/state and length) to init."""
        from repro.models.cache_ops import cache_reset_slot

        return cache_reset_slot(cache, slot)

    def prepack(self, params):
        """Quantize-once weight residency (DESIGN.md §9): pack every dense
        weight whose policy spec is AXQ / *_EMUL into its int8 residency
        form.  Idempotent; a no-op for EXACT-only policies.  Call at init,
        checkpoint-load, or serve admission — the result is inference-only
        (packed leaves carry no gradients)."""
        from repro.kernels import qstore

        return qstore.prepack_params(params, self.cfg, self.policy)

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def build_model(cfg: ArchConfig, policy: Optional[ApproxPolicy] = None) -> Model:
    return Model(cfg, policy or ApproxPolicy())


# ---------------------------------------------------------------------------
# input specs — ShapeDtypeStruct stand-ins for every model input (dry-run)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Abstract input batch for (arch, shape): weak-type-correct,
    shardable, no device allocation."""
    from repro.configs.base import SHAPES

    s = SHAPES[shape_name]
    B, S = s.global_batch, s.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    if s.kind == "decode":
        return {"tokens": sd((B, 1), i32)}
    if cfg.frontend == "vision":
        s_img = cfg.frontend_tokens
        s_txt = S - s_img
        return {
            "tokens": sd((B, s_txt), i32),
            "patch_embeds": sd((B, s_img, cfg.frontend_dim), f32),
            "labels": sd((B, s_txt), i32),
        }
    if cfg.frontend == "audio":
        return {
            "frame_feats": sd((B, S, cfg.frontend_dim), f32),
            "labels": sd((B, S), i32),
        }
    return {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}


def concrete_batch(cfg: ArchConfig, seq: int, batch: int, key=None) -> dict:
    """Small concrete random batch (smoke tests, examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    out: dict = {}
    if cfg.frontend == "audio":
        out["frame_feats"] = jax.random.normal(ks[0], (batch, seq, cfg.frontend_dim))
        out["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
        return out
    if cfg.frontend == "vision":
        s_img = cfg.frontend_tokens
        s_txt = seq - s_img
        out["patch_embeds"] = jax.random.normal(ks[0], (batch, s_img, cfg.frontend_dim))
        out["tokens"] = jax.random.randint(ks[1], (batch, s_txt), 0, cfg.vocab)
        out["labels"] = jax.random.randint(ks[2], (batch, s_txt), 0, cfg.vocab)
        return out
    out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    out["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    return out
