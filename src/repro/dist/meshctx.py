"""Process-global mesh registry + activation-sharding helpers.

The launch entrypoints build one mesh per process (``make_mesh`` +
``set_mesh``); model code reads it back with ``get_mesh`` wherever a sharding
decision is needed at trace time (activation constraints, shard_map regions,
MoE capacity math).  Single-device runs (unit tests) never call ``set_mesh``
— ``get_mesh`` lazily returns a trivial ``(1, 1)`` ``("data", "model")`` mesh
so every call site works unconditionally.

Axis convention (DESIGN.md §5.1): the last axis is always ``"model"``
(tensor/expert parallelism); every other axis shards the batch
(``"data"``, and ``"pod"`` on multi-pod meshes).
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_MESH: Optional[Mesh] = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Build a mesh of the first ``prod(shape)`` local devices.

    ``shape`` and ``axes`` must align; ``axes`` must contain ``"model"``.
    """
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} / axes {axes} rank mismatch")
    if "model" not in axes:
        raise ValueError(f"mesh axes {axes} must include 'model'")
    n = math.prod(shape)
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(
            f"mesh {tuple(shape)} needs {n} devices, have {len(devices)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N for "
            "host-mesh dry-runs)")
    devs = np.asarray(devices[:n]).reshape(tuple(shape))
    return Mesh(devs, tuple(axes))


def set_mesh(mesh: Mesh) -> Mesh:
    """Install ``mesh`` as the process-global mesh; returns it."""
    global _MESH
    _MESH = mesh
    return mesh


def get_mesh() -> Mesh:
    """The active mesh; a trivial single-device mesh if none was set."""
    global _MESH
    if _MESH is None:
        _MESH = make_mesh((1, 1), ("data", "model"))
    return _MESH


@contextmanager
def use_mesh(mesh: Mesh):
    """Scoped ``set_mesh`` (tests / nested tools)."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def batch_axes(mesh: Optional[Mesh] = None) -> tuple[str, ...]:
    """Every mesh axis that shards the batch dim (all but ``"model"``)."""
    mesh = mesh or get_mesh()
    return tuple(a for a in mesh.axis_names if a != "model")


def bspec(*rest) -> P:
    """Activation PartitionSpec: batch dim over the data axes + explicit
    trailing dims, e.g. ``bspec(None, "model", None)`` for (B, S, H, D)."""
    b = batch_axes()
    return P(b if b else None, *rest)
