"""Replica fleet supervision: serve through replica loss (DESIGN.md §14).

One engine is a blast radius.  :class:`FleetSupervisor` fronts N
data-parallel replica groups — each its own :class:`~jax.sharding.Mesh`
slice of ``tp`` devices running an independent serve engine — and owns the
story the single engine cannot tell: a whole replica dying mid-decode.

The failure arc, end to end:

  1. the fleet-level :class:`~repro.resil.FaultPlan` schedules a seeded
     ``replica_loss`` event (same determinism contract as SEU/latency
     faults: stateless per-tick draws, scripted mode for exact scenarios);
  2. the supervisor marks the victim dead (``repro_replica_up`` gauge to
     0), migrates its *queued* requests to survivors in order, and rewinds
     its *in-flight* requests through the same front-requeue machinery the
     per-slot quarantine uses — full rewind, capped backoff, ``failed``
     past ``max_retries`` — so exactly-once ``{ok,failed,shed,deadline}``
     accounting holds fleet-wide;
  3. :func:`repro.dist.elastic.plan_rescale` picks the survivor mesh
     (ragged counts degrade to a power-of-two subset + ``idle_devices``
     instead of crashing the recovery path) and the rescale duration —
     injectable through :class:`~repro.resil.VirtualClock` — lands in the
     ``repro_rescale_seconds`` histogram;
  4. serving resumes on the survivors; the capacity dip is absorbed by
     each engine's own brownout ladder (degrade approximation rungs)
     before anything sheds.

:func:`decommission` is the graceful twin: stop routing, drain the
decodable slots in place, then retire the replica — zero rewinds.

Every transition is written to the fleet ``resil_log`` (plain
``(tick, name, sorted-args)`` tuples, ``==``-comparable across runs) and
mirrored onto the ``fleet`` trace track; ``bench_elastic`` pins the whole
arc — goodput across the kill, zero lost/dup/corrupt payloads, same-seed
recovery trace — behind the ``_check_elastic`` regression gate.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.dist.elastic import RescalePlan, plan_rescale
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry


def fleet_meshes(replicas: int, tp: int = 1) -> list:
    """One ``(1, tp)`` mesh per replica over disjoint device slices when
    ``replicas * tp`` local devices exist; otherwise every replica shares
    the first ``tp`` devices (degenerate but correct — the CI fast suite
    runs whole fleets on one host CPU device this way)."""
    devs = jax.devices()
    tp = min(tp, len(devs))
    meshes = []
    for r in range(replicas):
        lo = r * tp
        sub = devs[lo:lo + tp] if lo + tp <= len(devs) else devs[:tp]
        meshes.append(jax.sharding.Mesh(
            np.asarray(sub).reshape(1, tp), ("data", "model")))
    return meshes


@dataclass
class Replica:
    """One replica group: its mesh slice, its engine, and liveness."""

    rid: int
    mesh: object
    engine: object
    alive: bool = True
    #: fleet tick the replica died on (None while alive)
    died_at: Optional[int] = None


class FleetSupervisor:
    """Route requests across replica engines and survive losing one.

    ``build_engine(mesh, rid)`` constructs one replica's engine — the
    caller closes over shared pieces (model, params, QoS ladder, engine
    fault plans, the :class:`~repro.resil.VirtualClock`).  Engine-level
    fault plans must not carry ``replica_loss`` (engines ignore the kind;
    ``launch.serve`` zeroes it) — the fleet-level ``faults=`` plan is
    where replica deaths are drawn, bound here via ``bind_fleet``.

    ``policy`` governs the *fleet-level* rewind (retry cap + backoff for
    requests torn out of a dead replica's slots); per-engine policies keep
    governing their own queues.  ``rescale_ms`` is the modeled re-shard
    latency: charged to the injectable clock, observed into the
    ``repro_rescale_seconds`` histogram — deterministic on CI.
    """

    def __init__(self, build_engine: Callable, replicas: int, *,
                 tp: int = 1, clock=None, faults=None, policy=None,
                 registry: Optional[Registry] = None, tracer=None,
                 rescale_ms: float = 5.0,
                 target_global_batch: Optional[int] = None,
                 route_by: str = "slots"):
        if replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        if route_by not in ("slots", "backlog"):
            raise ValueError("route_by must be 'slots' or 'backlog'")
        self.route_by = route_by
        self.tp = int(tp)
        self._clock = clock if clock is not None else time.time
        self._tracer = (tracer if tracer is not None
                        else obs_trace.get_tracer())
        self.faults = faults
        if faults is not None:
            faults.bind_fleet(replicas)
        if policy is None:
            from repro.resil import ServePolicy
            policy = ServePolicy()
        self.policy = policy
        self.rescale_ms = float(rescale_ms)
        self.registry = registry if registry is not None else Registry()
        self._g_up = self.registry.gauge(
            "repro_replica_up", "replica liveness (1 = serving)",
            labels=("replica",))
        self._h_rescale = self.registry.histogram(
            "repro_rescale_seconds", "elastic rescale duration")
        self._c_loss = self.registry.counter(
            "repro_replica_loss_total", "replica-loss events applied")
        self.replicas: list[Replica] = []
        meshes = fleet_meshes(replicas, tp)
        # one fleet-wide request-id counter: per-engine counters would
        # collide across replicas, making the recovery trace ambiguous
        # about which request a rewind/migrate names
        shared_rid = itertools.count()
        for rid, mesh in enumerate(meshes):
            eng = build_engine(mesh, rid)
            eng._rid = shared_rid
            self.replicas.append(Replica(rid, mesh, eng))
            self._g_up.labels(replica=str(rid)).set(1)
        # fleet-wide batch target for rescale planning: default the sum of
        # slot capacity (a serving fleet's "global batch" is its slots)
        self._tgb = (int(target_global_batch) if target_global_batch
                     else sum(r.engine.slots for r in self.replicas))
        self._ticks = 0
        #: fleet recovery trace — same tuple format as the engine logs
        self.resil_log: list = []
        #: requests terminated at fleet level (rewind exhausted retries)
        self._fleet_done: list = []
        #: the survivor-mesh plans, one per rescale, newest last
        self.rescales: list[RescalePlan] = []

    # -- liveness ---------------------------------------------------------

    @property
    def live(self) -> list[Replica]:
        return [r for r in self.replicas if r.alive]

    def _event(self, name: str, **args) -> None:
        self.resil_log.append((self._ticks, name,
                               tuple(sorted(args.items()))))
        self._tracer.event(name, track="fleet", tick=self._ticks, **args)

    # -- routing ----------------------------------------------------------

    def _route(self) -> Replica:
        """Least-loaded live replica, ties to the lowest rid (deterministic
        routing is part of the same-seed recovery-trace contract).

        ``route_by="slots"`` (default) counts requests: queued + in-slot.
        ``route_by="backlog"`` counts admission work instead — queued
        payload units plus the un-ingested remainder of every mid-admission
        slot — so a replica grinding through one long chunked prompt stops
        looking as cheap as one serving short decodes."""
        live = self.live
        if not live:
            raise RuntimeError("no live replicas")

        def load(r: Replica) -> tuple:
            eng = r.engine
            busy = sum(1 for q in eng.slot_req if q is not None)
            if self.route_by == "backlog":
                wl = eng.workload
                units = sum(q.payload_units for q in eng.queue)
                units += sum(max(q.payload_units - 1 - q.cursor, 0)
                             for q in eng.slot_req
                             if q is not None and not wl.admit_complete(q))
                return (units + busy, r.rid)
            return (len(eng.queue) + busy, r.rid)

        return min(live, key=load)

    def submit(self, payload, budget=None, **kw):
        """Enqueue one request on the least-loaded live replica; returns
        the live Request (the engine's own submit surface)."""
        return self._route().engine.submit(payload, budget, **kw)

    # -- failure path -----------------------------------------------------

    def _finish_fleet(self, req, status: str, now: float) -> None:
        req.status = status
        req.done = True
        req.t_done = now
        self._fleet_done.append(req)

    def _rewind(self, req, now: float) -> None:
        """Tear one in-flight request out of a dead replica: the same full
        rewind the per-slot quarantine performs (the retry must be
        indistinguishable from a fresh admission), front-requeued onto a
        survivor behind capped backoff, or failed past the retry cap."""
        req.retries += 1
        if req.retries > self.policy.max_retries:
            self._finish_fleet(req, "failed", now)
            self._event("request_failed", rid=req.rid, retries=req.retries)
            return
        req.out.clear()
        req.cursor = 0
        req.admitted_units = 0
        req.t_first_emit = 0.0
        req.degree_at_first_emit = None
        backoff = self.policy.backoff_s(req.retries)
        req.eligible_at = now + backoff
        target = self._route()
        target.engine.queue.appendleft(req)
        self._event("rewind", rid=req.rid, retries=req.retries,
                    to_replica=target.rid,
                    backoff_ms=round(backoff * 1e3, 3))

    def _migrate_queue(self, victim: Replica) -> int:
        """Move a dead/draining replica's *queued* (never-admitted)
        requests to survivors, FIFO order preserved — no rewind needed,
        nothing was decoded yet."""
        moved = 0
        while victim.engine.queue:
            req = victim.engine.queue.popleft()
            target = self._route()
            target.engine.queue.append(req)
            moved += 1
            self._event("migrate", rid=req.rid, to_replica=target.rid)
        return moved

    def _rescale(self, reason: str) -> RescalePlan:
        """Replan the survivor mesh and charge the re-shard latency to the
        (injectable) clock."""
        survivors = len(self.live)
        plan = plan_rescale(max(survivors, 1) * self.tp,
                            target_global_batch=self._tgb, tp=self.tp)
        seconds = self.rescale_ms / 1e3
        advance = getattr(self._clock, "advance", None)
        if advance is not None:
            advance(seconds)
        else:
            time.sleep(seconds)
        self._h_rescale.observe(seconds)
        self.rescales.append(plan)
        self._event("rescale", reason=reason, replicas=survivors,
                    data=plan.data, model=plan.model,
                    idle=plan.idle_devices, ms=round(seconds * 1e3, 3))
        return plan

    def kill(self, rid: int, reason: str = "fault") -> Optional[RescalePlan]:
        """Hard replica loss: mark dead, migrate its queue, rewind its
        in-flight slots onto survivors, replan the mesh.  The last live
        replica is never killed (a fleet of zero serves nobody — the event
        is logged and skipped; availability beats fidelity to the fault)."""
        victim = self.replicas[rid]
        if not victim.alive:
            return None
        if len(self.live) == 1:
            self._event("replica_loss_skipped", replica=rid,
                        why="last_live_replica")
            return None
        victim.alive = False
        victim.died_at = self._ticks
        self._g_up.labels(replica=str(rid)).set(0)
        self._c_loss.inc()
        now = self._clock()
        self._event("replica_lost", replica=rid, reason=reason)
        moved = self._migrate_queue(victim)
        eng = victim.engine
        rewound = 0
        for s in range(eng.slots):
            req = eng.slot_req[s]
            if req is None:
                continue
            eng.slot_req[s] = None
            self._rewind(req, now)
            rewound += 1
        self._event("replica_drained", replica=rid, migrated=moved,
                    rewound=rewound)
        return self._rescale(f"replica_loss:{rid}")

    def decommission(self, rid: int, max_ticks: int = 1000
                     ) -> Optional[RescalePlan]:
        """Graceful retirement: stop routing to the replica (migrate its
        queue), let its decodable in-flight slots drain in place, then
        mark it dead and replan — zero rewinds, zero retries."""
        victim = self.replicas[rid]
        if not victim.alive or len(self.live) == 1:
            return None
        self._event("decommission", replica=rid)
        self._migrate_queue(victim)
        ticks = 0
        while any(r is not None for r in victim.engine.slot_req) \
                and ticks < max_ticks:
            victim.engine.tick()
            self._migrate_queue(victim)   # quarantine requeues drain too
            ticks += 1
        victim.alive = False
        victim.died_at = self._ticks
        self._g_up.labels(replica=str(rid)).set(0)
        self._event("replica_drained", replica=rid, migrated=0, rewound=0)
        return self._rescale(f"decommission:{rid}")

    # -- the fleet loop ---------------------------------------------------

    def _apply_faults(self) -> None:
        for ev in self.faults.events_at(self._ticks):
            if ev.kind != "replica_loss":
                continue   # engine-level kinds belong to engine-level plans
            self.faults.record(ev)
            self.kill(ev.slot % len(self.replicas), reason="injected")

    def tick(self) -> int:
        """One fleet iteration: apply scheduled replica losses, then tick
        every live engine.  Returns total active slots fleet-wide."""
        if self.faults is not None:
            self._apply_faults()
        active = 0
        for r in self.live:
            active += r.engine.tick()
        self._ticks += 1
        return active

    def run_until_drained(self, max_ticks: int = 10_000) -> list:
        """Tick until every live queue and slot is empty (or the budget
        runs out); returns the fleet-wide done list."""
        ticks = 0
        while any(r.engine.queue or
                  any(q is not None for q in r.engine.slot_req)
                  for r in self.live) and ticks < max_ticks:
            self.tick()
            ticks += 1
        for r in self.live:
            if getattr(r.engine, "emitter", None) is not None:
                r.engine.emitter.flush()
        return self.done

    # -- accounting -------------------------------------------------------

    @property
    def done(self) -> list:
        """Every terminated request fleet-wide — dead replicas' histories
        included (their finished work still happened), plus requests the
        fleet itself failed out of the rewind path.  Exactly one entry per
        submitted request, whatever its fate."""
        out = []
        for r in self.replicas:
            out.extend(r.engine.done)
        out.extend(self._fleet_done)
        return out

    def status_counts(self) -> dict:
        """Fleet-wide ``{ok,failed,shed,deadline}`` tally."""
        counts: dict = {}
        for req in self.done:
            counts[req.status] = counts.get(req.status, 0) + 1
        return counts
