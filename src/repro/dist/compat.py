"""jax cross-version shims.

The container pins a jax release where ``shard_map`` still lives in
``jax.experimental.shard_map`` and its replication checker is spelled
``check_rep``; newer releases expose ``jax.shard_map(..., check_vma=...)``.
Model code and the test suite use the modern spelling, so installing the
package aliases the experimental entry point onto ``jax`` and translates the
keyword.  On a jax that already ships ``jax.shard_map`` this is a no-op.
"""

from __future__ import annotations

import os

import jax


def _shard_map_compat(f=None, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, check_rep=None, **kwargs):
    from jax.experimental.shard_map import shard_map as _shard_map

    check = True
    if check_rep is not None:
        check = check_rep
    elif check_vma is not None:
        check = check_vma
    if f is None:  # used as a decorator factory
        def deco(fn):
            return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check, **kwargs)
        return deco
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, **kwargs)


def install() -> None:
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    # Forcing host-platform devices is by definition a CPU-mesh dry-run; if
    # the caller didn't pin a platform, pin CPU now.  Otherwise a container
    # with libtpu installed but no TPU attached stalls for minutes probing
    # the cloud metadata server before falling back.
    if ("xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
            and not os.environ.get("JAX_PLATFORMS")):
        os.environ["JAX_PLATFORMS"] = "cpu"
        try:
            jax.config.update("jax_platforms", "cpu")
        except Exception:
            pass
