"""repro.dist — the distribution layer (DESIGN.md §5, §14).

Six modules, mirroring the paper's approximation philosophy applied to the
interconnect instead of the multiplier datapath:

  meshctx       process-global mesh registry + activation-sharding helpers
  sharding      name-pattern partition rules for params / opt state / batches
  collectives   approximation-as-communication: quantized + error-feedback
                gradient compression and an int8 ring all-reduce
  hlo_analysis  trip-count-aware HLO text walker (dot FLOPs, collective bytes)
  elastic       surviving-device-count -> (pod, data, model) rescale planning
  fleet         replica fleet supervision for elastic sharded serving —
                routing, replica-loss recovery, rescale (docs/distributed_serving.md)

Importing this package also installs the jax version-compatibility shims
(``jax.shard_map`` on releases that only ship the experimental API) so model
code and tests can use the modern spelling uniformly.
"""

from repro.dist import compat as _compat

_compat.install()

from repro.dist import meshctx  # noqa: E402  (shims must install first)

__all__ = ["meshctx", "compat"]
