"""Name-pattern partition rules (DESIGN.md §5.2).

Megatron-style tensor parallelism over the ``"model"`` axis, resolved purely
from parameter *names* so every family (dense / MoE / hybrid / VLM / audio /
SSM) shares one rule table:

  column-parallel  (output dim sharded, no fwd collective): wq/wk/wv, up,
                   gate, in_proj, unembed, and any unrecognized dense ``w``
  row-parallel     (contracting dim sharded, output psum): wo, down, out_proj
  expert-parallel  (expert dim sharded): everything under ``experts/``
  vocab-parallel   embedding table (tied unembedding shards the logits)
  replicated       norms, biases, routers, convs, gates/decays and every
                   other small 1-D parameter

Leading dims beyond a rule's trailing pattern are layer-stacking dims from
``scan``-over-layers inits and stay unsharded — the rules return *trailing*
specs padded left with ``None`` to the leaf's rank.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.meshctx import batch_axes, get_mesh

# module names whose dense ``w`` contracts over the sharded dim (output
# reduction); mirrors kernels/ops.py _RING_PATHS
_ROW_MODULES = {"wo", "down", "out_proj"}
# leaf names that are themselves projection matrices (MoE shared experts
# store bare up/gate/down arrays without a dense sub-dict)
_COL_LEAVES = {"up", "gate"}
_ROW_LEAVES = {"down"}
# modules that stay replicated even though they hold a ``w``
_REPLICATED_MODULES = {"router", "conv"}


def spec_for_param(name: str, ndim: int) -> P:
    """PartitionSpec for a parameter with path ``name`` (/-joined) and rank
    ``ndim``.  Unknown names are replicated (safe default)."""
    parts = name.lower().split("/")
    leaf = parts[-1] if parts else name
    module = parts[-2] if len(parts) >= 2 else ""

    trailing: tuple = ()
    if module in _REPLICATED_MODULES or leaf in _REPLICATED_MODULES:
        trailing = ()
    elif "experts" in parts:
        trailing = ("model", None, None)          # (E, d, f) / (E, f, d)
    elif leaf == "emb":
        trailing = ("model", None)                # (vocab, d) vocab-parallel
    elif leaf == "w" and module in _ROW_MODULES or leaf in _ROW_LEAVES:
        trailing = ("model", None)                # (K_sharded, d)
    elif leaf == "w" or leaf in _COL_LEAVES:
        trailing = (None, "model")                # (d, N_sharded)
    if len(trailing) > ndim:
        trailing = ()
    return P(*([None] * (ndim - len(trailing)) + list(trailing)))


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def partition_params(params: Any, family: str = "") -> Any:
    """PartitionSpec tree matching ``params``.  ``family`` is accepted for
    future per-family overrides; the name rules currently cover all six."""
    del family

    def spec(path, leaf):
        name = "/".join(_key_str(k) for k in path)
        return spec_for_param(name, getattr(leaf, "ndim", len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec, params)


def partition_opt_state(opt: Any, pspecs: Any) -> Any:
    """AdamW state shards exactly like the parameters (mu/nu mirror the
    param tree; the step counter is replicated)."""
    from repro.optim.adamw import AdamWState

    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def partition_batch(batch: Any) -> Any:
    """Batch leaves shard dim 0 over the data axes, rest replicated."""
    b = batch_axes()
    bd = tuple(b) if b else None

    def spec(leaf):
        nd = getattr(leaf, "ndim", len(leaf.shape))
        return P(*([bd] + [None] * (nd - 1))) if nd else P()

    return jax.tree.map(spec, batch)


def partition_cache(cache: Any, family: str = "") -> Any:
    """Decode-cache specs: KV stacks shard heads over ``model`` and batch
    over the data axes; recurrent states shard batch (and SSM heads)."""
    del family
    b = batch_axes()
    bd = tuple(b) if b else None

    def spec(path, leaf):
        name = _key_str(path[-1]) if path else ""
        nd = getattr(leaf, "ndim", len(leaf.shape))
        if name == "length" or nd <= 1:
            return P(bd) if nd else P()
        if name in ("k", "v", "ks", "vs"):
            # (L, B, T, KVr[, D]) — heads at dim 3
            return P(*([None, bd, None, "model", None][:nd]))
        if name == "h" and nd == 5:
            return P(None, bd, "model", None, None)   # SSM (L, B, H, P, N)
        return P(*([None, bd] + [None] * (nd - 2)))   # (L, B, ...) states
    return jax.tree_util.tree_map_with_path(spec, cache)


def named(specs: Any, mesh=None) -> Any:
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    mesh = mesh or get_mesh()

    def to_named(s):
        if isinstance(s, NamedSharding):
            return s
        return NamedSharding(mesh, s)

    return jax.tree.map(to_named, specs,
                        is_leaf=lambda x: isinstance(x, (P, NamedSharding)))
