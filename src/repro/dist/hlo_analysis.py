"""Trip-count-aware HLO text analysis (DESIGN.md §5.4).

``compiled.cost_analysis()`` counts a ``while`` body **once**, so a model
that scans over L layers under-reports dot FLOPs by ~L x.  This walker
parses the compiled HLO text instead: it recursively evaluates each
computation (following fusion/call/while/conditional edges), multiplies
``while`` bodies by their trip count (XLA annotates
``backend_config={"known_trip_count":{"n":...}}``; a compare-against-constant
loop condition is the fallback), and reports

  dot_flops           2 * |output| * |contracted| per dot, trip-weighted
  dot_flops_by_dtype  the same split by accumulator dtype (int8/int32 MXU
                      paths run at 2x the bf16 rate — the roofline re-prices)
  collectives         bytes by kind (all-reduce, all-gather, reduce-scatter,
                      all-to-all, collective-permute), trip-weighted

Consumed by launch/dryrun.py (per-cell artifacts) and benchmarks/roofline.py
(compute / memory / collective terms).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1, "f8e3m4": 1,
    "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)


def shape_bytes(shape: str) -> int:
    """Bytes of an HLO shape string; tuples sum their elements.

    ``shape_bytes("f32[4,4]") == 64``; layout annotations (``{1,0}``) and
    nesting are ignored/flattened.
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveReport:
    bytes_by_kind: dict[str, float] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def add(self, kind: str, nbytes: float) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes

    def scaled(self, k: float) -> "CollectiveReport":
        return CollectiveReport(
            {kind: v * k for kind, v in self.bytes_by_kind.items()})

    def merged(self, other: "CollectiveReport") -> "CollectiveReport":
        out = CollectiveReport(dict(self.bytes_by_kind))
        for kind, v in other.bytes_by_kind.items():
            out.add(kind, v)
        return out

    def as_dict(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "by_kind": dict(self.bytes_by_kind)}


@dataclass
class HloReport:
    dot_flops: float = 0.0
    dot_flops_by_dtype: dict[str, float] = field(default_factory=dict)
    collectives: CollectiveReport = field(default_factory=CollectiveReport)
    while_trip_counts: dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "dot_flops_by_dtype": dict(self.dot_flops_by_dtype),
            "collectives": self.collectives.as_dict(),
            "while_trip_counts": dict(self.while_trip_counts),
        }


# --- parsing ----------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")
_CALLEE_RE = {
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_SHAPE_RE = re.compile(r"([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s*%")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: list[str] | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped):
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = []
                    comps[m.group(1)] = cur
        else:
            if stripped == "}":
                cur = None
            else:
                cur.append(stripped)
    return comps


def _parse_instruction(line: str) -> tuple[str, str, str, str] | None:
    """(name, shape, opcode, rest-of-line) for an instruction line, or None.

    The shape can be a tuple containing ``/*index=N*/`` comments (which hold
    ``=`` and defeat any non-greedy regex), so tuple shapes are scanned for
    their balancing close paren instead.
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, tail = rhs[: end + 1], rhs[end + 1:]
    else:
        cut = rhs.find(" ")
        if cut < 0:
            return None
        shape, tail = rhs[:cut], rhs[cut:]
    om = _OPCODE_RE.match(tail)
    if not om:
        return None
    opcode = om.group(1)
    rest = tail[om.end():]
    return name, shape, opcode, rest


def _dims(shape: str) -> list[int]:
    m = _SHAPE_RE.search(shape)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _dot_flops(shape: str, rest: str) -> tuple[float, str]:
    """(flops, accumulator dtype) for one dot instruction line."""
    out_dims = _dims(shape)
    flops = 2.0
    for d in out_dims:
        flops *= d
    cm = _CONTRACT_RE.search(rest)
    lhs_shape = None
    op_shapes = _OPERAND_SHAPE_RE.findall(rest)
    if op_shapes:
        lhs_shape = op_shapes[0]
    if cm is not None and lhs_shape is not None and cm.group(1):
        ldims = _dims(lhs_shape)
        for i in (int(x) for x in cm.group(1).split(",")):
            if i < len(ldims):
                flops *= ldims[i]
    dm = _SHAPE_RE.search(shape)
    dtype = dm.group(1) if dm else "f32"
    return flops, dtype


def _cond_trip_count(cond_lines: list[str]) -> int | None:
    """Fallback: compare(LT/LE) against a constant in the loop condition."""
    const = None
    direction = None
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            const = int(m.group(1))
        if " compare(" in line:
            dm = re.search(r"direction=(\w+)", line)
            direction = dm.group(1) if dm else None
    if const is None:
        return None
    if direction == "LE":
        return const + 1
    return const


def analyze_hlo(text: str) -> HloReport:
    """Walk HLO text; returns trip-count-weighted FLOP/collective totals."""
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
    report = HloReport()
    memo: dict[str, tuple[float, dict, CollectiveReport]] = {}

    def eval_comp(name: str) -> tuple[float, dict, CollectiveReport]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, {}, CollectiveReport())  # cycle guard
        flops = 0.0
        by_dtype: dict[str, float] = {}
        coll = CollectiveReport()
        for line in comps.get(name, ()):
            parsed = _parse_instruction(line)
            if parsed is None:
                continue
            iname, shape, opcode, rest = parsed
            if opcode == "dot":
                f, dt = _dot_flops(shape, rest)
                flops += f
                by_dtype[dt] = by_dtype.get(dt, 0.0) + f
            elif opcode.endswith("-done"):
                continue  # async pair: counted at -start
            elif opcode in _COLLECTIVE_KINDS or (
                    opcode.endswith("-start")
                    and opcode[:-6] in _COLLECTIVE_KINDS):
                if opcode.endswith("-start"):
                    # async spelling returns (operand, result, ctx...) — count
                    # only the payload (largest element), matching the bytes
                    # the sync spelling of the same op would report
                    kind = opcode[:-6]
                    sizes = [shape_bytes(f"{dt}[{dims}]")
                             for dt, dims in _SHAPE_RE.findall(shape)]
                    nbytes = max(sizes, default=0)
                else:
                    kind = opcode
                    nbytes = shape_bytes(shape)
                coll.add(kind, float(nbytes))
            elif opcode == "while":
                body = _CALLEE_RE["body"].search(rest)
                cond = _CALLEE_RE["condition"].search(rest)
                trip = None
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = int(tm.group(1))
                elif cond and cond.group(1) in comps:
                    trip = _cond_trip_count(comps[cond.group(1)])
                trip = trip if trip and trip > 0 else 1
                report.while_trip_counts[iname] = trip
                for callee, mult in ((body, trip), (cond, trip)):
                    if callee and callee.group(1) in comps:
                        cf, cd, cc = eval_comp(callee.group(1))
                        flops += cf * mult
                        for dt, v in cd.items():
                            by_dtype[dt] = by_dtype.get(dt, 0.0) + v * mult
                        coll = coll.merged(cc.scaled(mult))
            elif opcode == "conditional":
                bm = _CALLEE_RE["branches"].search(rest)
                names = []
                if bm:
                    names = [b.strip().lstrip("%")
                             for b in bm.group(1).split(",") if b.strip()]
                else:  # true/false computation spelling
                    names = re.findall(
                        r"(?:true|false)_computation=%?([\w.\-]+)", rest)
                # worst-case branch (upper bound, matches roofline use)
                best: tuple[float, dict, CollectiveReport] | None = None
                for bn in names:
                    if bn in comps:
                        cand = eval_comp(bn)
                        if best is None or cand[0] + cand[2].total_bytes > \
                                best[0] + best[2].total_bytes:
                            best = cand
                if best:
                    flops += best[0]
                    for dt, v in best[1].items():
                        by_dtype[dt] = by_dtype.get(dt, 0.0) + v
                    coll = coll.merged(best[2])
            else:
                for key in ("calls", "to_apply"):
                    cm = _CALLEE_RE[key].search(rest)
                    if cm and cm.group(1) in comps:
                        cf, cd, cc = eval_comp(cm.group(1))
                        flops += cf
                        for dt, v in cd.items():
                            by_dtype[dt] = by_dtype.get(dt, 0.0) + v
                        coll = coll.merged(cc)
        memo[name] = (flops, by_dtype, coll)
        return memo[name]

    if entry is not None:
        f, d, c = eval_comp(entry)
        report.dot_flops = f
        report.dot_flops_by_dtype = d
        report.collectives = c
    return report
