"""Approximation-compressed collectives (DESIGN.md §5.3).

The dissertation trades arithmetic exactness for energy/area with a runtime
degree; the same trade applied to the interconnect is *precision-scaled
communication*: gradients and tensor-parallel partial sums move as int8 (or
narrower) on the wire, with error feedback keeping optimization unbiased.

Two deployment paths:

  pjit path    ``compress_tree_for_allreduce`` / ``dp_allreduce_compressed``
               quantize-dequantize gradients *before* GSPMD inserts the data-
               parallel all-reduce — numerically the compressed collective,
               expressible without shard_map (train/step.py hook).
  shard_map    ``ring_allreduce_int8_local`` — an explicit ring all-reduce
               whose reduce-scatter and all-gather phases move int8 chunks +
               one f32 scale through ``ppermute``; wire bytes drop ~4x vs an
               f32 ``psum`` and the reduction error stays <2% because every
               hop re-quantizes against the *current partial's* range.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------


def quantize_dequantize(x: Array, bits: int = 8) -> Array:
    """Symmetric per-tensor fake-quantization to ``bits`` (round-to-nearest).

    Max error is bounded by ``amax / qmax / 2`` — half an LSB of the grid the
    wire format would carry.
    """
    qmax = float((1 << (bits - 1)) - 1)
    x32 = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-30)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def ef_compress(g: Array, err: Array, bits: int = 8) -> tuple[Array, Array]:
    """Error-feedback compression (1-bit-Adam lineage): transmit the
    quantized (gradient + carried residual), carry the new residual.

    Telescoping guarantee: ``sum(sent) + err_final == sum(g_true)`` exactly,
    so the residual stays bounded by one quantization step instead of
    accumulating — the property ``tests/test_collectives.py`` pins.
    """
    acc = g.astype(jnp.float32) + err.astype(jnp.float32)
    sent = quantize_dequantize(acc, bits)
    return sent, acc - sent


def dp_allreduce_compressed(x: Array, bits: int = 8) -> Array:
    """Data-parallel all-reduce with int-``bits`` wire emulation (pjit path).

    Under GSPMD the actual all-reduce is inserted by the partitioner; this
    hook quantize-dequantizes the local contribution so the values crossing
    the wire are exactly the int grid — on a single device it is the
    identity up to one quantization step.
    """
    return quantize_dequantize(x, bits)


def compress_tree_for_allreduce(grads, bits: int = 8):
    """Apply ``dp_allreduce_compressed`` to every matrix-shaped gradient.

    1-D leaves (norm scales, biases, gates) are a negligible fraction of the
    wire bytes and have the widest dynamic range — they pass through exact.
    """
    return jax.tree.map(
        lambda g: dp_allreduce_compressed(g, bits) if g.ndim >= 2 else g,
        grads)


# ---------------------------------------------------------------------------
# int8 ring all-reduce (shard_map path)
# ---------------------------------------------------------------------------


def _q8_chunk(x: Array) -> tuple[Array, Array]:
    """Per-chunk symmetric int8 quantization; returns (q int8, scale (1,))."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    scale = (amax / 127.0).reshape(1)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _deq(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8_local(x: Array, axis_name: str) -> Array:
    """Ring all-reduce of ``x`` over ``axis_name`` with int8 wire format.

    Must be called *inside* a shard_map region; ``x`` is the per-device
    shard.  Classic two-phase ring, unrolled (mesh axes are small and static)
    so the HLO byte count is directly visible to hlo_analysis:

      reduce-scatter   n-1 hops; each hop re-quantizes the running partial
                       against its own range before sending, so quantization
                       error grows ~sqrt(hops), not linearly;
      all-gather       n-1 hops forwarding each owner's fully-reduced chunk,
                       quantized exactly once.

    Wire cost per device: ``2 (n-1) (|chunk| + 4)`` bytes vs ``~2 |x| * 4``
    for an f32 psum ring — a ~4x reduction measured from the compiled HLO.
    """
    n = jax.lax.psum(1, axis_name)
    if n == 1:
        return x
    dt = x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    size = flat.shape[0]
    chunk = -(-size // n)
    pad = chunk * n - size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    chunks = flat.reshape(n, chunk)
    me = jax.lax.axis_index(axis_name)
    fwd = [(j, (j + 1) % n) for j in range(n)]

    def local(i):
        return jax.lax.dynamic_index_in_dim(chunks, i % n, axis=0,
                                            keepdims=False)

    # -- reduce-scatter: after n-1 hops, device i owns chunk (i+1) % n ------
    part = local(me)
    for s in range(n - 1):
        q, scale = _q8_chunk(part)
        q = jax.lax.ppermute(q, axis_name, fwd)
        scale = jax.lax.ppermute(scale, axis_name, fwd)
        part = _deq(q, scale) + local(me - s - 1)
    own = (me + 1) % n

    # -- all-gather: forward each owner's chunk around the ring -------------
    # (the own slot is left zero here and filled with the exact f32 partial
    # at the end — only forwarded chunks pay a quantization round-trip)
    out_q = jnp.zeros((n, chunk), jnp.int8)
    out_s = jnp.zeros((n, 1), jnp.float32)
    cq, cs = _q8_chunk(part)
    for s in range(n - 1):
        cq = jax.lax.ppermute(cq, axis_name, fwd)
        cs = jax.lax.ppermute(cs, axis_name, fwd)
        idx = (me - s) % n  # chunk id carried by this hop's payload
        out_q = jax.lax.dynamic_update_index_in_dim(out_q, cq, idx, axis=0)
        out_s = jax.lax.dynamic_update_index_in_dim(out_s, cs[None], idx,
                                                    axis=0)
    out = out_q.astype(jnp.float32) * out_s
    # own chunk needs no round-trip: keep the f32 partial exactly
    out = jax.lax.dynamic_update_index_in_dim(out, part, own, axis=0)
    return out.reshape(-1)[:size].reshape(x.shape).astype(dt)
