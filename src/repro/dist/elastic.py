"""Elastic rescale planning (DESIGN.md §5.5).

When preemption or hardware failure shrinks the device pool, the trainer
restarts from a mesh-agnostic checkpoint onto whatever survives.  This
module maps a surviving device count to a coherent (pod, data, model) mesh
and a gradient-accumulation factor that preserves the *effective* global
batch, so the optimization trajectory (LR schedule, batch statistics) is
unchanged up to accumulation order:

  * tensor parallelism is kept at the requested ``tp`` while it fits, and
    degraded by powers of two when fewer devices than ``tp`` survive;
  * the per-data-replica microbatch is held at its full-pod value
    (``target_global_batch / (devices_per_pod / tp)``), so activation
    memory per device never grows on the shrunken mesh;
  * lost data parallelism is bought back with ``grad_accum`` microsteps;
  * ragged survivor counts (7 of 8 devices, a part-dead pod) never crash
    the recovery path: the data axis degrades to the largest power-of-two
    subset that factors, and the devices left over are reported as
    ``idle_devices`` — the fleet supervisor / trainer parks them as warm
    spares instead of aborting the rescale.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RescalePlan:
    n_devices: int
    pods: int
    data: int              # data-parallel degree per pod
    model: int             # tensor-parallel degree
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    per_step_batch: int    # sequences per optimizer microstep (all pods)
    grad_accum: int
    effective_batch: int   # per_step_batch * grad_accum (>= target)
    idle_devices: int = 0  # survivors the mesh cannot use (ragged counts)


def plan_rescale(devices: int, *, target_global_batch: int, tp: int,
                 devices_per_pod: int = 256) -> RescalePlan:
    """Plan the mesh + accumulation for ``devices`` surviving chips."""
    if devices <= 0:
        raise ValueError("no surviving devices")
    pods = max(devices // devices_per_pod, 1)
    per_pod = devices // pods

    model = tp
    while model > 1 and model > per_pod:
        model //= 2
    if per_pod % model == 0:
        # exact factorization: use every survivor (full data parallelism)
        data = per_pod // model
    else:
        # ragged count: largest power-of-two data axis that fits, surplus
        # devices idle — recovery must never crash on an awkward survivor
        # count (7 of 8), and power-of-two replica groups keep collective
        # rings / replica routing uniform
        data = 1
        while data * 2 * model <= per_pod:
            data *= 2
    used = pods * data * model
    idle = devices - used

    if pods > 1:
        mesh_shape: tuple[int, ...] = (pods, data, model)
        mesh_axes: tuple[str, ...] = ("pod", "data", "model")
    else:
        mesh_shape = (data, model)
        mesh_axes = ("data", "model")

    # full-pod reference microbatch per data replica (never grow activations)
    data_full = max(devices_per_pod // tp, 1)
    replica_batch = max(target_global_batch // data_full, 1)
    per_step = replica_batch * data * pods
    grad_accum = max(-(-target_global_batch // per_step), 1)
    return RescalePlan(
        n_devices=devices,
        pods=pods,
        data=data,
        model=model,
        mesh_shape=mesh_shape,
        mesh_axes=mesh_axes,
        per_step_batch=per_step,
        grad_accum=grad_accum,
        effective_batch=per_step * grad_accum,
        idle_devices=idle,
    )
