"""Approximation policy: which technique, at what degree, on which layer.

This is the framework's first-class integration of the paper's methodology
(Ch. 7 + MAx-DNN fine-grained approximation): every matmul in the model zoo is
executed through ``approx_matmul(x, w, spec)`` and an ``ApproxPolicy`` maps
parameter paths (regex) to per-layer ``ApproxSpec`` — heterogeneous
approximation across the network, exactly the knob the paper explores
(Fig. 7.10-7.12: per-layer approximation of ResNet-8).

Modes
-----
EXACT       plain dot in the configured dtype (baseline).
AXQ         TPU-native deployment path: block-quantized int8 GEMM with a
            runtime effective-bits degree (kernels/axqmm Pallas kernel) — the
            DyFXU analogue (perforation == dropped low bits, see DESIGN.md §2).
PR_EMUL     bit-exact AxFXU emulation on int8/int16-quantized operands
            (software-exploration stage of the Ch. 7 methodology).
RAD_EMUL    bit-exact RAD(k) emulation on quantized operands.
ROUP_EMUL   bit-exact ROUP(k,p,r) emulation on quantized operands.
POW2_W      weights snapped to powers of two (RAD's shift-only insight).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from enum import Enum
from typing import Sequence


class ApproxMode(str, Enum):
    EXACT = "exact"
    AXQ = "axq"
    PR_EMUL = "pr_emul"
    RAD_EMUL = "rad_emul"
    ROUP_EMUL = "roup_emul"
    POW2_W = "pow2_w"


@dataclass(frozen=True)
class ApproxSpec:
    mode: ApproxMode = ApproxMode.EXACT
    # PR / ROUP degrees (perforation rows, rounding bit)
    p: int = 0
    r: int = 0
    # hybrid high-radix k (RAD / ROUP)
    k: int = 8
    # emulation quantization lane width (bits) for *_EMUL modes
    lane_bits: int = 8
    # AXQ: effective operand bits (<= 8); 8 == plain int8
    ebits: int = 8
    # AXQ: quantization block size along the contraction dim
    block: int = 256
    # runtime-configurable degree (DyFXU): degree passed as traced scalar
    dynamic: bool = False

    def describe(self) -> str:
        if self.mode == ApproxMode.EXACT:
            return "exact"
        if self.mode == ApproxMode.AXQ:
            d = "dyn" if self.dynamic else "static"
            return f"axq(e{self.ebits},b{self.block},{d})"
        if self.mode == ApproxMode.PR_EMUL:
            return f"pr(p{self.p},r{self.r},n{self.lane_bits})"
        if self.mode == ApproxMode.RAD_EMUL:
            return f"rad(k{self.k},n{self.lane_bits})"
        if self.mode == ApproxMode.ROUP_EMUL:
            return f"roup(k{self.k},p{self.p},r{self.r},n{self.lane_bits})"
        return "pow2_w"


EXACT = ApproxSpec()


@dataclass
class ApproxPolicy:
    """Ordered (pattern -> spec) rules; first match wins; default EXACT.

    Example (the MAx-DNN experiment shape):
        ApproxPolicy([
            (r".*layers_[0-3]/.*", ApproxSpec(mode=ApproxMode.EXACT)),       # early layers exact
            (r".*mlp.*",           ApproxSpec(mode=ApproxMode.AXQ, ebits=6)),
            (r".*attn.*",          ApproxSpec(mode=ApproxMode.AXQ, ebits=8)),
        ])
    """

    rules: Sequence[tuple[str, ApproxSpec]] = field(default_factory=list)
    default: ApproxSpec = EXACT

    def spec_for(self, path: str) -> ApproxSpec:
        for pattern, spec in self.rules:
            if re.fullmatch(pattern, path) or re.search(pattern, path):
                return spec
        return self.default

    def with_degree(self, **kw) -> "ApproxPolicy":
        """Return a policy with every non-exact rule's degree fields replaced
        (used by the QoS controller to move the global degree)."""
        new_rules = [
            (pat, replace(spec, **kw) if spec.mode != ApproxMode.EXACT else spec)
            for pat, spec in self.rules
        ]
        new_default = (
            replace(self.default, **kw) if self.default.mode != ApproxMode.EXACT else self.default
        )
        return ApproxPolicy(new_rules, new_default)


def uniform(spec: ApproxSpec) -> ApproxPolicy:
    return ApproxPolicy(rules=[], default=spec)


def policy_from_flag(approx: str, dynamic: bool = False) -> ApproxPolicy:
    """One parser for the launchers' ``--approx`` flag: ``exact`` or ``axqN``
    (N in 1..8) -> a uniform policy.  Shared by launch.train and launch.serve
    so a model trained at a degree serves at the same spec (same block)."""
    if approx == "exact":
        return ApproxPolicy()
    m = re.fullmatch(r"axq([1-8])", approx)
    if not m:
        raise ValueError(
            f"--approx must be 'exact' or axqN with N in 1..8, got {approx!r}")
    return uniform(ApproxSpec(mode=ApproxMode.AXQ, ebits=int(m.group(1)),
                              dynamic=dynamic))
