"""Bit-level operand encodings from the dissertation (Ch. 3-6).

Everything here is a *bit-exact emulation* of the paper's encoders, vectorized
over JAX integer arrays so it can run (a) standalone for error analysis and
(b) inside model graphs (approximate conv / matmul emulation paths).

Conventions
-----------
* An "n-bit operand" is a signed integer in [-2^(n-1), 2^(n-1)-1], stored in an
  int32 lane (n <= 16 keeps every intermediate product representable in int32;
  wider studies use the numpy/int64 helpers in ``error_analysis``).
* Bit extraction is performed on the unsigned n-bit view ``u = x & (2^n - 1)``.
* Modified-Booth (radix-4) digits follow Table 4.1:
      y_j = -2*b_{2j+1} + b_{2j} + b_{2j-1},   b_{-1} = 0.
* The hybrid high-radix digit follows Eq. (4.3) and its approximation Table 4.2.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# Bit helpers
# ---------------------------------------------------------------------------


def _mask(n: int) -> int:
    return (1 << n) - 1


def unsigned_view(x: Array, n: int) -> Array:
    """Unsigned n-bit view of a signed operand (two's complement)."""
    return jnp.bitwise_and(x.astype(jnp.int32), _mask(n))


def bit(x: Array, i: int, n: int) -> Array:
    """i-th bit of the two's-complement n-bit representation of x."""
    u = unsigned_view(x, n)
    return jnp.bitwise_and(jnp.right_shift(u, i), 1)


def to_signed(u: Array, n: int) -> Array:
    """Interpret an unsigned n-bit value as two's complement."""
    u = jnp.bitwise_and(u.astype(jnp.int32), _mask(n))
    return jnp.where(u >= (1 << (n - 1)), u - (1 << n), u)


# ---------------------------------------------------------------------------
# Radix-4 (Modified Booth) encoding  — Table 4.1 / Eq. (3.3)-(3.5)
# ---------------------------------------------------------------------------


def booth_digits(b: Array, n: int) -> Array:
    """Radix-4 Modified-Booth digits of an n-bit operand.

    Returns an int32 array of shape ``b.shape + (n // 2,)`` with digit j at
    index j (LSB digit first); each digit is in {0, +-1, +-2} and
    ``sum_j 4^j y_j == b`` exactly (verified by tests, property of the MB
    recoding of two's-complement numbers).
    """
    assert n % 2 == 0, "Modified Booth needs an even bit-width"
    digits = []
    for j in range(n // 2):
        b_hi = bit(b, 2 * j + 1, n)
        b_mid = bit(b, 2 * j, n)
        b_lo = bit(b, 2 * j - 1, n) if j > 0 else jnp.zeros_like(b, jnp.int32)
        digits.append(-2 * b_hi + b_mid + b_lo)
    return jnp.stack(digits, axis=-1).astype(jnp.int32)


def recombine_radix4(digits: Array) -> Array:
    """Inverse of :func:`booth_digits`: sum_j 4^j y_j."""
    m = digits.shape[-1]
    weights = jnp.array([4**j for j in range(m)], dtype=jnp.int32)
    return jnp.sum(digits * weights, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Partial-product perforation — Ch. 5 (AxFXU / DyFXU), Fig. 5.1
# ---------------------------------------------------------------------------


def perforate_operand(b: Array, n: int, p: int) -> Array:
    """Value of B after perforating the ``p`` least-significant radix-4
    partial products: B' = sum_{j >= p} 4^j y_j.

    p = 0 is exact.  Equivalent closed form (used by the Pallas kernel):
    B' = B - (B mod 2^{2p}) + 2^{2p} * b_{2p-1}.
    """
    if p == 0:
        return b.astype(jnp.int32)
    assert 0 < p <= n // 2
    low = jnp.bitwise_and(unsigned_view(b, n), _mask(2 * p))
    carry = bit(b, 2 * p - 1, n) * (1 << (2 * p))
    return (b.astype(jnp.int32) - low + carry).astype(jnp.int32)


def round_operand(a: Array, r: int) -> Array:
    """Round the multiplicand at bit ``r`` (partial-product rounding, Ch. 5):
    A_r = (floor(A / 2^r) + a_{r-1}) * 2^r   (round-half-away-from-zero-ish,
    implemented exactly as the hardware does: add the MSB of the dropped part).

    r = 0 is exact.
    """
    if r == 0:
        return a.astype(jnp.int32)
    a = a.astype(jnp.int32)
    rb = jnp.bitwise_and(jnp.right_shift(a, r - 1), 1)  # arithmetic shift: ok
    return jnp.left_shift(jnp.right_shift(a, r) + rb, r)


# ---------------------------------------------------------------------------
# Hybrid high-radix encoding — Ch. 4 (RAD), Eq. (4.1)-(4.3), Tables 4.1/4.2
# ---------------------------------------------------------------------------


def highradix_digit(b: Array, n: int, k: int) -> Array:
    """Accurate radix-2^k digit of the k LSBs (Eq. 4.3):
    y0 = -2^(k-1) b_{k-1} + sum_{i<k-1} 2^i b_i  in [-2^(k-1), 2^(k-1)-1]."""
    assert k % 2 == 0 and 4 <= k <= n - 2
    low = jnp.bitwise_and(unsigned_view(b, n), _mask(k))
    return to_signed(low, k)


def approx_highradix_digit(y0: Array, k: int) -> Array:
    """Approximate mapping of Table 4.2: snap y0 to the 4 largest powers of two
    (or 0), nearest-value intervals.  Doubling avoids the fractional 2^(k-5)
    threshold at k = 4.

        2|y0| in [0,       2^(k-4))       -> 0
        2|y0| in [2^(k-4), 3*2^(k-4))     -> 2^(k-4)
        2|y0| in [3*2^(k-4), 3*2^(k-3))   -> 2^(k-3)
        2|y0| in [3*2^(k-3), 3*2^(k-2))   -> 2^(k-2)
        2|y0| >= 3*2^(k-2)                -> 2^(k-1)
    """
    m2 = 2 * jnp.abs(y0)
    t = jnp.int32
    mag = jnp.where(
        m2 < (1 << (k - 4)),
        jnp.zeros_like(y0),
        jnp.where(
            m2 < 3 * (1 << (k - 4)),
            jnp.full_like(y0, 1 << (k - 4)),
            jnp.where(
                m2 < 3 * (1 << (k - 3)),
                jnp.full_like(y0, 1 << (k - 3)),
                jnp.where(
                    m2 < 3 * (1 << (k - 2)),
                    jnp.full_like(y0, 1 << (k - 2)),
                    jnp.full_like(y0, 1 << (k - 1)),
                ),
            ),
        ),
    )
    return (jnp.sign(y0) * mag).astype(t)


def rad_encode(b: Array, n: int, k: int) -> Array:
    """B-hat of the RAD multiplier: accurate radix-4 MSB part + approximate
    radix-2^k LSB digit.  The returned value satisfies the paper's key error
    property: rel_err(A x B-hat) = (B-hat - B)/B independent of A."""
    y0 = highradix_digit(b, n, k)
    y0_hat = approx_highradix_digit(y0, k)
    high = b.astype(jnp.int32) - y0  # == sum_{j>=k/2} 4^j y_j, exact
    return high + y0_hat


# ---------------------------------------------------------------------------
# DLSB (double least-significant bit) — Ch. 3
# ---------------------------------------------------------------------------


def dlsb_value(x: Array, xp: Array) -> Array:
    """Value of a DLSB number X+ = <x>_2's + x_0+  (Eq. 3.1)."""
    return x.astype(jnp.int32) + xp.astype(jnp.int32)


def dlsb_encode_sophisticated(a: Array, ap: Array, n: int) -> tuple[Array, Array]:
    """Sophisticated DLSB re-encoding (Eq. 3.9): A+ = (-1)^{a0+} * A' with
    a'_i = a_i XOR a0+.  Returns (A', a0+) so that the caller can fold the
    sign into the Booth digits (Eq. 3.11-3.13)."""
    u = unsigned_view(a, n)
    flip = jnp.where(ap.astype(jnp.int32) > 0, _mask(n), 0)
    a_prime = to_signed(jnp.bitwise_xor(u, flip), n)
    return a_prime, ap.astype(jnp.int32)


def mult_dlsb_straightforward(a: Array, ap: Array, b: Array, bp: Array, n: int) -> Array:
    """Straightforward DLSB multiplier (Eq. 3.6): conventional MB product of
    A x B+ plus the extra term a0+ * B+ (digit-level emulation)."""
    # B+ encoded with b_{-1} = b0+ in the least significant Booth digit.
    digits = booth_digits(b, n)
    d0 = digits[..., 0] + bp.astype(jnp.int32)  # b_{-1} := b0+
    b_plus = recombine_radix4(
        jnp.concatenate([d0[..., None], digits[..., 1:]], axis=-1)
    )
    return a.astype(jnp.int32) * b_plus + ap.astype(jnp.int32) * b_plus


def mult_dlsb_sophisticated(a: Array, ap: Array, b: Array, bp: Array, n: int) -> Array:
    """Sophisticated DLSB multiplier (Eq. 3.14): re-encode A+ as (-1)^{a0+}A',
    fold the sign into the Booth digits of B+ (s'_j = s_j xor a0+)."""
    a_prime, a0p = dlsb_encode_sophisticated(a, ap, n)
    digits = booth_digits(b, n)
    d0 = digits[..., 0] + bp.astype(jnp.int32)
    digits = jnp.concatenate([d0[..., None], digits[..., 1:]], axis=-1)
    sign = jnp.where(a0p > 0, -1, 1).astype(jnp.int32)
    signed_digits = digits * sign[..., None]
    return recombine_radix4(signed_digits) * a_prime


# ---------------------------------------------------------------------------
# Power-of-two snapping (RAD-inspired weight mode; DESIGN.md section 2.2)
# ---------------------------------------------------------------------------


def pow2_snap(x: Array) -> Array:
    """Snap every element to the nearest signed power of two (or 0).

    TPU-native use: weights snapped to +-2^i make the multiply a shift in an
    edge/VPU deployment; here it is a quality-evaluation mode."""
    ax = jnp.abs(x).astype(jnp.float32)
    e = jnp.round(jnp.log2(jnp.maximum(ax, 1e-30)))
    snapped = jnp.exp2(e)
    out = jnp.sign(x).astype(jnp.float32) * snapped
    return jnp.where(ax == 0, jnp.zeros_like(out), out)


# ---------------------------------------------------------------------------
# numpy mirrors (int64-exact, for wide-operand error studies; no jit)
# ---------------------------------------------------------------------------


def np_booth_digits(b: np.ndarray, n: int) -> np.ndarray:
    u = (b.astype(np.int64)) & _mask(n)
    ds = []
    for j in range(n // 2):
        hi = (u >> (2 * j + 1)) & 1
        mid = (u >> (2 * j)) & 1
        lo = ((u >> (2 * j - 1)) & 1) if j > 0 else np.zeros_like(u)
        ds.append(-2 * hi + mid + lo)
    return np.stack(ds, axis=-1)


def np_perforate_operand(b: np.ndarray, n: int, p: int) -> np.ndarray:
    if p == 0:
        return b.astype(np.int64)
    u = b.astype(np.int64) & _mask(n)
    low = u & _mask(2 * p)
    carry = ((u >> (2 * p - 1)) & 1) << (2 * p)
    return b.astype(np.int64) - low + carry


def np_round_operand(a: np.ndarray, r: int) -> np.ndarray:
    if r == 0:
        return a.astype(np.int64)
    a = a.astype(np.int64)
    rb = (a >> (r - 1)) & 1
    return ((a >> r) + rb) << r


def np_rad_encode(b: np.ndarray, n: int, k: int) -> np.ndarray:
    u = b.astype(np.int64) & _mask(n)
    low = u & _mask(k)
    y0 = np.where(low >= (1 << (k - 1)), low - (1 << k), low)
    m2 = 2 * np.abs(y0)
    mag = np.select(
        [
            m2 < (1 << (k - 4)),
            m2 < 3 * (1 << (k - 4)),
            m2 < 3 * (1 << (k - 3)),
            m2 < 3 * (1 << (k - 2)),
        ],
        [0, 1 << (k - 4), 1 << (k - 3), 1 << (k - 2)],
        default=1 << (k - 1),
    )
    y0_hat = np.sign(y0) * mag
    return b.astype(np.int64) - y0 + y0_hat
