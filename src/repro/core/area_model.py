"""Unit-gate area/energy proxy model — reimplements the dissertation's model
(Tables 3.2, 3.3, 4.4, 4.5) so every approximate configuration gets the same
area/energy ranking the paper uses for its Pareto fronts.

Unit-gate costs (Ch. 3, "unit gate model used in [240]"):
    AND-2 / OR-2 = 1,  NOT = 0.5,  XOR-2 = 2,  FA = 7,  HA = 3,
    MB encoder = 5.5,  DLSB MB encoder = 7.5,  MB PP generator = 5 per bit,
    AND PP generator = 1 per bit,  correction-term generator = 2,
    prefix propagate group = 3.

The model reproduces the paper's Table 3.3 overheads exactly
(DLSB2: 1.4 / 0.8 / 0.5 %, DLSB1: 11.8 / 6.7 / 3.7 % for n = 8/16/32) —
asserted in tests/test_area_model.py.

Energy proxy: the paper measures energy = power x delay at the synthesized
critical path.  Gate-level power tracks switched capacitance ~ gate count, and
tree depth tracks delay, so we expose  energy_proxy = area * log2(#pp rows),
documented as a *ranking* proxy (it reproduces the paper's orderings, not its
absolute nJ numbers).
"""

from __future__ import annotations

import math

AND = OR = 1.0
NOT = 0.5
XOR = 2.0
FA = 7.0
HA = 3.0
MB_ENC = 5.5
DLSB_MB_ENC = 7.5
MB_PPGEN_BIT = 5.0
AND_PPGEN_BIT = 1.0
CORR = 2.0
PG = 3.0


def _final_adder(n: int) -> float:
    """Fast prefix adder on the 2n-bit carry-save output (Ch. 3 model):
    2n HAs + n*log2(2n) propagate groups + 2n XORs."""
    return 2 * n * HA + n * math.log2(2 * n) * PG + 2 * n * XOR


def _tree(rows: int, width: int) -> float:
    """Carry-save accumulation of `rows` vectors of `width` bits: each FA row
    reduces 3 vectors to 2, so (rows - 2) * width FAs (Ch. 3: "n/2 + 1 vectors
    ... (n/2 - 1) x n full adders")."""
    return max(rows - 2, 0) * width * FA


def area_cmb(n: int) -> float:
    """Conventional Modified-Booth multiplier (exact baseline)."""
    rows = n // 2
    return (
        rows * MB_ENC
        + rows * (n + 1) * MB_PPGEN_BIT
        + rows * CORR
        + rows * NOT                      # inverted MSB per partial product
        + _tree(rows + 1, n)              # rows PPs + constants/corrections row
        + _final_adder(n)
    )


def area_dlsb1(n: int) -> float:
    """Straightforward DLSB multiplier: CMB + (n+1) AND + NOT + one extra
    accumulated row (Table 3.2: n/2 x n FAs instead of (n/2-1) x n)."""
    return area_cmb(n) + (n + 1) * AND_PPGEN_BIT + NOT + n * FA


def area_dlsb2(n: int) -> float:
    """Sophisticated DLSB multiplier: CMB with DLSB MB encoders (Table 3.2)."""
    return area_cmb(n) + (n // 2) * (DLSB_MB_ENC - MB_ENC)


def area_rad(n: int, k: int) -> float:
    """RAD hybrid high-radix multiplier (Ch. 4): (n-k)/2 radix-4 PPs plus one
    shift-only high-radix PP.  The approximate high-radix encoder costs about
    2x the radix-4 encoder (stated in Ch. 4); its PP is produced by a shifter
    modelled as AND-level muxing over the 5 possible shifts."""
    rows4 = (n - k) // 2
    enc_cost = rows4 * MB_ENC + 2 * MB_ENC
    ppgen = rows4 * (n + 1) * MB_PPGEN_BIT + (n + k) * 5 * AND_PPGEN_BIT
    corr = (rows4 + 1) * CORR + (rows4 + 1) * NOT
    return enc_cost + ppgen + corr + _tree(rows4 + 2, n) + _final_adder(n)


def area_pr(n: int, p: int, r: int) -> float:
    """Perforation+rounding multiplier (Ch. 5): p rows removed; each remaining
    PP is (n + 1 - r) bits wide; rounding adds one row of correction bits,
    folded into the constants row (no extra row)."""
    rows = n // 2 - p
    return (
        rows * MB_ENC
        + rows * (n + 1 - r) * MB_PPGEN_BIT
        + rows * CORR
        + rows * NOT
        + _tree(rows + 1, n - r)
        + _final_adder(n)
    )


def area_roup(n: int, k: int, p: int, r: int) -> float:
    """Cooperative ROUP multiplier (Ch. 6): RAD(k) with p radix-4 rows
    perforated and operand rounding at bit r."""
    rows4 = max((n - k) // 2 - p, 0)
    enc_cost = rows4 * MB_ENC + 2 * MB_ENC
    ppgen = rows4 * (n + 1 - r) * MB_PPGEN_BIT + (n + k - r) * 5 * AND_PPGEN_BIT
    corr = (rows4 + 1) * CORR + (rows4 + 1) * NOT
    return enc_cost + ppgen + corr + _tree(rows4 + 2, n - r) + _final_adder(n)


def rows_of(fam: str, n: int, k: int, p: int) -> int:
    if fam in ("RAD",):
        return (n - k) // 2 + 1
    if fam == "ROUP":
        return max((n - k) // 2 - p, 0) + 1
    return n // 2 - p


def area_of(fam: str, n: int, k: int = 0, p: int = 0, r: int = 0) -> float:
    if fam in ("PERF", "ROUND", "PR", "CMB"):
        return area_pr(n, p, r) if fam != "CMB" else area_cmb(n)
    if fam == "RAD":
        return area_rad(n, k)
    if fam == "ROUP":
        return area_roup(n, k, p, r)
    raise ValueError(fam)


def energy_proxy(fam: str, n: int, k: int = 0, p: int = 0, r: int = 0) -> float:
    """area x log2(rows+1): switched capacitance x tree-depth delay proxy."""
    rows = rows_of(fam, n, k, p) if fam != "CMB" else n // 2
    return area_of(fam, n, k, p, r) * math.log2(rows + 1)


def dlsb_overhead_table() -> dict[int, tuple[float, float]]:
    """Reproduces Table 3.3: % unit-gate overhead of DLSB1/DLSB2 vs CMB."""
    out = {}
    for n in (8, 16, 32):
        base = area_cmb(n)
        out[n] = (
            100.0 * (area_dlsb1(n) - base) / base,
            100.0 * (area_dlsb2(n) - base) / base,
        )
    return out
