"""The dissertation's approximate multiplier families, bit-exact in JAX.

Families (all return exact integer products of *transformed* operands, which
is precisely what the hardware computes — the approximation lives entirely in
the operand/partial-product transformation):

=========  =========================================  ==================
family     transformation                             paper
=========  =========================================  ==================
CMB        none (exact Modified-Booth)                Ch. 3 baseline
DLSB       exact product of DLSB numbers              Ch. 3
RAD(k)     B -> rad_encode(B, n, k)                   Ch. 4
PERF(p)    B -> perforate_operand(B, n, p)            Ch. 5 (perforation)
ROUND(r)   A -> round_operand(A, r)                   Ch. 5 (rounding)
PR(p,r)    both of the above (AxFXU / DyFXU)          Ch. 5
ROUP(k,    RAD(k) on B + rounding(r) on A +           Ch. 6 (cooperative)
  p,r)     perforation(p) of the radix-4 MSB part
AxFPU      PR applied to the significand product      Ch. 5 (floating pt)
=========  =========================================  ==================

Runtime-configurable variants (DyFXU/DyFPU) are the same functions with
``p``/``r`` passed as *traced* JAX scalars (see :func:`pr_multiply_dynamic`) —
the software analogue of the paper's runtime-configuration scheme: one circuit,
degree selected by register write, no recompilation.

Bit-width contract: operands are n-bit signed with n <= 16 so int32 lanes hold
every product (|A_r| <= 2^(n-1), |B-hat| < 2^n => |prod| < 2^(2n-1) <= 2^31).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import encodings as enc

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Fixed point
# ---------------------------------------------------------------------------


def mult_exact(a: Array, b: Array) -> Array:
    return a.astype(jnp.int32) * b.astype(jnp.int32)


def mult_rad(a: Array, b: Array, n: int, k: int) -> Array:
    """RAD_2^k approximate multiplier (Ch. 4): A x rad_encode(B)."""
    return a.astype(jnp.int32) * enc.rad_encode(b, n, k)


def mult_pr(a: Array, b: Array, n: int, p: int, r: int) -> Array:
    """Perforation(p)+Rounding(r) multiplier (AxFXU, Ch. 5)."""
    return enc.round_operand(a, r) * enc.perforate_operand(b, n, p)


def mult_roup(a: Array, b: Array, n: int, k: int, p: int, r: int) -> Array:
    """Cooperative ROUP multiplier (Ch. 6): hybrid high-radix encoding of B,
    perforation of the p least-significant *radix-4* digits of B's MSB part,
    and rounding of A at bit r.

    With k LSBs already absorbed by the high-radix digit, perforation applies
    to digits j in [k/2, k/2 + p).
    """
    b_hat = enc.rad_encode(b, n, k)
    if p > 0:
        # Perforate p radix-4 digits just above the high-radix digit: clear
        # the contribution of bits [k, k + 2p) of the radix-4 part.
        y0 = enc.highradix_digit(b, n, k)
        high = b.astype(jnp.int32) - y0                    # radix-4 part value
        hi_perf = enc.perforate_operand(high, 2 * n, k // 2 + p)  # drop j < k/2+p
        # hi has zeros below bit k-1 except the borrow structure; perforating
        # at k/2 alone is identity on it, so the net effect is digits
        # [k/2, k/2+p) dropped.
        b_hat = hi_perf + (b_hat - high)
    a_r = enc.round_operand(a, r)
    return a_r * b_hat


def mult_dlsb(a: Array, ap: Array, b: Array, bp: Array, n: int) -> Array:
    """Exact DLSB multiplier via the sophisticated encoding (Ch. 3)."""
    return enc.mult_dlsb_sophisticated(a, ap, b, bp, n)


# Runtime-configurable (DyFXU): p and r are traced int32 scalars. ------------


def perforate_dynamic(b: Array, n: int, p: Array) -> Array:
    """Perforation with traced degree p in [0, n/2]: mask-select over the
    closed form B' = B - (B mod 2^{2p}) + 2^{2p} b_{2p-1}.  Emulates the
    paper's runtime configuration mux (Fig. 5.3)."""
    b = b.astype(jnp.int32)
    u = jnp.bitwise_and(b, (1 << n) - 1)
    two_p = jnp.left_shift(jnp.int32(1), 2 * p.astype(jnp.int32))
    low = jnp.bitwise_and(u, two_p - 1)
    # b_{2p-1}: for p = 0 there is no carry bit; guard with where.
    shift = jnp.maximum(2 * p.astype(jnp.int32) - 1, 0)
    carry_bit = jnp.bitwise_and(jnp.right_shift(u, shift), 1)
    carry = jnp.where(p > 0, carry_bit * two_p, 0)
    return b - low + carry


def round_dynamic(a: Array, r: Array) -> Array:
    a = a.astype(jnp.int32)
    r = r.astype(jnp.int32)
    rb = jnp.where(r > 0, jnp.bitwise_and(jnp.right_shift(a, jnp.maximum(r - 1, 0)), 1), 0)
    rounded = jnp.left_shift(jnp.right_shift(a, r) + rb, r)
    return jnp.where(r > 0, rounded, a)


def pr_multiply_dynamic(a: Array, b: Array, n: int, p: Array, r: Array) -> Array:
    """DyFXU: PR multiplier whose degree (p, r) is a runtime value."""
    return round_dynamic(a, r) * perforate_dynamic(b, n, p)


# ---------------------------------------------------------------------------
# Floating point (AxFPU / DyFPU) — PR on the significand product
# ---------------------------------------------------------------------------

_FLOAT_FMTS = {
    # name: (jnp dtype, exponent bits, mantissa bits)
    "bf16": (jnp.bfloat16, 8, 7),
    "fp16": (jnp.float16, 5, 10),
    "fp32": (jnp.float32, 8, 23),
}


def _decompose(x: Array, fmt: str):
    dtype, ebits, mbits = _FLOAT_FMTS[fmt]
    width = 1 + ebits + mbits
    x = x.astype(dtype)
    if width == 16:
        raw = jax.lax.bitcast_convert_type(x, jnp.int16).astype(jnp.int32)
        raw = jnp.bitwise_and(raw, 0xFFFF)
    else:
        raw = jax.lax.bitcast_convert_type(x, jnp.int32)
    sign = jnp.bitwise_and(jnp.right_shift(raw, ebits + mbits), 1)
    exp = jnp.bitwise_and(jnp.right_shift(raw, mbits), (1 << ebits) - 1)
    man = jnp.bitwise_and(raw, (1 << mbits) - 1)
    return sign, exp, man, ebits, mbits, dtype


def axfpu_multiply(a: Array, b: Array, fmt: str = "bf16", p: int = 0, r: int = 0) -> Array:
    """AxFPU (Ch. 5): exact exponent addition, PR-approximate significand
    product, truncating renormalization.  Subnormals flush to zero (as the
    paper's hardware does for the approximate variants).

    Supported in-graph formats: bf16 (8-bit significand) and fp16 (11-bit) —
    products stay within int32.  fp32 studies use the numpy mirror
    :func:`np_axfpu_multiply` (int64 lanes).
    """
    if fmt == "fp32":
        raise ValueError("in-graph AxFPU supports bf16/fp16; use np_axfpu_multiply for fp32")
    sa, ea, ma, ebits, mbits, dtype = _decompose(a, fmt)
    sb, eb, mb, *_ = _decompose(b, fmt)
    bias = (1 << (ebits - 1)) - 1
    nsig = mbits + 1
    # significands (implicit leading one); flush subnormals/zero to zero.
    siga = jnp.where(ea > 0, ma + (1 << mbits), 0)
    sigb = jnp.where(eb > 0, mb + (1 << mbits), 0)
    # PR transform on an even lane width wide enough that the (unsigned)
    # significand is positive in the lane's two's-complement view.
    n_lane = 2 * ((nsig + 2) // 2)
    siga_t = enc.round_operand(siga, r)
    sigb_t = enc.perforate_operand(sigb, n_lane, p) if p > 0 else sigb
    prod = siga_t * sigb_t  # < 2^(2*nsig) <= 2^22 (fp16) — int32 safe
    # Renormalize: product of [2^m, 2^(m+1)) values is in [2^2m, 2^(2m+2)).
    top = jnp.right_shift(prod, 2 * mbits + 1)  # 1 if product >= 2^(2m+1)
    shift = mbits + top
    man_out = jnp.right_shift(prod, shift)  # truncating (hardware-faithful)
    man_out = jnp.bitwise_and(man_out, (1 << mbits) - 1)
    exp_out = ea + eb - bias + top
    sign_out = jnp.bitwise_xor(sa, sb)
    # underflow/overflow handling: flush / saturate to inf.
    max_exp = (1 << ebits) - 1
    zero = jnp.logical_or(prod == 0, exp_out <= 0)
    inf = exp_out >= max_exp
    exp_out = jnp.clip(exp_out, 0, max_exp)
    raw = (
        jnp.left_shift(sign_out, ebits + mbits)
        + jnp.left_shift(jnp.where(inf, max_exp, exp_out), mbits)
        + jnp.where(inf, 0, man_out)
    )
    raw = jnp.where(zero, jnp.left_shift(sign_out, ebits + mbits), raw)
    if 1 + ebits + mbits == 16:
        out = jax.lax.bitcast_convert_type(raw.astype(jnp.int16), dtype)
    else:
        out = jax.lax.bitcast_convert_type(raw.astype(jnp.int32), dtype)
    return out


# ---------------------------------------------------------------------------
# numpy mirrors (wide operands, exhaustive error studies)
# ---------------------------------------------------------------------------


def np_mult_rad(a: np.ndarray, b: np.ndarray, n: int, k: int) -> np.ndarray:
    return a.astype(np.int64) * enc.np_rad_encode(b, n, k)


def np_mult_pr(a: np.ndarray, b: np.ndarray, n: int, p: int, r: int) -> np.ndarray:
    return enc.np_round_operand(a, r) * enc.np_perforate_operand(b, n, p)


def np_mult_roup(a: np.ndarray, b: np.ndarray, n: int, k: int, p: int, r: int) -> np.ndarray:
    b_hat = enc.np_rad_encode(b, n, k)
    if p > 0:
        u = b.astype(np.int64) & ((1 << n) - 1)
        low = u & ((1 << k) - 1)
        y0 = np.where(low >= (1 << (k - 1)), low - (1 << k), low)
        high = b.astype(np.int64) - y0
        hi_perf = enc.np_perforate_operand(high, 2 * n, k // 2 + p)
        b_hat = hi_perf + (b_hat - high)
    return enc.np_round_operand(a, r) * b_hat


def np_axfpu_multiply(a: np.ndarray, b: np.ndarray, p: int = 0, r: int = 0) -> np.ndarray:
    """fp32 AxFPU in numpy int64 lanes (24-bit significands, 48-bit products)."""
    ra = a.astype(np.float32).view(np.int32).astype(np.int64)
    rb = b.astype(np.float32).view(np.int32).astype(np.int64)
    sa, ea, ma = (ra >> 31) & 1, (ra >> 23) & 0xFF, ra & 0x7FFFFF
    sb, eb, mb = (rb >> 31) & 1, (rb >> 23) & 0xFF, rb & 0x7FFFFF
    siga = np.where(ea > 0, ma + (1 << 23), 0)
    sigb = np.where(eb > 0, mb + (1 << 23), 0)
    siga_t = enc.np_round_operand(siga, r)
    sigb_t = enc.np_perforate_operand(sigb, 24, p) if p > 0 else sigb
    prod = siga_t * sigb_t
    top = (prod >> 47) & 1
    man_out = (prod >> (23 + top)) & 0x7FFFFF
    exp_out = ea + eb - 127 + top
    sign_out = sa ^ sb
    zero = (prod == 0) | (exp_out <= 0)
    inf = exp_out >= 255
    exp_out = np.clip(exp_out, 0, 255)
    raw = (sign_out << 31) + (np.where(inf, 255, exp_out) << 23) + np.where(inf, 0, man_out)
    raw = np.where(zero, sign_out << 31, raw)
    return (raw & 0xFFFFFFFF).astype(np.uint32).view(np.float32)


# ---------------------------------------------------------------------------
# Family registry (used by pareto exploration + benchmarks)
# ---------------------------------------------------------------------------


def family_configs(n: int = 16):
    """Enumerate the dissertation's approximation space for n-bit operands.

    Yields (name, callable(a, b) -> product, meta-dict).  Mirrors the Ch. 6
    pool: PERF, ROUND, PR, RAD, ROUP.
    """
    out = []
    for p in range(1, 5):
        out.append((f"PERF{p}", partial(np_mult_pr, n=n, p=p, r=0), dict(fam="PERF", p=p, r=0, k=0)))
    for r in range(2, 11, 2):
        out.append((f"ROUND{r}", partial(np_mult_pr, n=n, p=0, r=r), dict(fam="ROUND", p=0, r=r, k=0)))
    for p in range(1, 4):
        for r in range(2, 9, 2):
            out.append((f"PR{p}_{r}", partial(np_mult_pr, n=n, p=p, r=r), dict(fam="PR", p=p, r=r, k=0)))
    for k in range(4, min(n - 2, 12) + 1, 2):
        out.append((f"RAD{2**k}", partial(np_mult_rad, n=n, k=k), dict(fam="RAD", p=0, r=0, k=k)))
    for k in (4, 6, 8):
        for p in (0, 1, 2):
            for r in (0, 2, 4):
                if p == 0 and r == 0:
                    continue
                out.append(
                    (
                        f"ROUP{k}_{p}_{r}",
                        partial(np_mult_roup, n=n, k=k, p=p, r=r),
                        dict(fam="ROUP", p=p, r=r, k=k),
                    )
                )
    return out
