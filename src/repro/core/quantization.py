"""Effective-bits block quantization — the TPU-native embodiment of
perforation+rounding (DESIGN.md §2.1).

Perforating p low partial products of an n-bit operand keeps ~(n - 2p)
significant bits; rounding at bit r keeps (n - r).  On TPU the equivalent
resource knob is an int8 block-quantized GEMM whose operands can be further
degraded to e < 8 *effective bits* at runtime by round-and-mask (shift right,
round, shift left) — no recompilation, mirroring DyFXU's runtime registers.

Resource semantics on TPU v5e: s8 x s8 -> s32 runs at 2x bf16 MXU rate and
halves operand HBM traffic; each additional dropped effective bit does not
change MXU rate but models the paper's graceful accuracy degradation and maps
1:1 onto its error analysis (q_eff loses exactly the perforated low bits).

All functions are pure jnp (jit/vmap/pjit-safe); the Pallas kernel in
kernels/axqmm.py consumes the same representation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class QTensor(NamedTuple):
    """Block-quantized tensor: int8 values + per-block float scales.

    values: (..., K) int8; scales: (..., K // block) f32 broadcasting over the
    contraction dimension blocks.
    """

    values: Array
    scales: Array
    block: int

    @property
    def shape(self):
        return self.values.shape


def quantize_block(x: Array, block: int = 256, axis: int = -1) -> QTensor:
    """Symmetric int8 block quantization along `axis` (the contraction dim)."""
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    *lead, K = x.shape
    assert K % block == 0, f"contraction dim {K} not divisible by block {block}"
    xb = x.reshape(*lead, K // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return QTensor(q.reshape(*lead, K), scale[..., 0].astype(jnp.float32), block)


def degrade(q: Array, ebits) -> Array:
    """Drop to `ebits` effective bits by round-to-nearest at 2^(8-e):
    the runtime DyFXU knob.  `ebits` may be a traced scalar (dynamic mode).

    q int8 in [-127, 127]; result stays int8 (rounding may hit +-128: we
    saturate, matching a hardware clamp).
    """
    shift = (8 - jnp.asarray(ebits)).astype(jnp.int32)
    shift = jnp.maximum(shift, 0)
    q32 = q.astype(jnp.int32)
    half = jnp.where(shift > 0, jnp.left_shift(1, jnp.maximum(shift - 1, 0)), 0)
    down = jnp.right_shift(q32 + half, shift)
    out = jnp.left_shift(down, shift)
    out = jnp.clip(out, -127, 127)
    return jnp.where(shift > 0, out, q32).astype(jnp.int8)


def dequantize(qt: QTensor) -> Array:
    *lead, K = qt.values.shape
    v = qt.values.reshape(*lead, K // qt.block, qt.block).astype(jnp.float32)
    return (v * qt.scales[..., None]).reshape(*lead, K)


def qmm_packed_ref(x: Array, qw: Array, sw: Array, ebits=8,
                   out_dtype=jnp.float32) -> Array:
    """Reference block-quantized matmul against a *prepacked* K-major weight.

    x: (M, K) float; qw: (N, K) int8; sw: (N, K // block) f32 — the
    quantize-once residency form (kernels/qstore.py).  Only the activation is
    quantized in-trace; both operands are degraded to `ebits` and accumulated
    as per-block int32 dots scaled by the block scales.  This is the pure-jnp
    oracle for kernels/axqmm.py and the xla route of the GEMM dispatch.
    """
    M, K = x.shape
    N, K2 = qw.shape
    assert K == K2, (K, K2)
    nb = sw.shape[-1]
    block = K // nb
    qx = quantize_block(x, block)      # values (M,K), scales (M,nb)
    vx = degrade(qx.values, ebits).reshape(M, nb, block)
    vw = degrade(qw, ebits).reshape(N, nb, block)
    # per-block integer dot: (M, N, nb)
    acc = jnp.einsum(
        "mbk,nbk->mnb",
        vx.astype(jnp.int32),
        vw.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    scale = qx.scales[:, None, :] * sw[None, :, :]
    return jnp.sum(acc * scale, axis=-1).astype(out_dtype)


def qmm_ref(x: Array, w: Array, block: int = 256, ebits: int = 8,
            out_dtype=jnp.float32) -> Array:
    """Reference block-quantized matmul x @ w with effective-bits degradation.

    x: (M, K) float; w: (K, N) float.  Quantizes the weight on the fly (the
    same ``quantize_block`` the prepack pass runs once) and defers to
    :func:`qmm_packed_ref` — prepacked and on-the-fly execution share one
    graph from the quantized operands on, so their outputs are bit-identical.
    """
    K2 = w.shape[0]
    assert x.shape[-1] == K2
    qw = quantize_block(w.T, block)    # values (N,K), scales (N,nb)
    return qmm_packed_ref(x, qw.values, qw.scales, ebits, out_dtype)


def qmm_gated_packed_ref(x: Array, qw_up: Array, sw_up: Array, qw_gate: Array,
                         sw_gate: Array, act, ebits=8,
                         out_dtype=jnp.float32) -> Array:
    """Fused gated-MLP first half against prepacked weights:
    ``act(x @ w_gate) * (x @ w_up)`` with both GEMMs sharing the one in-trace
    activation quantization.  jnp oracle for axqmm_gated."""
    up = qmm_packed_ref(x, qw_up, sw_up, ebits)
    gate = qmm_packed_ref(x, qw_gate, sw_gate, ebits)
    return (act(gate) * up).astype(out_dtype)


def qmm_gated_ref(x: Array, w_up: Array, w_gate: Array, act, block: int = 256,
                  ebits: int = 8, out_dtype=jnp.float32) -> Array:
    """On-the-fly variant of :func:`qmm_gated_packed_ref` (three-call
    oracle's math, one function)."""
    qu = quantize_block(w_up.T, block)
    qg = quantize_block(w_gate.T, block)
    return qmm_gated_packed_ref(x, qu.values, qu.scales, qg.values, qg.scales,
                                act, ebits, out_dtype)


def pow2_weights(w: Array) -> Array:
    """RAD-inspired power-of-two weight snapping (quality-eval mode)."""
    from .encodings import pow2_snap

    return pow2_snap(w)
