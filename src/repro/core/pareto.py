"""Approximation-space exploration & Pareto-front extraction (Ch. 6).

The dissertation's "cooperative approximation" chapter enumerates combinations
of the technique pool, evaluates (error, resources) for each configuration,
and keeps the Pareto-optimal set.  This module is that loop, with the error
side computed bit-exactly (error_analysis) and the resource side from the
paper's own unit-gate model (area_model).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import area_model, axmult, error_analysis


@dataclass
class DesignPoint:
    name: str
    fam: str
    n: int
    k: int
    p: int
    r: int
    mred: float
    nmed: float
    area: float
    energy: float
    on_front: bool = False

    def row(self) -> str:
        star = "*" if self.on_front else " "
        return (
            f"{star} {self.name:<12} mred={self.mred:.6f} area={self.area:8.1f} "
            f"energy={self.energy:9.1f}"
        )


def explore(n: int = 16, num_samples: int = 1 << 16, seed: int = 0) -> list[DesignPoint]:
    """Evaluate the full configuration pool at bit-width n."""
    points: list[DesignPoint] = []
    # exact baseline
    base_area = area_model.area_cmb(n)
    points.append(
        DesignPoint("CMB", "CMB", n, 0, 0, 0, 0.0, 0.0, base_area,
                    area_model.energy_proxy("CMB", n))
    )
    for name, fn, meta in axmult.family_configs(n):
        rep = error_analysis.evaluate_sampled(fn, n, num=num_samples, seed=seed)
        fam, k, p, r = meta["fam"], meta["k"], meta["p"], meta["r"]
        points.append(
            DesignPoint(
                name, fam, n, k, p, r, rep.mred, rep.nmed,
                area_model.area_of(fam, n, k, p, r),
                area_model.energy_proxy(fam, n, k, p, r),
            )
        )
    mark_front(points, x="mred", y="energy")
    return points


def mark_front(points: list[DesignPoint], x: str = "mred", y: str = "energy") -> None:
    """Mark Pareto-optimal points (minimize both x and y) in place."""
    for pt in points:
        pt.on_front = True
        for other in points:
            if other is pt:
                continue
            ox, oy = getattr(other, x), getattr(other, y)
            px, py = getattr(pt, x), getattr(pt, y)
            if ox <= px and oy <= py and (ox < px or oy < py):
                pt.on_front = False
                break


def front(points: list[DesignPoint]) -> list[DesignPoint]:
    return sorted([p for p in points if p.on_front], key=lambda p: p.mred)


def best_under_error(points: list[DesignPoint], mred_budget: float) -> DesignPoint | None:
    """The paper's design-selection rule: max resource gain subject to an
    error constraint."""
    ok = [p for p in points if p.mred <= mred_budget]
    return min(ok, key=lambda p: p.energy) if ok else None
