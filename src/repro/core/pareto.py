"""Approximation-space exploration & Pareto-front extraction (Ch. 6).

The dissertation's "cooperative approximation" chapter enumerates combinations
of the technique pool, evaluates (error, resources) for each configuration,
and keeps the Pareto-optimal set.  This module is that loop, with the error
side computed bit-exactly (error_analysis) and the resource side from the
paper's own unit-gate model (area_model).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import area_model, axmult, error_analysis


@dataclass
class DesignPoint:
    name: str
    fam: str
    n: int
    k: int
    p: int
    r: int
    mred: float
    nmed: float
    area: float
    energy: float
    on_front: bool = False

    def row(self) -> str:
        star = "*" if self.on_front else " "
        return (
            f"{star} {self.name:<12} mred={self.mred:.6f} area={self.area:8.1f} "
            f"energy={self.energy:9.1f}"
        )


def explore(n: int = 16, num_samples: int = 1 << 16, seed: int = 0) -> list[DesignPoint]:
    """Evaluate the full multiplier-configuration pool at bit-width ``n``.

    Enumerates every family config from ``axmult.family_configs`` plus the
    exact CMB baseline, attaches sampled error metrics (MRED/NMED) and
    unit-gate area/energy, and marks the (mred, energy) Pareto front in
    place.  This is the Ch. 6 *circuit-level* exploration; the network-level
    counterpart over per-layer degree vectors lives in ``repro.tune``
    (which reuses :func:`front_mask` for the same dominance rule)."""
    points: list[DesignPoint] = []
    # exact baseline
    base_area = area_model.area_cmb(n)
    points.append(
        DesignPoint("CMB", "CMB", n, 0, 0, 0, 0.0, 0.0, base_area,
                    area_model.energy_proxy("CMB", n))
    )
    for name, fn, meta in axmult.family_configs(n):
        rep = error_analysis.evaluate_sampled(fn, n, num=num_samples, seed=seed)
        fam, k, p, r = meta["fam"], meta["k"], meta["p"], meta["r"]
        points.append(
            DesignPoint(
                name, fam, n, k, p, r, rep.mred, rep.nmed,
                area_model.area_of(fam, n, k, p, r),
                area_model.energy_proxy(fam, n, k, p, r),
            )
        )
    mark_front(points, x="mred", y="energy")
    return points


def front_mask(xs, ys) -> list[bool]:
    """Generic minimize-both Pareto mask over two parallel sequences.

    ``mask[i]`` is True iff no other point weakly dominates point ``i``
    (``x <= x_i and y <= y_i`` with at least one strict).  Duplicated points
    all stay on the front.  Shared by :func:`mark_front` (multiplier design
    points) and the ``repro.tune`` plan search (per-layer degree vectors) —
    one dominance rule for both exploration stages."""
    n = len(xs)
    assert len(ys) == n
    mask = []
    for i in range(n):
        dominated = any(
            xs[j] <= xs[i] and ys[j] <= ys[i]
            and (xs[j] < xs[i] or ys[j] < ys[i])
            for j in range(n) if j != i)
        mask.append(not dominated)
    return mask


def mark_front(points: list[DesignPoint], x: str = "mred", y: str = "energy") -> None:
    """Mark Pareto-optimal points (minimize both ``x`` and ``y`` attributes)
    in place by setting ``on_front`` — the presentation layer over
    :func:`front_mask`."""
    mask = front_mask([getattr(p, x) for p in points],
                      [getattr(p, y) for p in points])
    for pt, m in zip(points, mask):
        pt.on_front = m


def front(points: list[DesignPoint]) -> list[DesignPoint]:
    """The marked Pareto subset, sorted most-accurate (lowest mred) first —
    run :func:`mark_front` (or :func:`explore`) beforehand."""
    return sorted([p for p in points if p.on_front], key=lambda p: p.mred)


def best_under_error(points: list[DesignPoint], mred_budget: float) -> DesignPoint | None:
    """The paper's design-selection rule: the cheapest (minimum energy)
    configuration whose error stays within ``mred_budget``; None when no
    configuration qualifies."""
    ok = [p for p in points if p.mred <= mred_budget]
    return min(ok, key=lambda p: p.energy) if ok else None
