"""Error metrics & evaluation harness for approximate multipliers (Ch. 4-6).

Metrics follow the dissertation's definitions:

* RED  (relative error distance)     |P - P_hat| / |P|
* MRED (mean RED)                    mean over the operand distribution
* NMED (normalized mean error dist.) mean|P - P_hat| / max|P|
* PRED(t)                            Pr[RED <= t]   (paper reports PRED(2%))
* mean error (bias)                  mean (P_hat - P)  — the paper shows RAD's
                                     error distribution is near-zero-mean.

Evaluation styles:
* exhaustive over all operand pairs (n <= 8: 65k pairs, n <= 10: 1M pairs);
* sampled (uniform operands) for 16/32-bit;
* operand-marginal for RAD: because rel. error depends only on the encoded
  operand (Ch. 4 property), MRED = E_B |(B_hat - B)/B| *exactly* by enumerating
  the 2^n values of B — this is the paper's "accelerated error analysis".
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import encodings as enc


@dataclass
class ErrorReport:
    mred: float
    nmed: float
    max_red: float
    mean_err: float          # signed bias, normalized by max product
    error_rate: float        # fraction of pairs with any error
    pred2: float             # Pr[RED <= 2%]

    def row(self) -> str:
        return (
            f"mred={self.mred:.6f} nmed={self.nmed:.6f} max_red={self.max_red:.4f} "
            f"bias={self.mean_err:+.3e} er={self.error_rate:.4f} pred2={self.pred2:.4f}"
        )


def _report(p_exact: np.ndarray, p_approx: np.ndarray) -> ErrorReport:
    p_exact = p_exact.astype(np.float64)
    p_approx = p_approx.astype(np.float64)
    err = p_approx - p_exact
    nz = p_exact != 0
    red = np.zeros_like(err)
    red[nz] = np.abs(err[nz]) / np.abs(p_exact[nz])
    # where exact product is 0, RED is defined as 0 if approx is also 0 else inf;
    # the paper sidesteps 0 operands — we count them in NMED but clip RED.
    red[~nz & (err != 0)] = np.inf
    finite = np.isfinite(red)
    maxp = np.abs(p_exact).max() if p_exact.size else 1.0
    return ErrorReport(
        mred=float(red[finite].mean()) if finite.any() else 0.0,
        nmed=float(np.abs(err).mean() / max(maxp, 1e-30)),
        max_red=float(red[finite].max()) if finite.any() else 0.0,
        mean_err=float(err.mean() / max(maxp, 1e-30)),
        error_rate=float((err != 0).mean()),
        pred2=float((red[finite] <= 0.02).mean()) if finite.any() else 1.0,
    )


def evaluate_exhaustive(mult_fn, n: int) -> ErrorReport:
    """All operand pairs of an n-bit signed multiplier (n <= 10 sensible)."""
    vals = np.arange(-(1 << (n - 1)), 1 << (n - 1), dtype=np.int64)
    a, b = np.meshgrid(vals, vals, indexing="ij")
    exact = a * b
    approx = mult_fn(a, b)
    return _report(exact, approx)


def evaluate_sampled(mult_fn, n: int, num: int = 1 << 20, seed: int = 0) -> ErrorReport:
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (n - 1)), (1 << (n - 1)) - 1
    a = rng.integers(lo, hi + 1, size=num, dtype=np.int64)
    b = rng.integers(lo, hi + 1, size=num, dtype=np.int64)
    exact = a * b
    approx = mult_fn(a, b)
    return _report(exact, approx)


def rad_operand_marginal(n: int, k: int) -> ErrorReport:
    """Exact RAD error metrics by enumerating only B (the paper's accelerated
    method): RED(A,B) = |B_hat - B| / |B| for every A != 0."""
    b = np.arange(-(1 << (n - 1)), 1 << (n - 1), dtype=np.int64)
    b_hat = enc.np_rad_encode(b, n, k)
    err = (b_hat - b).astype(np.float64)
    nz = b != 0
    red = np.abs(err[nz]) / np.abs(b[nz]).astype(np.float64)
    maxb = float(1 << (n - 1))
    return ErrorReport(
        mred=float(red.mean()),
        nmed=float(np.abs(err).mean() / maxb),
        max_red=float(red.max()),
        mean_err=float(err.mean() / maxb),
        error_rate=float((err != 0).mean()),
        pred2=float((red <= 0.02).mean()),
    )


def evaluate_float(mult_fn, num: int = 1 << 18, seed: int = 0, scale: float = 4.0) -> ErrorReport:
    """Error metrics for an approximate float multiplier against exact fp64."""
    rng = np.random.default_rng(seed)
    a = (rng.standard_normal(num) * scale).astype(np.float32)
    b = (rng.standard_normal(num) * scale).astype(np.float32)
    exact = a.astype(np.float64) * b.astype(np.float64)
    approx = np.asarray(mult_fn(a, b), dtype=np.float64)
    return _report(exact, approx)


# ---------------------------------------------------------------------------
# signal/vision quality metrics (stream-workload calibration — ISSUE 7)
# ---------------------------------------------------------------------------
# The plan autotuner and the serve quality tap calibrate stream workloads on
# application-level quality (the approximate-computing surveys' requirement:
# PSNR/SSIM for signal & vision, not logit error).  All numpy-only, defined
# on arbitrary-shape arrays; ``ref`` is the exact-arithmetic output.


def mse(ref, x) -> float:
    """Mean squared error."""
    ref = np.asarray(ref, np.float64)
    x = np.asarray(x, np.float64)
    return float(np.mean((ref - x) ** 2))


def snr_db(ref, x) -> float:
    """Signal-to-noise ratio in dB: signal power over error power (the
    dissertation's FIR quality figure; shared by bench_dsp and the DSP
    example — previously duplicated in both)."""
    ref = np.asarray(ref, np.float64)
    x = np.asarray(x, np.float64)
    err = ref - x
    p_sig = float(np.mean(ref ** 2))
    p_err = float(np.mean(err ** 2))
    return float(10.0 * np.log10(p_sig / max(p_err, 1e-30)))


def psnr_db(ref, x, peak=None) -> float:
    """Peak signal-to-noise ratio in dB.  ``peak`` defaults to the
    reference's max magnitude (1.0 for an all-zero reference).  The MSE is
    floored at ``peak**2 * 1e-18`` (180 dB ceiling), so identical inputs
    give a large *finite* value — monotone in MSE, JSON-safe, and usable
    negated as a Pareto error axis (``-psnr_db``)."""
    ref = np.asarray(ref, np.float64)
    x = np.asarray(x, np.float64)
    if peak is None:
        peak = float(np.max(np.abs(ref))) or 1.0
    m = max(mse(ref, x), float(peak) ** 2 * 1e-18)
    return float(10.0 * np.log10(float(peak) ** 2 / m))


def ssim(ref, x, peak=None) -> float:
    """Structural similarity (global-statistics variant, Wang et al. 2004
    constants C1=(0.01*peak)^2, C2=(0.03*peak)^2): luminance x contrast x
    structure over the whole array rather than a sliding window — the
    scale-invariant per-rung quality figure the bench gate checks.  Exactly
    1.0 on identical inputs; finite on constant signals (the stabilizing
    constants keep every denominator positive)."""
    ref = np.asarray(ref, np.float64)
    x = np.asarray(x, np.float64)
    if peak is None:
        peak = float(np.max(np.abs(ref))) or 1.0
    c1 = (0.01 * peak) ** 2
    c2 = (0.03 * peak) ** 2
    mu_r, mu_x = float(np.mean(ref)), float(np.mean(x))
    var_r, var_x = float(np.var(ref)), float(np.var(x))
    cov = float(np.mean((ref - mu_r) * (x - mu_x)))
    return ((2 * mu_r * mu_x + c1) * (2 * cov + c2)
            / ((mu_r ** 2 + mu_x ** 2 + c1) * (var_r + var_x + c2)))
