"""Core: the dissertation's arithmetic-approximation techniques as a
composable JAX library.

Layers:
  encodings       bit-exact operand encodings (Booth, DLSB, hybrid high-radix)
  axmult          the approximate multiplier families (RAD, PR/AxFXU, ROUP,
                  AxFPU, DyFXU dynamic variants)
  error_analysis  MRED/NMED/PRED evaluation harness
  area_model      the paper's unit-gate area/energy proxy model
  pareto          Ch. 6 cooperative-approximation design-space exploration
  approx          per-layer approximation policy (MAx-DNN style)
  quantization    TPU-native effective-bits block quantization (DyFXU analogue)
  dynamic         runtime QoS controller (dynamic approximation tuning)
"""

from . import (  # noqa: F401
    area_model,
    axmult,
    dynamic,
    encodings,
    error_analysis,
    pareto,
    quantization,
)
from .approx import EXACT, ApproxMode, ApproxPolicy, ApproxSpec, uniform  # noqa: F401
