"""Runtime approximation control — the DyFXU/DyFPU analogue at system level
(Ch. 5 §5.2.3 "Dynamic Configuration of the Approximation Degree").

The paper's circuits expose (p, r) configuration registers written at runtime;
the gains of approximation remain available without re-synthesis at ~3% area
overhead.  Here the same contract is: the deployed computation keeps its
compiled XLA executable (degree is a *traced* scalar input), and this host-side
controller moves the degree to track a quality budget — the embedded-systems
QoS loop of the dissertation.

Control law (simple, monotone, hysteresis-banded):
  * quality signal q_t (e.g. eval loss delta vs exact probe, or logit-KL);
  * if EMA(q) < low_water  -> increase approximation (cheaper, lossier);
  * if EMA(q) > high_water -> decrease approximation (costlier, safer);
  * degree clamped to the configured ladder.
"""

from __future__ import annotations

from dataclasses import dataclass, field


def degree_operand(entry: dict):
    """Turn one QoS ladder entry into the traced degree operand the models
    consume: ``{"degrees": [...]}`` (an ApproxPlan rung) becomes a per-site
    int32 vector, ``{"ebits": n}`` the legacy global scalar.  The single
    decoder shared by the serve engine and the trainer — the ladder-entry
    format has exactly one owner."""
    import jax.numpy as jnp

    if "degrees" in entry:
        return jnp.asarray([int(e) for e in entry["degrees"]], jnp.int32)
    return jnp.asarray(int(entry.get("ebits", 8)), jnp.int32)


def degree_record(degree, *, as_tuple: bool = False):
    """Loggable/hashable form of a degree operand: a plain int for the
    global scalar, a tuple of ints for a per-site vector.  The one
    operand-to-record rule (engine history, trainer history/checkpoints).

    ``as_tuple=True`` normalizes the scalar case to a 1-tuple as well, so
    consumers that iterate record streams (metrics exporters, trace
    events, tests) never isinstance-branch on int-vs-tuple — the serve
    engine's ``degree_history`` records in this form."""
    import numpy as np

    arr = np.asarray(degree)
    if arr.ndim or as_tuple:
        return tuple(int(x) for x in arr.reshape(-1))
    return int(arr)


@dataclass
class QoSController:
    """Moves an integer degree along a ladder to track an error budget.

    degree semantics: index into `ladder`; entry 0 = most accurate.
    `ladder` entries are opaque to the controller — either global degree
    kwargs (`{'ebits': 8} .. {'ebits': 5}`) or whole per-layer ApproxPlan
    rungs (`{'degrees': [...]}`, see repro.tune.plan.ApproxPlan.qos_ladder);
    the consumer (serve engine / trainer) turns the chosen entry into the
    traced degree operand.
    """

    ladder: list[dict]
    low_water: float
    high_water: float
    ema_alpha: float = 0.1
    cooldown_steps: int = 10
    degree: int = 0
    _ema: float | None = field(default=None, repr=False)
    _cooldown: int = field(default=0, repr=False)
    history: list[tuple[int, float, int]] = field(default_factory=list, repr=False)

    def update(self, step: int, quality_signal: float) -> dict:
        """Feed one quality observation; returns the (possibly new) degree
        kwargs to apply at the next step."""
        self._ema = (
            quality_signal
            if self._ema is None
            else (1 - self.ema_alpha) * self._ema + self.ema_alpha * quality_signal
        )
        if self._cooldown > 0:
            self._cooldown -= 1
        elif self._ema < self.low_water and self.degree < len(self.ladder) - 1:
            self.degree += 1          # quality headroom -> approximate harder
            self._cooldown = self.cooldown_steps
        elif self._ema > self.high_water and self.degree > 0:
            self.degree -= 1          # quality violated -> back off
            self._cooldown = self.cooldown_steps
        self.history.append((step, float(self._ema), self.degree))
        return self.ladder[self.degree]

    @property
    def ema(self) -> float:
        return self._ema if self._ema is not None else 0.0
