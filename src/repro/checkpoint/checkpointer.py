"""Fault-tolerant checkpointing (no orbax in this container — built from
scratch, DESIGN.md §3):

  * atomic: write to step-dir.tmp, fsync manifest, os.replace -> step-dir;
  * manifest with per-array digest so a torn write is detected and the
    restore falls back to the previous valid step;
  * async: a background thread serializes (params are first device_get'd on
    the main thread so training can proceed);
  * mesh-agnostic: arrays are saved unsharded (gathered) with their tree
    paths, so restore works onto any mesh/layout (elastic restart);
  * pipeline cursor + python RNG state + step config all live in the
    manifest -> bit-reproducible resume.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(p): np.asarray(jax.device_get(v)) for p, v in flat}


def _check_array(name: str, arr: np.ndarray, meta: dict) -> None:
    """Verify one loaded array against its manifest entry: shape AND the
    content digest stamped at save time — same-size bit corruption (a bad
    sector, a torn concurrent write) fails here, not at some NaN three
    thousand train steps later."""
    if list(arr.shape) != meta["shape"]:
        raise ValueError(f"checkpoint array {name!r}: shape {list(arr.shape)}"
                         f" != manifest {meta['shape']}")
    digest = hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]
    if digest != meta["digest"]:
        raise ValueError(f"checkpoint array {name!r}: content digest "
                         f"{digest} != manifest {meta['digest']} (corrupt)")


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        """Snapshot `tree` (host copy taken synchronously), write async
        unless blocking."""
        arrays = _flatten_with_paths(tree)
        extra = dict(extra or {})
        self.wait()  # one in-flight save at a time
        if blocking:
            self._write(step, arrays, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, arrays, extra),
                daemon=True)
            self._thread.start()

    def _write_guard(self, step, arrays, extra):
        try:
            self._write(step, arrays, extra)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, arrays: dict[str, np.ndarray], extra: dict):
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "arrays": {}}
        for name, arr in arrays.items():
            fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
            np.save(tmp / fname, arr)
            manifest["arrays"][name] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "digest": hashlib.sha1(arr.tobytes()).hexdigest()[:16],
            }
        mf = tmp / "manifest.json"
        with open(mf, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ---------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():
                try:
                    out.append(int(d.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_valid_step(self) -> Optional[int]:
        """Newest step whose manifest digests verify (torn-write defense)."""
        for s in reversed(self.all_steps()):
            if self._verify(s):
                return s
        return None

    def _verify(self, step: int) -> bool:
        d = self.dir / f"step_{step:010d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            for name, meta in manifest["arrays"].items():
                arr = np.load(d / meta["file"])
                _check_array(name, arr, meta)
            return True
        except Exception:
            return False

    def restore(self, step: int, like: Any) -> tuple[Any, dict]:
        """Restore into the structure of `like` (ShapeDtypeStructs or arrays).
        Returns (tree, extra).  Every loaded array is verified against its
        manifest digest — a truncated or bit-corrupted checkpoint raises
        instead of loading silently (``restore_latest`` skips it)."""
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in flat[0]:
            name = _path_str(p)
            meta = manifest["arrays"].get(name)
            if meta is None:
                raise KeyError(f"checkpoint missing array {name!r}")
            arr = np.load(d / meta["file"])
            _check_array(name, arr, meta)
            if hasattr(ref, "shape") and tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs {ref.shape}")
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat[1], leaves)
        return tree, manifest.get("extra", {})

    def restore_latest(self, like: Any) -> Optional[tuple[int, Any, dict]]:
        s = self.latest_valid_step()
        if s is None:
            return None
        tree, extra = self.restore(s, like)
        return s, tree, extra
