"""Deterministic, resumable, shardable synthetic-token data pipeline.

Design constraints for 1000+-node training (DESIGN.md §3):
  * deterministic as a function of (seed, step) — any host can regenerate any
    batch, so restarts and elastic re-sharding never need data coordination;
  * the cursor is a single integer (global step) stored in the checkpoint;
  * per-host sharding: a host materializes only its slice of the global batch
    (here single-process: the full batch, sharded by pjit on device_put).

The synthetic stream is a mixture of (a) a Markov-chain "language" with
long-range copy dependencies (so loss curves are non-trivial and approximate-
arithmetic ablations are measurable) and (b) optional file-backed token
shards (data/file_source.py style .npy) when real corpora are available.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "markov"      # markov | uniform | file
    file_path: Optional[str] = None
    # markov params
    order_mix: float = 0.7    # P(follow chain) vs uniform
    copy_prob: float = 0.15   # P(copy from 64 tokens back)


class SyntheticPipeline:
    """step -> batch dict; stateless besides the step cursor."""

    def __init__(self, cfg: DataConfig, arch: Optional[ArchConfig] = None):
        self.cfg = cfg
        self.arch = arch
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # sparse-ish Markov transition: each token has 32 likely successors
        self._succ = rng.integers(0, v, size=(min(v, 4096), 32), dtype=np.int32)
        self._file = None
        if cfg.kind == "file" and cfg.file_path:
            # host-side I/O rides the shared resilience retry helper: a
            # transient NFS/FUSE hiccup at trainer start is retried with
            # capped backoff instead of killing the run
            from repro.resil import retry

            self._file = retry(
                lambda: np.load(cfg.file_path, mmap_mode="r"))

    def _markov_tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        follow = rng.random((b, s)) < self.cfg.order_mix
        copy = rng.random((b, s)) < self.cfg.copy_prob
        succ_pick = rng.integers(0, 32, size=(b, s))
        uniform = rng.integers(0, v, size=(b, s), dtype=np.int32)
        m = self._succ.shape[0]
        for t in range(1, s):
            nxt = self._succ[toks[:, t - 1] % m, succ_pick[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, uniform[:, t])
            if t >= 64:
                toks[:, t] = np.where(copy[:, t], toks[:, t - 64], toks[:, t])
        return toks

    def batch_at(self, step: int, host_slice: slice | None = None) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        b, s = c.global_batch, c.seq_len
        if self._file is not None:
            n = self._file.shape[0]
            starts = rng.integers(0, n - s - 1, size=b)
            toks = np.stack([self._file[st:st + s + 1] for st in starts]) \
                .astype(np.int32)
        elif c.kind == "uniform":
            toks = rng.integers(0, c.vocab, size=(b, s + 1), dtype=np.int32)
        else:
            toks = self._markov_tokens(rng, b, s + 1)
        if host_slice is not None:
            toks = toks[host_slice]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.arch is not None and self.arch.frontend == "audio":
            feats = rng.standard_normal(
                (toks.shape[0], s, self.arch.frontend_dim)).astype(np.float32)
            # HuBERT-style masked prediction: mask 8% spans, loss on masked
            mask = rng.random((toks.shape[0], s)) < 0.08
            labels = np.where(mask, batch["tokens"] % self.arch.vocab, -1)
            return {"frame_feats": feats, "labels": labels.astype(np.int32)}
        if self.arch is not None and self.arch.frontend == "vision":
            s_img = self.arch.frontend_tokens
            pe = rng.standard_normal(
                (toks.shape[0], s_img, self.arch.frontend_dim)).astype(np.float32)
            return {
                "patch_embeds": pe,
                "tokens": batch["tokens"][:, : s - s_img],
                "labels": batch["labels"][:, : s - s_img],
            }
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(arch: ArchConfig, seq_len: int, global_batch: int,
                  seed: int = 1234, kind: str = "markov") -> SyntheticPipeline:
    return SyntheticPipeline(
        DataConfig(vocab=arch.vocab, seq_len=seq_len, global_batch=global_batch,
                   seed=seed, kind=kind),
        arch,
    )
