"""Byte-level tokenizer (for the runnable examples; real deployments plug a
sentencepiece model into the same interface)."""

from __future__ import annotations

import numpy as np


class ByteTokenizer:
    """Reversible byte tokenizer with BOS/EOS; vocab = 256 + specials."""

    BOS = 256
    EOS = 257
    PAD = 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) for i in ids if int(i) < 256)
        return bs.decode("utf-8", errors="replace")
