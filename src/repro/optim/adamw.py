"""AdamW from scratch (no optax in this container) + global-norm clipping +
gradient accumulation, pure-pytree style so optimizer state shards exactly
like parameters.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AdamWState(NamedTuple):
    step: Array        # () int32
    mu: Any            # pytree like params (f32)
    nu: Any            # pytree like params (f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _decay_mask(path_leaf) -> bool:
    """No weight decay on norms/biases/1-d params."""
    return path_leaf.ndim >= 2


def update(cfg: AdamWConfig, state: AdamWState, params, grads, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def cosine_warmup(step: Array, *, warmup: int, total: int,
                  min_ratio: float = 0.1) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum((s + 1.0) / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
