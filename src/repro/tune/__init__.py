"""repro.tune — calibration-driven per-layer approximation plans.

Offline half: :func:`build_plan` / :func:`profile_sensitivity` explore mixed
per-layer degree assignments on a calibration batch and emit a serializable
:class:`ApproxPlan` (plan.py).  Runtime half: the plan's degree ladder is
executed by the models' per-layer degree vectors (models/degrees.py) and
stepped by the serve QoS controller (serve/engine.py ``plan=``).
See docs/plans.md for the workflow.
"""

from repro.tune.autotune import (build_plan, energy_per_mac, measure_error,
                                 profile_sensitivity, site_macs, vector_cost)
from repro.tune.plan import (ApproxPlan, PlanPoint, site_names, uniform_plan)

__all__ = [
    "ApproxPlan", "PlanPoint", "build_plan", "energy_per_mac",
    "measure_error", "profile_sensitivity", "site_macs", "site_names",
    "uniform_plan", "vector_cost",
]
