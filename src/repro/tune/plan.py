"""ApproxPlan: a serialized per-layer approximation assignment + degree ladder.

The dissertation's methodology is two-staged: an *offline* exploration of the
approximation space (Ch. 6 — here `repro.tune.autotune`, driven by a
calibration batch) and a *runtime* configuration register that moves the
approximation degree without re-synthesis (Ch. 5 §5.2.3 — here the traced
per-layer degree vector of models/degrees.py).  The `ApproxPlan` is the
artifact that connects them: a checkpoint-adjacent JSON file holding

  * the **sites** — one per layer plus the shared head site, in the model's
    stacking order (hybrid: group-major, tail last);
  * the **static configuration** — execution mode (AXQ) and quantization
    block, from which :meth:`ApproxPlan.policy` rebuilds the ApproxPolicy the
    model must run under for the plan's degrees to mean anything;
  * the measured per-site **sensitivity** profile (calibration metadata kept
    for auditability — re-tuning can tell whether the model drifted);
  * the **ladder** — an ordered sequence of Pareto points, most accurate
    first.  Each :class:`PlanPoint` is a full per-site degree vector with its
    measured calibration error and modeled cost, so the serve QoS controller
    steps between *whole mixed configurations* instead of rescaling one
    global knob.

Round-tripping is bit-stable: `ApproxPlan.load(p.save(path))` compares equal
field-for-field (degrees are plain ints, floats go through `repr`-exact JSON).
"""

from __future__ import annotations

import json
import pathlib
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core.approx import ApproxMode, ApproxPolicy, ApproxSpec, uniform

PLAN_VERSION = 1


@dataclass(frozen=True)
class PlanPoint:
    """One rung of the degree ladder: a full per-site assignment.

    ``degrees``: tuple of ints, one per plan site (layers then head), each an
    AXQ effective-bits degree in 1..8.  ``error`` is the calibration metric
    measured with this exact vector (autotune.measure_error); ``cost`` is the
    unit-gate energy proxy of the whole network under this vector, normalized
    so the all-8 assignment costs 1.0.
    """

    name: str
    degrees: tuple
    error: float
    cost: float

    def degree_array(self) -> np.ndarray:
        return np.asarray(self.degrees, np.int32)


@dataclass
class ApproxPlan:
    """Serializable per-layer approximation plan (see module docstring)."""

    arch: str
    sites: list
    ladder: list                      # list[PlanPoint], most accurate first
    mode: str = "axq"
    block: int = 256
    sensitivity: dict = field(default_factory=dict)   # site -> {ebits: error}
    meta: dict = field(default_factory=dict)
    version: int = PLAN_VERSION

    # ---- runtime -----------------------------------------------------

    def num_sites(self) -> int:
        return len(self.sites)

    def degrees(self, rung: int = 0) -> np.ndarray:
        """The per-site degree vector of ladder rung ``rung`` (0 = most
        accurate), ready to pass as the model's runtime ``degree``."""
        return self.ladder[rung].degree_array()

    def policy(self, dynamic: bool = True) -> ApproxPolicy:
        """The ApproxPolicy the model must be built with to execute this
        plan: a uniform spec in the plan's mode/block whose *degree* is the
        runtime knob (``dynamic=True`` so the traced vector wins over the
        spec's static ebits)."""
        if self.mode != ApproxMode.AXQ.value:
            raise ValueError(
                f"only AXQ plans execute at runtime (got mode {self.mode!r}); "
                "emulation modes are exploration-stage only")
        return uniform(ApproxSpec(mode=ApproxMode.AXQ, ebits=8,
                                  block=self.block, dynamic=dynamic))

    def qos_ladder(self) -> list:
        """Ladder entries for :class:`repro.core.dynamic.QoSController`:
        each rung contributes ``{"degrees": [...]}`` kwargs, consumed by the
        serve engine / trainer in place of the global ``{"ebits": n}``."""
        return [{"degrees": list(pt.degrees)} for pt in self.ladder]

    # ---- (de)serialization -------------------------------------------

    def to_dict(self) -> dict:
        d = asdict(self)
        d["ladder"] = [
            {**asdict(pt), "degrees": list(pt.degrees)} for pt in self.ladder
        ]
        # JSON object keys are strings: canonicalize the per-site ebits keys
        # so save -> load -> to_dict round-trips field-for-field
        d["sensitivity"] = {
            site: {str(e): v for e, v in prof.items()}
            for site, prof in self.sensitivity.items()
        }
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ApproxPlan":
        if d.get("version", 1) > PLAN_VERSION:
            raise ValueError(f"plan version {d['version']} is newer than "
                             f"this reader ({PLAN_VERSION})")
        ladder = [
            PlanPoint(name=p["name"], degrees=tuple(int(x) for x in p["degrees"]),
                      error=float(p["error"]), cost=float(p["cost"]))
            for p in d["ladder"]
        ]
        sens = {
            site: {int(e): float(v) for e, v in prof.items()}
            for site, prof in d.get("sensitivity", {}).items()
        }
        return cls(arch=d["arch"], sites=list(d["sites"]), ladder=ladder,
                   mode=d.get("mode", "axq"), block=int(d.get("block", 256)),
                   sensitivity=sens,
                   meta=d.get("meta", {}), version=d.get("version", 1))

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "ApproxPlan":
        return cls.from_dict(json.loads(pathlib.Path(path).read_text()))

    def validate_for(self, cfg) -> None:
        """Loud mismatch check before running a plan against a model."""
        if self.arch != cfg.name:
            raise ValueError(
                f"plan was tuned for arch {self.arch!r}, not {cfg.name!r} — "
                "its calibrated errors/costs do not transfer; re-tune")
        want = cfg.n_layers + 1
        if len(self.sites) != want:
            raise ValueError(
                f"plan has {len(self.sites)} sites but arch {cfg.name!r} "
                f"needs {want} (n_layers + head)")
        if not self.ladder:
            raise ValueError("plan has an empty ladder")
        for pt in self.ladder:
            if len(pt.degrees) != want:
                raise ValueError(f"ladder point {pt.name!r} has "
                                 f"{len(pt.degrees)} degrees, needs {want}")


def site_names(cfg) -> list:
    """Canonical plan site names: ``layer_i`` in stacking order, then
    ``head`` (unembedding + frontend projections).  Non-LM configs may
    carry their own names (``StreamConfig.site_names`` -> fir/conv2d/gain);
    the count contract (n_layers + 1) is unchanged."""
    if hasattr(cfg, "site_names"):
        return list(cfg.site_names())
    return [f"layer_{i}" for i in range(cfg.n_layers)] + ["head"]


def uniform_plan(cfg, ebits_ladder=(8, 7, 6, 5), block: int = 256) -> ApproxPlan:
    """A degenerate plan whose every rung is a uniform assignment — the
    pre-plan global-knob behavior expressed in plan form (baselines, tests)."""
    sites = site_names(cfg)
    ladder = [
        PlanPoint(name=f"uniform_e{e}", degrees=tuple([int(e)] * len(sites)),
                  error=0.0, cost=0.0)
        for e in ebits_ladder
    ]
    return ApproxPlan(arch=cfg.name, sites=sites, ladder=ladder, block=block,
                      meta={"kind": "uniform"})
