"""Calibration-driven approximation-plan search (the Ch. 6 exploration loop
aimed at a deployed network instead of a lone multiplier).

The uniform global degree the QoS controller used to rescale treats every
layer as equally error-sensitive; the surveys the repo tracks (Leon et al.,
arXiv:2307.11124 / 2307.11128) identify per-layer assignment driven by
error-sensitivity profiling as the technique that dominates it on the
quality-vs-cost front.  This module closes that loop:

  1. :func:`profile_sensitivity` — one calibration batch, one site at a time:
     degrade site ``i`` to ``e`` effective bits while every other site stays
     at 8, and record the output-error metric.  Because the runtime degree is
     a traced vector (models/degrees.py), the whole profile runs inside ONE
     compiled executable.
  2. :func:`build_plan` — greedy descent over mixed assignments: repeatedly
     degrade the site with the best modeled-cost-saving per predicted-error
     ratio, *measure* the true error of each visited vector, keep the
     Pareto-optimal visits (``core.pareto.front_mask`` — the same dominance
     rule as the multiplier-space exploration), and emit the front as an
     :class:`~repro.tune.plan.ApproxPlan` degree ladder.

Costs come from the dissertation's own unit-gate model: dropping to ``e``
effective bits is the rounding knob ``r = 8 - e`` of the PR multiplier
(``core.quantization`` maps them 1:1), so a site's per-MAC energy is
``area_model.energy_proxy("ROUND", 8, r=8-e)`` and a vector's cost is the
MAC-weighted sum over sites, normalized to the all-8 assignment.

Everything here is offline tooling: jitted forwards on a calibration batch,
no engine or kernel changes — the emitted plan is what crosses into runtime.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area_model, pareto
from repro.core.approx import ApproxPolicy
from repro.tune.plan import ApproxPlan, PlanPoint, site_names

DEFAULT_GRID = (8, 7, 6, 5, 4)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def energy_per_mac(ebits: int, n: int = 8) -> float:
    """Unit-gate energy proxy of one MAC at ``ebits`` effective bits: the
    PR multiplier with rounding at ``r = n - ebits`` (the DyFXU mapping of
    core/quantization.py), normalized so ``ebits == n`` costs 1.0."""
    base = area_model.energy_proxy("ROUND", n, p=0, r=0)
    return area_model.energy_proxy("ROUND", n, p=0, r=n - int(ebits)) / base


def site_macs(cfg) -> list:
    """Approximate per-site MAC counts (one forward token) for the matmuls
    the approximation dispatch touches — the weights of the cost sum.
    Order matches ``plan.site_names``: layers in stacking order, head last.

    Configs may carry their own counts (non-LM workloads — e.g. the stream
    pipeline's ``StreamConfig.site_macs``): that override wins outright."""
    if hasattr(cfg, "site_macs"):
        return [float(m) for m in cfg.site_macs()]
    d = cfg.d_model
    pd = cfg.padded(1)

    def attn_macs() -> float:
        qo = 2 * d * pd.n_heads * cfg.head_dim
        kv = 2 * d * cfg.n_kv_heads * cfg.head_dim
        return qo + kv

    def mlp_macs(d_ff: int) -> float:
        return 3 * d * d_ff

    per_layer: list = []
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        H = d_in // s.headdim
        lm = d * (2 * d_in + 2 * s.d_state + H) + d_in * d
        per_layer = [float(lm)] * cfg.n_layers
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern
        n_groups, tail = divmod(cfg.n_layers, len(pat))
        rec = 5 * d * d + mlp_macs(cfg.d_ff)
        att = attn_macs() + mlp_macs(cfg.d_ff)
        group = [rec if name == "rec" else att for name in pat]
        per_layer = group * n_groups + [rec] * tail
    else:
        if cfg.moe:
            m = cfg.moe
            ffn = (d * m.n_experts                       # router
                   + m.top_k * 3 * d * m.d_expert
                   + m.n_shared * 3 * d * m.d_shared)
        else:
            ffn = mlp_macs(cfg.d_ff)
        per_layer = [float(attn_macs() + ffn)] * cfg.n_layers
    head = float(d * cfg.vocab)
    if cfg.frontend:
        head += float(cfg.frontend_dim * d)
    return per_layer + [head]


def vector_cost(cfg, degrees: Sequence[int]) -> float:
    """Modeled cost of a per-site degree vector: MAC-weighted unit-gate
    energy, normalized so the uniform all-8 vector costs 1.0."""
    macs = site_macs(cfg)
    assert len(macs) == len(degrees), (len(macs), len(degrees))
    total = sum(m * energy_per_mac(e) for m, e in zip(macs, degrees))
    return total / sum(macs)


# ---------------------------------------------------------------------------
# calibration error
# ---------------------------------------------------------------------------


class _Prober:
    """Jit-cached forwards for one (model, params, batch): an exact-policy
    reference plus an AXQ forward taking the degree vector as a traced
    operand (one compile for the whole profile/search).  Errors are memoized
    per degree vector, so the sensitivity profile and the search never pay
    twice for the same assignment.

    ``metric`` makes the calibration error pluggable (ISSUE 7: plans must
    calibrate on *application-level* error — PSNR/SSIM for signal/vision
    streams, logit error for LMs): a callable ``metric(ref, out) -> float``
    over float64 numpy arrays, LOWER = better (Pareto front_mask minimizes
    both axes — wrap quality-style metrics as their negation, e.g.
    ``lambda ref, out: -psnr_db(ref, out)``).  None keeps the historical
    normalized-RMS deviation bit-for-bit.

    Models may supply their exact-arithmetic twin via an ``exact_model()``
    hook (servable workloads); LM Models fall back to the exact-policy
    rebuild."""

    def __init__(self, model, params, batch, metric=None):
        self.cfg = model.cfg
        self.batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.params = params
        self.metric = metric
        if hasattr(model, "exact_model"):
            exact = model.exact_model()
        else:
            from repro.models.registry import Model

            exact = Model(model.cfg, ApproxPolicy())
        self._fwd_exact = jax.jit(
            lambda p, b: exact.forward(p, b, remat="none")[0])
        self._fwd = jax.jit(
            lambda p, b, deg: model.forward(p, b, degree=deg, remat="none")[0])
        self.ref = np.asarray(self._fwd_exact(params, self.batch),
                              np.float64)
        self._ref_rms = float(np.sqrt(np.mean(self.ref ** 2))) or 1.0
        self._memo: dict = {}

    def error(self, degrees: Sequence[int]) -> float:
        """Calibration error vs the exact-arithmetic reference: the plugged
        ``metric``, or normalized RMS output deviation (the NMED analogue at
        network scale) by default."""
        key = tuple(int(e) for e in degrees)
        if key in self._memo:
            return self._memo[key]
        deg = jnp.asarray(np.asarray(degrees, np.int32))
        out = np.asarray(self._fwd(self.params, self.batch, deg), np.float64)
        if self.metric is not None:
            err = float(self.metric(self.ref, out))
        else:
            err = float(np.sqrt(np.mean((out - self.ref) ** 2))
                        / self._ref_rms)
        self._memo[key] = err
        return err


def measure_error(model, params, batch, degrees, metric=None) -> float:
    """One-off measurement (tests / benches); for sweeps build a
    :class:`_Prober` once via :func:`build_plan`."""
    return _Prober(model, params, batch, metric=metric).error(degrees)


def profile_sensitivity(model, params, batch,
                        grid: Sequence[int] = DEFAULT_GRID,
                        prober: Optional[_Prober] = None,
                        metric=None) -> dict:
    """Per-site error-sensitivity profile on a calibration batch.

    For each site ``i`` and degree ``e`` in ``grid`` (below 8), measure the
    output error of the vector that is all-8 except ``degrees[i] = e``.
    Returns ``{site_name: {ebits: error}}`` — the auditable record the plan
    carries (re-tuning can detect model drift).  The search itself ranks
    candidates by *measured* errors, not this profile; sharing a prober
    just makes these single-site probes free for it (error memo)."""
    p = prober or _Prober(model, params, batch, metric=metric)
    names = site_names(model.cfg)
    S = len(names)
    out: dict = {}
    for i, name in enumerate(names):
        prof = {}
        for e in grid:
            if e >= 8:
                continue
            vec = [8] * S
            vec[i] = int(e)
            prof[int(e)] = p.error(vec)
        out[name] = prof
    return out


# ---------------------------------------------------------------------------
# plan search
# ---------------------------------------------------------------------------


def build_plan(model, params, batch, *, grid: Sequence[int] = DEFAULT_GRID,
               max_rungs: int = 8, block: Optional[int] = None,
               exhaustive_budget: int = 160,
               seed_meta: Optional[dict] = None,
               prober: Optional[_Prober] = None,
               metric=None) -> ApproxPlan:
    """Search mixed per-site degree assignments and emit the Pareto ladder.

    ``model`` must be built with the plan-execution policy (uniform dynamic
    AXQ — ``ApproxPlan.policy()``); ``batch`` is the calibration batch the
    errors are measured on.  Two strategies, picked by design-space size:

    * **exhaustive** — when ``len(grid) ** n_sites <= exhaustive_budget``,
      every assignment is measured (the Ch. 6 full-space sweep; feasible for
      smoke-scale layer counts).
    * **measured greedy** — otherwise: starting from uniform-8, every
      single-site one-grid-step candidate is *measured* each round and the
      one with the best cost-saving per error-increase ratio is taken.  All
      probed candidates (not just accepted ones) enter the visited set, so
      the front is denser than the walk itself.

    Visited vectors are filtered by ``core.pareto.front_mask`` on (measured
    error, modeled cost) and the front — subsampled to ``max_rungs`` —
    becomes the ladder, most accurate rung first.

    Callers doing further measurements (benchmarks) can pass a shared
    ``prober`` (``_Prober(model, params, batch)``) — its error memo makes
    every vector the search visited free to re-query.
    """
    import itertools

    cfg = model.cfg
    names = site_names(cfg)
    S = len(names)
    grid = sorted({int(e) for e in grid}, reverse=True)
    if grid[0] != 8:
        raise ValueError(f"grid must start at 8 (got {grid})")
    t0 = time.time()
    prober = prober or _Prober(model, params, batch, metric=metric)
    sens = profile_sensitivity(model, params, batch, grid, prober=prober)
    macs = site_macs(cfg)

    visited: list[tuple[list, float, float]] = []
    seen: set = set()

    def record(vec):
        key = tuple(int(e) for e in vec)
        if key in seen:
            return next(v for v in visited if tuple(v[0]) == key)[1:]
        seen.add(key)
        err = prober.error(vec)          # memoized: profile probes are free
        cost = vector_cost(cfg, vec)
        visited.append((list(key), err, cost))
        return err, cost

    exhaustive = len(grid) ** S <= exhaustive_budget
    if exhaustive:
        for vec in itertools.product(grid, repeat=S):
            record(vec)
    else:
        def next_lower(e: int) -> Optional[int]:
            below = [g for g in grid if g < e]
            return below[0] if below else None

        degrees = [8] * S
        cur_err, cur_cost = record(degrees)
        eps = 1e-12
        while True:
            best = None
            for i in range(S):
                nxt = next_lower(degrees[i])
                if nxt is None:
                    continue
                cand = list(degrees)
                cand[i] = nxt
                err, cost = record(cand)
                score = (cur_cost - cost) / max(err - cur_err, eps)
                if best is None or score > best[0]:
                    best = (score, i, nxt, err, cost)
            if best is None:
                break
            _, i, nxt, cur_err, cur_cost = best
            degrees[i] = nxt

    errs = [v[1] for v in visited]
    costs = [v[2] for v in visited]
    mask = pareto.front_mask(errs, costs)
    front = [v for v, m in zip(visited, mask) if m]
    front.sort(key=lambda v: (-v[2], v[1]))     # costliest == most accurate first
    if len(front) > max_rungs:
        idx = np.linspace(0, len(front) - 1, max_rungs).round().astype(int)
        front = [front[i] for i in sorted(set(idx.tolist()))]
    ladder = [
        PlanPoint(name=f"rung_{r}", degrees=tuple(int(x) for x in vec),
                  error=float(err), cost=float(cost))
        for r, (vec, err, cost) in enumerate(front)
    ]
    used = prober.metric
    meta = {
        "calibration": {k: list(np.shape(v)) for k, v in batch.items()},
        "grid": list(grid),
        "metric": (getattr(used, "metric_name", None)
                   or getattr(used, "__name__", "custom")) if used else "nrms",
        "strategy": "exhaustive" if exhaustive else "greedy",
        "visited": len(visited),
        "tune_seconds": round(time.time() - t0, 3),
        **(seed_meta or {}),
    }
    spec = model.policy.default
    return ApproxPlan(arch=cfg.name, sites=names, ladder=ladder,
                      block=int(block if block is not None else spec.block),
                      sensitivity=sens, meta=meta)
